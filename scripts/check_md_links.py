#!/usr/bin/env python
"""Check that intra-repo markdown links resolve to real files.

Scans every tracked *.md for inline links and fails with a listing of
dangling ones.  External links (scheme://, mailto:) and pure anchors
are skipped; a `path#fragment` link only checks the path.  Run from
anywhere:

    python scripts/check_md_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ROOT = Path(__file__).resolve().parent.parent


def check(root: Path) -> int:
    bad = []
    md_files = [p for p in root.rglob("*.md")
                if ".git" not in p.parts and "results" not in p.parts]
    n_links = 0
    for md in md_files:
        for m in LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            n_links += 1
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                bad.append(f"{md.relative_to(root)}: ({target})")
    if bad:
        print(f"{len(bad)} dangling markdown link(s):")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"{len(md_files)} markdown files, {n_links} intra-repo links, "
          "all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(check(ROOT))
