#!/usr/bin/env python
"""Check that intra-repo markdown links resolve — files AND anchors.

Scans every tracked *.md for inline links and fails with a listing of
dangling ones.  A `path#fragment` link checks both that the path
exists and, for markdown targets, that the fragment names a rendered
heading (GitHub slug rules: lowercase, punctuation stripped, spaces to
dashes, duplicate slugs suffixed -1, -2, ...).  Pure `#fragment`
links validate against the containing file's own headings.  External
links (scheme://, mailto:) are skipped.  Run from anywhere:

    python scripts/check_md_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.+?)\s*$", re.M)
FENCE = re.compile(r"^(```|~~~).*?^\1[^\n]*$", re.M | re.S)
ROOT = Path(__file__).resolve().parent.parent


def slugify(text: str) -> str:
    """GitHub's heading-to-anchor rule: strip inline markup, lowercase,
    drop everything but word chars / spaces / hyphens, spaces become
    hyphens (NOT collapsed — `a — b` renders as `a--b`)."""
    text = re.sub(r"`([^`]*)`", r"\1", text)                 # inline code
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)     # links
    text = re.sub(r"[*_]{1,2}([^*_]+)[*_]{1,2}", r"\1", text)  # emphasis
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(md: Path, cache: dict) -> set:
    if md not in cache:
        # fenced code blocks can hold `# comment` lines — not headings
        body = FENCE.sub("", md.read_text(encoding="utf-8"))
        seen: dict = {}
        out = set()
        for m in HEADING.finditer(body):
            slug = slugify(m.group(1))
            n = seen.get(slug, 0)
            seen[slug] = n + 1
            out.add(slug if n == 0 else f"{slug}-{n}")
        cache[md] = out
    return cache[md]


def check(root: Path) -> int:
    bad = []
    md_files = [p for p in root.rglob("*.md")
                if ".git" not in p.parts and "results" not in p.parts]
    anchor_cache: dict = {}
    n_links = n_anchors = 0
    for md in md_files:
        for m in LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if "://" in target or target.startswith("mailto:"):
                continue
            path, _, frag = target.partition("#")
            resolved = (md.parent / path).resolve() if path else md
            if path:
                n_links += 1
                if not resolved.exists():
                    bad.append(f"{md.relative_to(root)}: ({target})")
                    continue
            if frag and resolved.suffix == ".md":
                n_anchors += 1
                if frag not in anchors_of(resolved, anchor_cache):
                    bad.append(f"{md.relative_to(root)}: ({target}) — "
                               f"no heading renders as #{frag}")
    if bad:
        print(f"{len(bad)} dangling markdown link(s):")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"{len(md_files)} markdown files, {n_links} intra-repo links "
          f"+ {n_anchors} anchor fragments, all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(check(ROOT))
