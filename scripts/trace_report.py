#!/usr/bin/env python
"""Summarise a serve telemetry JSONL trace (--trace-out output).

Prints per-tenant / per-SLO latency percentiles (TTFT and queue
delay in the trace's own clock units), speculation accept-rate, the
dispatch-kind step mix, and a migration table.  Extras:

    python scripts/trace_report.py TRACE.jsonl
    python scripts/trace_report.py TRACE.jsonl --validate
    python scripts/trace_report.py TRACE.jsonl --chrome OUT.json

``--validate`` re-checks the JSONL schema contract (line types, span
shape, event kinds, terminal uniqueness, token accounting) and exits
nonzero on any violation — CI runs it over the smoke trace.
``--chrome`` converts the trace to Chrome trace-event JSON for
Perfetto / chrome://tracing.

The telemetry module is loaded straight from its source file so this
script never imports the jax-heavy ``repro.serve`` package — it runs
anywhere a trace file lands, no accelerator stack required.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import sys
from collections import defaultdict
from pathlib import Path

_TEL_PATH = (Path(__file__).resolve().parent.parent / "src" / "repro"
             / "serve" / "telemetry.py")


def _load_telemetry():
    spec = importlib.util.spec_from_file_location(
        "_serve_telemetry", _TEL_PATH)
    mod = importlib.util.module_from_spec(spec)
    # dataclass processing resolves the defining module through
    # sys.modules, so the file-loaded module must be registered first
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


def load_lines(path: str):
    lines = []
    with open(path) as f:
        for i, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                lines.append(json.loads(raw))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{i}: not JSON ({e})")
    return lines


def validate(lines, tel) -> list:
    """Schema check over parsed lines; returns violation strings."""
    errs = []
    if not lines or lines[0].get("type") != "meta":
        errs.append("first line must be the meta record")
    for i, ln in enumerate(lines, 1):
        typ = ln.get("type")
        if typ not in ("meta", "span", "step", "metrics"):
            errs.append(f"line {i}: unknown type {typ!r}")
            continue
        if typ == "span":
            for field in ("rid", "tenant", "slo", "events"):
                if field not in ln:
                    errs.append(f"line {i}: span missing {field!r}")
            evs = ln.get("events", [])
            kinds = [e.get("kind") for e in evs]
            for e in evs:
                if e.get("kind") not in tel.EVENT_KINDS:
                    errs.append(f"line {i}: rid {ln.get('rid')} bad "
                                f"event kind {e.get('kind')!r}")
                if not isinstance(e.get("t"), (int, float)):
                    errs.append(f"line {i}: rid {ln.get('rid')} event "
                                "missing numeric t")
            if kinds == ["shed"]:
                # a shed span is a rejected submit: the lone marker,
                # no admission, no terminal, nothing generated
                if ln.get("generated", 0) != 0:
                    errs.append(f"line {i}: rid {ln.get('rid')} shed "
                                "span reports generated tokens")
                continue
            if kinds and kinds[0] != "submitted":
                errs.append(f"line {i}: rid {ln.get('rid')} span does "
                            "not open with 'submitted'")
            terms = [k for k in kinds if k in tel.TERMINAL_KINDS]
            if kinds and (len(terms) != 1
                          or kinds[-1] not in tel.TERMINAL_KINDS):
                errs.append(f"line {i}: rid {ln.get('rid')} has "
                            f"{len(terms)} terminal events")
            if kinds.count("failed") != kinds.count("recovered"):
                errs.append(f"line {i}: rid {ln.get('rid')} has "
                            f"{kinds.count('failed')} failed but "
                            f"{kinds.count('recovered')} recovered "
                            "events")
            ntok = sum(e.get("n", 0) for e in evs
                       if e.get("kind") in ("decode_round", "promoted"))
            if "generated" in ln and ntok != ln["generated"]:
                errs.append(f"line {i}: rid {ln.get('rid')} events "
                            f"confirm {ntok} tokens, span header says "
                            f"{ln['generated']}")
        elif typ == "step":
            for field in ("component", "t"):
                if field not in ln:
                    errs.append(f"line {i}: step missing {field!r}")
        elif typ == "metrics" and "values" not in ln:
            errs.append(f"line {i}: metrics missing 'values'")
    return errs


def report(lines, tel, out=sys.stdout):
    meta = lines[0] if lines and lines[0].get("type") == "meta" else {}
    unit = meta.get("clock", "steps")
    spans = [ln for ln in lines if ln.get("type") == "span"]
    steps = [ln for ln in lines if ln.get("type") == "step"]

    ttft = defaultdict(list)      # (tenant, slo) -> [ttft, ...]
    qdelay = defaultdict(list)    # (tenant, slo) -> [admit delay, ...]
    drafted = accepted = 0
    migrations = []
    failures = []                 # (rid, replica, reason, confirmed)
    n_finished = n_cancelled = n_shed = 0
    for sp in spans:
        evs = sp.get("events", [])
        key = (sp.get("tenant", "default"), sp.get("slo", "batch"))
        t_sub = next((e["t"] for e in evs
                      if e["kind"] == "submitted"), None)
        t_adm = next((e["t"] for e in evs
                      if e["kind"] == "admitted"), None)
        t_tok = next((e["t"] for e in evs
                      if e["kind"] in ("promoted", "decode_round")
                      and e.get("n", 0) > 0), None)
        if t_sub is not None and t_adm is not None:
            qdelay[key].append(t_adm - t_sub)
        if t_sub is not None and t_tok is not None:
            ttft[key].append(t_tok - t_sub)
        for e in evs:
            if e["kind"] == "decode_round":
                drafted += e.get("drafted", 0)
                accepted += e.get("accepted", 0)
            elif e["kind"] == "migrated":
                migrations.append((sp["rid"], e.get("src", "?"),
                                   e.get("dst", "?"),
                                   e.get("n_generated", 0)))
            elif e["kind"] == "failed":
                failures.append([sp["rid"], e.get("replica", "?"),
                                 e.get("reason", "?"), 0])
            elif e["kind"] == "recovered" and failures \
                    and failures[-1][0] == sp["rid"]:
                failures[-1][3] = e.get("n_confirmed", 0)
            elif e["kind"] == "shed":
                n_shed += 1
            elif e["kind"] == "finished":
                n_finished += 1
            elif e["kind"] == "cancelled":
                n_cancelled += 1

    w = out.write
    w(f"trace: {len(spans)} requests ({n_finished} finished, "
      f"{n_cancelled} cancelled), {len(steps)} step records, "
      f"clock={unit}\n")

    if ttft or qdelay:
        w(f"\nlatency by tenant/SLO ({unit}):\n")
        w(f"  {'tenant':<10} {'slo':<12} {'n':>4} "
          f"{'ttft_p50':>9} {'ttft_p99':>9} "
          f"{'queue_p50':>9} {'queue_p99':>9}\n")
        for key in sorted(set(ttft) | set(qdelay)):
            tt, qq = ttft.get(key, []), qdelay.get(key, [])
            w(f"  {key[0]:<10} {key[1]:<12} {len(tt):>4} "
              f"{tel.percentile(tt, 50):>9.2f} "
              f"{tel.percentile(tt, 99):>9.2f} "
              f"{tel.percentile(qq, 50):>9.2f} "
              f"{tel.percentile(qq, 99):>9.2f}\n")

    if drafted:
        w(f"\nspeculation: {accepted}/{drafted} drafts accepted "
          f"(accept_rate={accepted / drafted:.3f})\n")

    kinds = defaultdict(int)
    for ln in steps:
        if ln.get("component") == "engine":
            kinds[ln.get("kind", "?")] += 1
    if kinds:
        w("\nengine step mix: ")
        w(", ".join(f"{k}={n}" for k, n in
                    sorted(kinds.items(), key=lambda kv: -kv[1])))
        w("\n")

    if migrations:
        w(f"\nmigrations ({len(migrations)}):\n")
        w(f"  {'rid':>5} {'src':<6} {'dst':<6} {'tokens_carried':>14}\n")
        for rid, src, dst, n in migrations:
            w(f"  {rid:>5} {src:<6} {dst:<6} {n:>14}\n")

    if failures:
        w(f"\nfailures/recoveries ({len(failures)}):\n")
        w(f"  {'rid':>5} {'replica':<8} {'reason':<8} "
          f"{'confirmed_toks':>14}\n")
        for rid, rep, why, n in failures:
            w(f"  {rid:>5} {rep:<8} {why:<8} {n:>14}\n")
    if n_shed:
        w(f"\nshed: {n_shed} submits rejected under degraded "
          "capacity\n")

    final = next((ln for ln in reversed(lines)
                  if ln.get("type") == "metrics"), None)
    if final:
        vals = final.get("values", {})
        picks = sorted(k for k in vals
                       if k.startswith(("n_total_dispatches",
                                        "n_migrations",
                                        "n_replicas_peak",
                                        "n_failures",
                                        "n_recovered_requests",
                                        "n_recovery_replayed_tokens",
                                        "n_repairs", "n_shed")))
        if picks:
            w("\nfinal metrics: ")
            w(", ".join(f"{k}={vals[k]:g}" for k in picks))
            w("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarise a serve telemetry JSONL trace.")
    ap.add_argument("trace", help="path to --trace-out JSONL file")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the trace; exit 1 on violation")
    ap.add_argument("--chrome", metavar="OUT.json",
                    help="also write Chrome trace-event JSON "
                         "(Perfetto / chrome://tracing)")
    args = ap.parse_args(argv)

    tel = _load_telemetry()
    lines = load_lines(args.trace)
    if args.validate:
        errs = validate(lines, tel)
        if errs:
            for e in errs:
                print(f"INVALID: {e}", file=sys.stderr)
            return 1
        print(f"validate: OK ({len(lines)} lines)")
    report(lines, tel)
    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(tel.chrome_trace(lines), f)
        n = len(tel.chrome_trace(lines)["traceEvents"])
        print(f"\nchrome trace: wrote {args.chrome} ({n} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
