"""ServeBackend protocol: engine/router conformance, drop-in
interchangeability, per-step confirmed-token events, Request
backward-compat, and the ServeOptions construction surface."""
import argparse
import warnings

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import (
    Request, RequestRouter, ServeBackend, ServeEngine, ServeOptions,
    StreamEvent, greedy_generate,
)


@pytest.fixture(scope="module")
def qwen3():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n=6, plen=20, gen=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, plen,
                                        dtype=np.int32),
                    max_new_tokens=gen) for i in range(n)]


def _oracle(model, params, reqs):
    out = {}
    for r in reqs:
        p = np.asarray(r.prompt)
        toks = greedy_generate(model, params, {"tokens": p[None]},
                               r.max_new_tokens,
                               cache_len=len(p) + r.max_new_tokens)
        out[r.rid] = [int(t) for t in np.asarray(toks)[0]]
    return out


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("n_pages", 48)
    kw.setdefault("page_size", 8)
    kw.setdefault("chunk_size", 16)
    return ServeEngine(model, params, **kw)


# ------------------------------------------------------------- protocol
def test_engine_and_router_satisfy_protocol(qwen3):
    _, model, params = qwen3
    eng = _engine(model, params)
    router = RequestRouter([_engine(model, params)])
    for backend in (eng, router):
        assert isinstance(backend, ServeBackend)


def test_request_backward_compat():
    """Pre-frontend construction sites (rid/prompt/max_new_tokens,
    optional arrival) must keep working, with neutral defaults for the
    new multi-tenant fields."""
    r = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                max_new_tokens=8)
    assert (r.tenant, r.slo_class, r.arrival) == ("default", "batch", 0.0)
    r2 = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=8, arrival=2.5)
    assert r2.arrival == 2.5 and r2.tenant == "default"


def test_engine_router_interchangeable(qwen3):
    """A single-replica router is a drop-in for the engine: identical
    token streams and the same core stats counters from run()."""
    cfg, model, params = qwen3
    want = _oracle(model, params, _requests(cfg))
    results = {}
    for name in ("engine", "router"):
        backend = (_engine(model, params) if name == "engine"
                   else RequestRouter([_engine(model, params)]))
        done = backend.run(_requests(cfg), realtime=False)
        results[name] = {r.rid: list(r.generated) for r in done}
        st = backend.stats()
        for key in ("n_decode_steps", "n_prefill_chunks",
                    "n_prefill_dispatches"):
            assert key in st, (name, key)
    assert results["engine"] == results["router"] == want


# --------------------------------------------------------------- events
@pytest.mark.parametrize("make", ["engine", "router"])
def test_stream_events_concatenate_to_generated(qwen3, make):
    """Driving submit/step/drain_events by hand, the concatenated
    per-rid event tokens reproduce Request.generated exactly and every
    stream ends with exactly one finished event."""
    cfg, model, params = qwen3
    reqs = _requests(cfg)
    backend = (_engine(model, params, spec_k=3) if make == "engine"
               else RequestRouter([_engine(model, params, spec_k=3)]))
    for r in reqs:
        backend.submit(r)
    got = {r.rid: [] for r in reqs}
    fins = {r.rid: 0 for r in reqs}
    while backend.step():
        for ev in backend.drain_events():
            assert isinstance(ev, StreamEvent)
            got[ev.rid].extend(ev.tokens)
            fins[ev.rid] += bool(ev.finished)
    for ev in backend.drain_events():
        got[ev.rid].extend(ev.tokens)
        fins[ev.rid] += bool(ev.finished)
    for r in reqs:
        assert got[r.rid] == list(r.generated), r.rid
        assert fins[r.rid] == 1, r.rid


def test_extract_resubmit_resumes_exactly(qwen3):
    """extract() mid-flight frees the slot; resubmitting the same
    Request resumes the stream token-exactly (replay machinery)."""
    cfg, model, params = qwen3
    reqs = _requests(cfg, n=3, gen=12)
    want = _oracle(model, params, reqs)
    eng = _engine(model, params)
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    victim = eng.extract(1)
    assert victim is reqs[1] and not victim.finished
    assert eng.extract(99) is None
    while eng.step():
        pass
    eng.submit(victim)
    while eng.step():
        pass
    eng.drain_events()
    assert {r.rid: list(r.generated) for r in reqs} == want


# ---------------------------------------------------------- ServeOptions
def test_serve_options_cli_round_trip():
    ap = argparse.ArgumentParser()
    ServeOptions.add_cli(ap)
    args = ap.parse_args(["--batch", "8", "--page-size", "4",
                          "--no-spec", "--bucket-edges", "2,4,8",
                          "--no-prefix-sharing", "--replicas", "3",
                          "--router-policy", "round-robin",
                          "--tenant-weights", "gold=3,free=1"])
    opts = ServeOptions.from_args(args)
    assert opts.batch == 8 and opts.page_size == 4
    assert opts.spec_k == 0 and not opts.prefix_sharing
    assert opts.bucket_edges == [2, 4, 8]
    assert opts.replicas == 3 and opts.router_policy == "round-robin"
    assert opts.tenant_weights == {"gold": 3.0, "free": 1.0}


def test_serve_options_sized_for_and_build(qwen3):
    cfg, model, params = qwen3
    reqs = _requests(cfg)
    opts = ServeOptions(batch=2, page_size=8, chunk_size=16)
    with pytest.raises(ValueError):
        opts.build(model, params)          # n_pages unresolved
    sized = opts.sized_for(reqs)
    assert sized.n_pages > 0 and sized.max_pages_per_seq is not None
    assert opts.n_pages == 0               # original untouched
    eng = sized.build(model, params)
    assert isinstance(eng, ServeEngine)
    fleet = ServeOptions(batch=2, page_size=8, chunk_size=16,
                         replicas=2).sized_for(reqs).build(model, params)
    assert isinstance(fleet, RequestRouter)
    assert len(fleet.replicas) == 2
    done = fleet.run(reqs, realtime=False)
    assert {r.rid: list(r.generated) for r in done} \
        == _oracle(model, params, _requests(cfg))


def test_run_engine_shim_deprecated(qwen3):
    cfg, model, params = qwen3
    from repro.launch.serve import run_engine
    reqs = _requests(cfg, n=3)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        stats = run_engine(model, params, reqs, batch=2, page_size=8,
                           n_pages=48, realtime=False, chunk_size=16)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert stats["tokens"] == sum(r.max_new_tokens for r in reqs)
