"""Telemetry conformance: span exactness under chaos, migration
attribution, the zero-cost-when-off guarantee, and the metrics
registry's audit + compatibility view.

The bar (ISSUE / docs/observability.md): with tracing ON, every
request the chaos fuzzer produces carries a well-formed span (exactly
one ``submitted``, exactly one terminal event, token-confirming events
summing to the stream length) and the registry reconciles with the
legacy ``stats()`` counters including the dispatch identity; with
tracing OFF, token streams and dispatch counts are bitwise identical
to the traced run and requests carry no span at all.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import (Request, RequestRouter, ServeEngine, Telemetry,
                         check_spans, merge_stats)
from repro.serve.frontend import ServeFrontend
from repro.serve.scheduler import _ENGINE_COUNTERS
from repro.serve.step import (ServePrograms, make_decode_step,
                              make_prefill_step)
from repro.serve.telemetry import MetricsRegistry, chrome_trace
from test_serve_fuzz import MAX_LEN, _case, _fresh, drive_and_check

REPO = Path(__file__).resolve().parent.parent
TRACE_REPORT = REPO / "scripts" / "trace_report.py"


@pytest.fixture(scope="module")
def bundle():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # ONE program bundle for the module (same compile-cache discipline
    # as the fuzz module: knobs vary, the model does not)
    return cfg, model, params, ServePrograms(model)


@pytest.fixture(scope="module")
def oracle(bundle):
    cfg, model, params, _ = bundle
    prefill = jax.jit(make_prefill_step(model, max_len=MAX_LEN))
    decode = jax.jit(make_decode_step(model))
    memo = {}

    def run(prompt: np.ndarray, gen: int) -> np.ndarray:
        key = (prompt.tobytes(), gen)
        if key not in memo:
            last, cache = prefill(params, {"tokens": prompt[None]})
            tok = np.argmax(np.asarray(last), -1).astype(np.int32)[:,
                                                                   None]
            out = [tok]
            tok = jax.numpy.asarray(tok)
            for _ in range(gen - 1):
                tok, cache = decode(params, cache, tok)
                out.append(np.asarray(tok))
            memo[key] = np.concatenate(out, axis=1)[0]
        return memo[key]
    return run


# ---------------------------------------------- spans under the fuzzer
@pytest.mark.parametrize("seed", range(6))
def test_traced_fuzz_spans_reconcile_with_stats(bundle, oracle, seed):
    """The chaos fuzzer with tracing on: full conformance bar PLUS the
    telemetry sweep (``check_spans`` inside ``drive_and_check``), then
    registry-vs-stats reconciliation on top."""
    cfg, model, params, programs = bundle
    reqs, knobs, cancels = _case(seed, cfg)
    tel = Telemetry(trace=True, metrics_interval=4)
    eng = ServeEngine(model, params, fused=True, programs=programs,
                      telemetry=tel, **knobs)
    drive_and_check(eng, _fresh(reqs), oracle=oracle, cancels=cancels,
                    telemetry=tel)
    st = eng.stats()
    # the registry subsumes stats(): every legacy counter is one
    # registry counter's value (single replica -> total == value)
    for name in _ENGINE_COUNTERS:
        assert tel.registry.total(name) == st[name], name
    assert not tel.registry.audit()
    # the step timeline covered every engine step, kinds from the
    # closed dispatch vocabulary
    engine_recs = [r for r in tel.records if r.get("component") ==
                   "engine"]
    assert len(engine_recs) == st["n_engine_steps"]
    for r in engine_recs:
        assert set(r["kind"].split("+")) <= \
            {"prefill", "decode", "replay", "fused", "idle"}, r
    # metrics_interval=4 embedded periodic snapshots
    if len(engine_recs) >= 4:
        assert any(r.get("type") == "metrics" for r in tel.records)
    # finished requests recorded TTFT histograms
    if eng.finished:
        snap = tel.registry.snapshot()
        assert any(k.startswith("ttft{") and k.endswith(".count")
                   for k in snap)


@pytest.mark.parametrize("seed", range(3))
def test_migration_spans_carry_src_and_dst(bundle, oracle, seed):
    """Elastic-churn arm with tracing on: every router migration shows
    up as exactly one ``migrated`` span event with src != dst (and
    ``check_spans`` pins that the next admission lands on dst)."""
    cfg, model, params, programs = bundle
    reqs, knobs, cancels = _case(seed, cfg)
    tel = Telemetry(trace=True)

    def mk():
        return ServeEngine(model, params, fused=True,
                           programs=programs, telemetry=tel, **knobs)

    router = RequestRouter([mk(), mk()], policy="prefix",
                           telemetry=tel)
    rng = np.random.default_rng(2000 + seed)
    events = {}
    for t in rng.choice(np.arange(1, 14),
                        size=int(rng.integers(2, 5)), replace=False):
        def churn(r, _rng=rng):
            live = [i for i in range(len(r.replicas))
                    if not r.is_draining(i)]
            grow = len(r.replicas) < 4 and (len(live) < 2
                                            or _rng.random() < 0.5)
            if grow:
                r.add_replica(mk())
            elif len(live) > 1:
                r.drain(int(_rng.choice(live)))
        events.setdefault(int(t), []).append(churn)
    trace = _fresh(reqs)
    drive_and_check(router, trace, oracle=oracle, cancels=cancels,
                    events=events, telemetry=tel)
    st = router.stats()
    migrated = [e for r in trace for e in r.trace
                if e.kind == "migrated"]
    assert len(migrated) == st["n_migrations"]
    for e in migrated:
        assert e.attrs["src"] != e.attrs["dst"], e
    # fleet-wide reconciliation across join/retire churn: summed
    # registry counters equal the aggregated (live + departed) stats
    for name in ("n_total_dispatches", "n_decode_steps",
                 "n_replay_steps", "n_engine_steps"):
        assert tel.registry.total(name) == st[name], name
    assert not tel.registry.audit()
    # the router timeline saw the churn
    kinds = {r["kind"] for r in tel.records
             if r.get("component") == "router"}
    assert "join" in kinds or "retire" in kinds or "route" in kinds


@pytest.mark.parametrize("seed", [2, 11])
def test_tracing_off_is_bitwise_free(bundle, seed):
    """The zero-cost-when-off contract: the untraced run produces
    bitwise-identical token streams, the exact same dispatch counters
    (zero extra dispatches), and no span events at all."""
    cfg, model, params, programs = bundle
    reqs, knobs, cancels = _case(seed, cfg)
    runs = {}
    for trace_on in (False, True):
        tel = Telemetry(trace=trace_on)
        eng = ServeEngine(model, params, fused=True, programs=programs,
                          telemetry=tel, **knobs)
        r = _fresh(reqs)
        done = drive_and_check(eng, r, cancels=cancels,
                               telemetry=tel if trace_on else None)
        runs[trace_on] = (done, eng.stats(), r)
    done_off, st_off, reqs_off = runs[False]
    done_on, st_on, _ = runs[True]
    assert set(done_off) == set(done_on)
    for rid in done_off:
        np.testing.assert_array_equal(done_off[rid], done_on[rid])
    assert st_off == st_on                 # incl. n_total_dispatches
    for r in reqs_off:
        assert r.trace == []               # off-arm: no spans anywhere


# ------------------------------------------------------------ frontend
def test_frontend_spans_slo_preemption_and_tenant_tokens(bundle):
    cfg, model, params, programs = bundle
    rng = np.random.default_rng(9)
    tel = Telemetry(trace=True)
    eng = ServeEngine(model, params, fused=True, programs=programs,
                      telemetry=tel, max_batch=2, page_size=8,
                      n_pages=30, max_pages_per_seq=8, chunk_size=8,
                      prefill_batch=2, spec_k=0)
    fe = ServeFrontend(eng)
    assert fe.tel is tel                   # inherited from the backend

    def prompt(n):
        return rng.integers(0, cfg.vocab_size, size=(n,)).astype(
            np.int32)

    bulk = [fe.submit(prompt(6), 6, tenant="free") for _ in range(2)]
    for _ in range(3):
        fe.pump()
    # slots are full of batch work -> the interactive arrival preempts
    vip = fe.submit(prompt(5), 4, tenant="gold",
                    slo_class="interactive")
    fe.drain()
    reqs = [s.req for s in bulk] + [vip.req]
    check_spans(reqs, backend=eng)
    preempts = [e for r in reqs for e in r.trace
                if e.kind == "preempted" and
                (e.attrs or {}).get("source") == "slo"]
    assert len(preempts) == fe.n_slo_preemptions >= 1
    want = {}
    for r in reqs:
        want[r.tenant] = want.get(r.tenant, 0) + len(r.generated)
    assert fe.tenant_tokens == want
    # the front-end's submitted event is the span opener even though
    # the engine re-submits underneath (dedup'd single 'submitted')
    for r in reqs:
        assert [e.kind for e in r.trace].count("submitted") == 1


# ----------------------------------------------------- registry + merge
def test_merge_stats_rederives_ratios():
    a = {"n_drafted": 8, "n_draft_accepted": 8, "accept_rate": 1.0,
         "n_prefill_chunks": 4, "n_prefill_dispatches": 2,
         "prefill_rows_mean": 2.0, "n_decode_steps": 5}
    b = {"n_drafted": 2, "n_draft_accepted": 0, "accept_rate": 0.0,
         "n_prefill_chunks": 1, "n_prefill_dispatches": 1,
         "prefill_rows_mean": 1.0, "n_decode_steps": 3}
    m = merge_stats([a, b])
    assert m["n_decode_steps"] == 8
    assert m["accept_rate"] == 0.8         # 8/10, not mean(1.0, 0.0)
    assert m["prefill_rows_mean"] == 5 / 3
    # empty and missing-denominator cases stay finite
    assert merge_stats([])["accept_rate"] == 0.0


def test_registry_audit_catches_identity_violation():
    reg = MetricsRegistry()
    lbl = dict(component="engine", replica="x0")
    reg.counter("n_prefill_dispatches", **lbl).inc(3)
    reg.counter("n_decode_steps", **lbl).inc(5)
    reg.counter("n_replay_steps", **lbl).inc(1)
    reg.counter("n_fused_dispatches", **lbl).inc(2)
    reg.counter("n_total_dispatches", **lbl).inc(7)   # 3+5+1-2
    assert reg.audit() == []
    reg.counter("n_total_dispatches", **lbl).inc()    # break it
    errs = reg.audit()
    assert errs and "n_total_dispatches" in errs[0]
    # a traced stack trips the self-audit on the next step record
    tel = Telemetry(trace=True, registry=reg)
    with pytest.raises(RuntimeError, match="self-audit"):
        tel.record("engine", t=0.0)


def test_registry_labels_types_and_snapshot():
    reg = MetricsRegistry()
    c = reg.counter("hits", tenant="a")
    assert reg.counter("hits", tenant="a") is c      # get-or-create
    c.inc(3)
    reg.counter("hits", tenant="b").inc(1)
    assert reg.total("hits") == 4
    reg.gauge("depth").set(2.5)
    h = reg.histogram("lat", slo="interactive")
    for v in (1.0, 9.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["hits{tenant=a}"] == 3
    assert snap["depth"] == 2.5
    assert snap["lat{slo=interactive}.count"] == 2
    assert snap["lat{slo=interactive}.p99"] == 9.0
    with pytest.raises(TypeError):
        reg.gauge("hits", tenant="a")      # name+labels type collision


# ----------------------------------------- export + trace_report CLI
def _tiny_trace(tmp_path) -> Path:
    """A hand-built two-request trace exercising every report table."""
    tel = Telemetry(trace=True)
    r0 = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=2, tenant="gold",
                 slo_class="interactive")
    tel.request_submitted(r0, t=0.0)
    tel.event(r0, "admitted", t=1.0, replica="e0", slot=0)
    tel.event(r0, "promoted", t=2.0, replica="e0", n=1)
    tel.event(r0, "decode_round", t=3.0, replica="e0", n=1,
              drafted=2, accepted=1)
    tel.event(r0, "finished", t=3.0, n_generated=2)
    r0.generated.extend([5, 7])
    r1 = Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                 max_new_tokens=4)
    tel.request_submitted(r1, t=0.0)
    tel.event(r1, "admitted", t=1.0, replica="e0", slot=1)
    tel.event(r1, "migrated", t=2.0, src="e0", dst="e1",
              n_generated=0)
    tel.event(r1, "admitted", t=2.0, replica="e1", slot=0)
    tel.event(r1, "cancelled", t=4.0)
    tel.record("engine", t=1.0, replica="e0", kind="prefill")
    tel.record("engine", t=3.0, replica="e0", kind="decode")
    p = tmp_path / "trace.jsonl"
    tel.write_jsonl(str(p))
    return p


def test_jsonl_and_chrome_export_shape(bundle, tmp_path):
    p = _tiny_trace(tmp_path)
    lines = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert lines[0]["type"] == "meta" and lines[0]["clock"] == "steps"
    spans = [ln for ln in lines if ln["type"] == "span"]
    assert [s["rid"] for s in spans] == [0, 1]
    assert spans[0]["tenant"] == "gold" and spans[0]["generated"] == 2
    assert lines[-1]["type"] == "metrics" and lines[-1]["final"]
    trace = chrome_trace(lines)
    phases = [e["ph"] for e in trace["traceEvents"]]
    assert phases.count("b") == 2 and phases.count("e") == 2
    assert phases.count("X") == 2          # one slice per step record
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "n"}
    assert {"submitted", "migrated", "finished"} <= names


def test_trace_report_cli(tmp_path):
    p = _tiny_trace(tmp_path)
    chrome = tmp_path / "trace.chrome.json"
    out = subprocess.run(
        [sys.executable, str(TRACE_REPORT), str(p), "--validate",
         "--chrome", str(chrome)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "validate: OK" in out.stdout
    assert "gold" in out.stdout and "interactive" in out.stdout
    assert "migrations (1)" in out.stdout
    assert "accept_rate=0.500" in out.stdout
    assert json.loads(chrome.read_text())["traceEvents"]
    # schema violations exit nonzero
    bad = tmp_path / "bad.jsonl"
    lines = p.read_text().splitlines()
    sp = json.loads(lines[1])
    sp["events"][0]["kind"] = "warped"     # not an EVENT_KIND
    bad.write_text("\n".join([lines[0], json.dumps(sp)] + lines[2:])
                   + "\n")
    out = subprocess.run(
        [sys.executable, str(TRACE_REPORT), str(bad), "--validate"],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "warped" in out.stderr
