"""Property tests for the fused-step masking math, as pure functions.

The fused uber-program (models/lm.fused_step_paged) is bitwise-equal to
the two dispatches it replaces because of three masking facts, each
tested here over randomized inputs:

* prefill rows never read past ``start + valid`` — keys beyond a row's
  causal frontier are exact no-ops for the online-softmax recurrence
  (poisoning them cannot change one output bit);
* decode/verify rows never read past ``lengths`` and never write live
  data from a padding lane — scatter targets past the table width or
  on invalid tokens land on the reserved null page;
* the scatter-target maps (components.chunk_scatter_targets /
  verify_scatter_targets) route exactly the valid (row, token) lanes
  to the pages the host allocated, slot = position % page_size.

Runs under real ``hypothesis`` (a test dependency, exercised by the CI
property-tests job) AND the dependency-free shim in
tests/_hypothesis_fallback.py (conftest.py installs it when the real
package is absent) — strategies here stay inside the shim's surface:
``integers`` / ``booleans`` / ``sampled_from`` and keyword bindings.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models.components import (chunk_scatter_targets,
                                     flash_attention,
                                     verify_scatter_targets)


# ------------------------------------------------- scatter-target maps
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), B=st.integers(1, 4),
       C=st.sampled_from([4, 8, 16]), ps=st.sampled_from([4, 8]),
       nb=st.integers(1, 6))
def test_chunk_scatter_pads_to_null_valid_to_table(seed, B, C, ps, nb):
    rng = np.random.default_rng(seed)
    table = rng.integers(1, 64, size=(B, nb)).astype(np.int32)
    # the scheduler invariant: every valid token's page index is inside
    # the row's table (start + valid <= nb * ps)
    n_valid = rng.integers(0, min(C, nb * ps) + 1,
                           size=(B,)).astype(np.int32)
    starts = np.array([rng.integers(0, nb * ps - v + 1) if v else 0
                       for v in n_valid], np.int32)
    pid, slot = chunk_scatter_targets(jnp.asarray(starts),
                                      jnp.asarray(n_valid),
                                      jnp.asarray(table), C, ps)
    pid, slot = np.asarray(pid), np.asarray(slot)
    for b in range(B):
        for t in range(C):
            if t >= n_valid[b]:
                assert pid[b, t] == 0, "padding lane must null-route"
            else:
                pos = starts[b] + t
                assert pid[b, t] == table[b, pos // ps]
                assert slot[b, t] == pos % ps


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), B=st.integers(1, 4),
       T=st.sampled_from([1, 3, 5]), ps=st.sampled_from([4, 8]),
       nb=st.integers(1, 6))
def test_verify_scatter_clamps_past_table_to_null(seed, B, T, ps, nb):
    rng = np.random.default_rng(seed)
    table = rng.integers(1, 64, size=(B, nb)).astype(np.int32)
    # lengths free to run the write window off the table's end — those
    # positions must hit the null page, NOT alias the last live page
    lengths = rng.integers(0, nb * ps + T, size=(B,)).astype(np.int32)
    pid, slot = verify_scatter_targets(jnp.asarray(lengths),
                                       jnp.asarray(table), T, ps)
    pid, slot = np.asarray(pid), np.asarray(slot)
    for b in range(B):
        for t in range(T):
            pos = lengths[b] + t
            if pos // ps < nb:
                assert pid[b, t] == table[b, pos // ps]
            else:
                assert pid[b, t] == 0, \
                    "past-table position must null-route"
            assert slot[b, t] == pos % ps


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), B=st.integers(1, 3),
       T=st.sampled_from([1, 4]), ps=st.sampled_from([4, 8]))
def test_masked_row_scatter_is_all_null(seed, B, T, ps):
    """An inactive row (all-zero table, zero length) — the fused
    program's padding rows — writes nowhere but the null page."""
    nb = 4
    rng = np.random.default_rng(seed)
    lengths = np.zeros((B,), np.int32)
    pid, _ = verify_scatter_targets(jnp.asarray(lengths),
                                    jnp.zeros((B, nb), jnp.int32), T, ps)
    assert not np.asarray(pid).any()
    starts = np.zeros((B,), np.int32)
    n_valid = rng.integers(0, 3, size=(B,)).astype(np.int32)
    pid, _ = chunk_scatter_targets(jnp.asarray(starts),
                                   jnp.asarray(n_valid),
                                   jnp.zeros((B, nb), jnp.int32), ps, ps)
    assert not np.asarray(pid).any()


# --------------------------------------------------- attention masking
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), B=st.integers(1, 3),
       Sq=st.sampled_from([4, 8]), extra=st.integers(0, 12),
       kv_chunk=st.sampled_from([4, 16]))
def test_prefill_rows_never_read_past_their_frontier(seed, B, Sq, extra,
                                                     kv_chunk):
    """Poison every key/value beyond each row's causal frontier
    (``q_offset[b] + Sq - 1``) — the fused/chunked prefill claim that
    fully-masked lanes are exact no-ops means not one output bit may
    change (max vs -1e30 cannot win, exp underflows to +0.0, and
    x + 0.0 == x bitwise)."""
    H = KVH = 2
    Dh = 4
    Skv = Sq + extra
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, Sq, H, Dh)).astype(np.float32)
    k = rng.standard_normal((B, Skv, KVH, Dh)).astype(np.float32)
    v = rng.standard_normal((B, Skv, KVH, Dh)).astype(np.float32)
    offsets = rng.integers(0, Skv - Sq + 1, size=(B,)).astype(np.int32)
    base = flash_attention(jnp.asarray(q), jnp.asarray(k),
                           jnp.asarray(v), causal=True,
                           kv_chunk=kv_chunk,
                           q_offset=jnp.asarray(offsets))
    kp, vp = k.copy(), v.copy()
    for b in range(B):
        kp[b, offsets[b] + Sq:] = 1e4 * (1 + rng.standard_normal(
            (Skv - offsets[b] - Sq, KVH, Dh))).astype(np.float32)
        vp[b, offsets[b] + Sq:] = -1e4
    got = flash_attention(jnp.asarray(q), jnp.asarray(kp),
                          jnp.asarray(vp), causal=True,
                          kv_chunk=kv_chunk,
                          q_offset=jnp.asarray(offsets))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), B=st.integers(1, 3),
       ps=st.sampled_from([4, 8]), nb=st.integers(1, 3))
def test_decode_rows_never_read_past_lengths_or_null_page(seed, B, ps,
                                                          nb):
    """Poison the null page and every page slot at positions >=
    ``lengths[b]`` in each row's own (disjoint) table — paged decode
    attention must not change by one bit (its valid mask ends at the
    row's length, so co-tenant writes routed to the null page or to
    positions past the frontier can never leak in)."""
    H = KVH = 2
    Dh = 4
    n_pages = 1 + B * nb
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, H, Dh)).astype(np.float32)
    k_pages = rng.standard_normal(
        (n_pages, ps, KVH, Dh)).astype(np.float32)
    v_pages = rng.standard_normal(
        (n_pages, ps, KVH, Dh)).astype(np.float32)
    # disjoint tables: row b owns pages [1 + b*nb, 1 + (b+1)*nb)
    table = (1 + np.arange(B * nb, dtype=np.int32).reshape(B, nb))
    lengths = rng.integers(1, nb * ps + 1, size=(B,)).astype(np.int32)
    base = paged_attention_ref(jnp.asarray(q), jnp.asarray(k_pages),
                               jnp.asarray(v_pages), jnp.asarray(table),
                               jnp.asarray(lengths))
    kp, vp = k_pages.copy(), v_pages.copy()
    kp[0], vp[0] = 1e4, -1e4                    # the null page
    for b in range(B):
        for pos in range(lengths[b], nb * ps):
            kp[table[b, pos // ps], pos % ps] = 1e4
            vp[table[b, pos // ps], pos % ps] = -1e4
    got = paged_attention_ref(jnp.asarray(q), jnp.asarray(kp),
                              jnp.asarray(vp), jnp.asarray(table),
                              jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))
