"""CNN reuse-scheme generators vs paper Table 6 + functional correctness."""
import numpy as np
import pytest

from repro.core import dataflows as df
from repro.core.dataflows import (ALEXNET_CONV2, PAPER_TABLE6, ConvSpec,
                                  Reuse, build_conv_program, conv_reference,
                                  panel_items, read_psums, seed_dram)
from repro.core.interpreter import MachineState, run_graph

SCHEMES = list(Reuse)


# --------------------------------------------------------- static counts
@pytest.mark.parametrize("scheme", [Reuse.NO_REUSE, Reuse.FILTER_REUSE,
                                    Reuse.IFMAP_REUSE])
def test_table6_exact_counts(scheme):
    """No/Filter/Ifmap Reuse reproduce Table 6 instruction + OPM counts
    for AlexNet_CONV2 exactly."""
    g = build_conv_program(ALEXNET_CONV2, scheme)
    got = g.totals()
    want = PAPER_TABLE6[scheme]
    for key in ("ld", "cal", "copy", "st", "opm_entries"):
        assert got[key] == want[key], (scheme, key, got[key], want[key])


def test_table6_cal_st_equal_across_all_schemes():
    """All five implementations have identical CAL and ST counts (Table 6)."""
    for scheme in SCHEMES:
        got = build_conv_program(ALEXNET_CONV2, scheme).totals()
        assert got["cal"] == 6400, scheme
        assert got["st"] == 256, scheme


def test_table6_ld_ordering():
    """LD traffic ordering: All < Conv < Filter = Ifmap < NoReuse."""
    ld = {s: build_conv_program(ALEXNET_CONV2, s).totals()["ld"]
          for s in SCHEMES}
    assert ld[Reuse.ALL_REUSE] < ld[Reuse.CONV_REUSE] \
        < ld[Reuse.FILTER_REUSE] == ld[Reuse.IFMAP_REUSE] \
        < ld[Reuse.NO_REUSE]


def test_table6_copy_ordering():
    """COPY: NoReuse has none; Conv-Reuse uses the most (Table 6)."""
    cp = {s: build_conv_program(ALEXNET_CONV2, s).totals()["copy"]
          for s in SCHEMES}
    assert cp[Reuse.NO_REUSE] == 0
    assert cp[Reuse.CONV_REUSE] == max(cp.values())
    assert cp[Reuse.ALL_REUSE] > cp[Reuse.FILTER_REUSE]


def test_opm_footprint_reduction():
    """Reuse schemes reduce Operand-RAM pressure (Table 6: 13056 -> 8256)."""
    t = {s: build_conv_program(ALEXNET_CONV2, s).totals() for s in SCHEMES}
    assert t[Reuse.FILTER_REUSE]["opm_entries"] == 8256
    assert t[Reuse.IFMAP_REUSE]["opm_entries"] == 8256
    assert t[Reuse.ALL_REUSE]["opm_entries"] \
        < t[Reuse.NO_REUSE]["opm_entries"]


def test_successor_fanout_respects_hardware_limit():
    for scheme in SCHEMES:
        g = build_conv_program(ALEXNET_CONV2, scheme)
        for _, b in g.all_blocks():
            assert len(b.successors) <= 3


# ------------------------------------------------------ functional checks
SMALL = ConvSpec("small", in_ch=2, out_ch=16, kh=3, kw=3, ih=8, iw=8)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_small_conv_matches_numpy(scheme):
    """Every scheme computes the same partial sums as the numpy oracle."""
    rng = np.random.default_rng(7)
    w = rng.normal(size=(SMALL.out_ch, SMALL.in_ch, 3, 3)).astype(np.float32)
    x = rng.normal(size=(SMALL.in_ch, SMALL.ih, SMALL.iw,
                         SMALL.batch)).astype(np.float32)
    p0 = rng.normal(size=(SMALL.out_ch, SMALL.oh * SMALL.ow,
                          SMALL.batch)).astype(np.float32)

    n_items = 16  # 4x4 grid on 8 PEs
    g = build_conv_program(SMALL, scheme, n_pes=8, items_per_block=2,
                           channel=1, n_items=n_items)
    state = MachineState(n_pes=8, opm_entries=4096)
    seed_dram(state, SMALL, w, x, p0)
    run_graph(g, state)

    items = panel_items(SMALL, scheme, n_items=n_items)
    want = conv_reference(SMALL, w, x, channel=1, items=items, psums0=p0)
    got = read_psums(state, SMALL, items)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_translated_program_equivalent(scheme):
    """translate() preserves semantics: physical program == logical program."""
    from repro.core.translator import TranslatorConfig, translate
    rng = np.random.default_rng(3)
    w = rng.normal(size=(SMALL.out_ch, SMALL.in_ch, 3, 3)).astype(np.float32)
    x = rng.normal(size=(SMALL.in_ch, SMALL.ih, SMALL.iw,
                         SMALL.batch)).astype(np.float32)
    n_items = 16
    g = build_conv_program(SMALL, scheme, n_pes=8, items_per_block=2,
                           channel=0, n_items=n_items)
    phys, report = translate(g, TranslatorConfig(n_pes=8))
    state = MachineState(n_pes=8, opm_entries=4096)
    seed_dram(state, SMALL, w, x)
    run_graph(phys, state)

    items = panel_items(SMALL, scheme, n_items=n_items)
    want = conv_reference(SMALL, w, x, channel=0, items=items)
    got = read_psums(state, SMALL, items)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    assert report.max_opm_entries <= 2048
