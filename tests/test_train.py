"""Optimizer + loss machinery."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.train.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, compress_int8,
                                   decompress_int8, init_opt_state)
from repro.train.step import (chunked_cross_entropy, cross_entropy,
                              make_loss_fn, make_train_step,
                              auto_microbatches)


def test_adamw_matches_reference_math():
    cfg = OptConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                    grad_clip=1e9, warmup_steps=1)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    s = init_opt_state(p, cfg)
    p1, s1, _ = adamw_update(p, g, s, cfg)
    # bias-corrected first step == SGD with lr on sign-ish update
    mu_hat = 0.5
    nu_hat = 0.25
    want = 1.0 - 1e-2 * mu_hat / (np.sqrt(nu_hat) + 1e-8)
    np.testing.assert_allclose(float(p1["w"][0]), want, rtol=1e-5)
    assert int(s1["step"]) == 1


def test_weight_decay_skips_vectors():
    cfg = OptConfig(weight_decay=0.1, grad_clip=1e9, warmup_steps=1)
    p = {"m": jnp.ones((2, 2)), "v": jnp.ones((2,))}
    g = jax.tree.map(jnp.zeros_like, p)
    s = init_opt_state(p, cfg)
    p1, _, _ = adamw_update(p, g, s, cfg)
    assert float(p1["m"][0, 0]) < 1.0       # decayed
    assert float(p1["v"][0]) == 1.0         # not decayed


def test_global_norm_clip():
    g = {"a": jnp.full((4,), 3.0)}          # norm 6
    clipped, gn = clip_by_global_norm(g, 3.0)
    np.testing.assert_allclose(float(gn), 6.0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(clipped["a"]), 1.5, rtol=1e-5)


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_int8_compression_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, scale = compress_int8(g, jax.random.PRNGKey(seed))
    deq = decompress_int8(q, scale)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 1.01


def test_error_feedback_preserves_signal():
    """With error feedback, repeated tiny gradients are not lost."""
    cfg = OptConfig(lr=1e-2, compress_grads=True, grad_clip=1e9,
                    warmup_steps=1)
    p = {"w": jnp.zeros((64,))}
    # gradient much smaller than the quantization step of its own max
    g = {"w": jnp.full((64,), 1e-3).at[0].set(1.0)}
    s = init_opt_state(p, cfg)
    for i in range(10):
        p, s, _ = adamw_update(p, g, s, cfg,
                               compress_key=jax.random.PRNGKey(i))
    # the small components moved too (error feedback accumulated them)
    assert float(jnp.abs(p["w"][5])) > 0


def test_auto_microbatches_divisibility():
    cfg = configs.get("qwen1.5-110b")
    n = auto_microbatches(cfg, 256, 4096, dp=16)
    assert 256 % n == 0 and (256 // n) % 16 == 0
    small = configs.get("qwen3-0.6b")
    assert auto_microbatches(small, 256, 4096, dp=16) <= n


def test_chunked_ce_equals_plain():
    cfg = dataclasses.replace(configs.get_smoke("qwen3-0.6b"),
                              loss_chunk=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = SyntheticPipeline(cfg, batch=2, seq=24).device_batch(0)
    hidden, _ = model.apply(params, batch, train=True, want_hidden=True)
    got = chunked_cross_entropy(hidden, params["embed"], batch["labels"],
                                cfg, 8)
    logits, _ = model.apply(params, batch, train=True)
    want = cross_entropy(logits, batch["labels"])
    np.testing.assert_allclose(float(got), float(want), rtol=2e-4)


def test_microbatched_grads_match_full_batch():
    cfg = configs.get_smoke("stablelm-1.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = SyntheticPipeline(cfg, batch=4, seq=16).device_batch(0)
    s1 = jax.jit(make_train_step(model, cfg, n_micro=1))
    s4 = jax.jit(make_train_step(model, cfg, n_micro=4))
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p4, _, m4 = s4(params, init_opt_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-5)
