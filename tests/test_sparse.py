"""Sparse-NN pipeline: pruning -> sparse vectors -> Sparse PC Inc
(paper Figs 18/19)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dataflows import ConvSpec, Reuse, build_conv_program, \
    conv_reference, panel_items, read_psums, seed_dram
from repro.core.interpreter import MachineState, run_graph
from repro.core.machine import MachineConfig, simulate
from repro.core.sparse import (apply_pruning, conv_sparse_vectors,
                               prune_weights, random_sparse_vectors)

SMALL = ConvSpec("small", in_ch=2, out_ch=16, kh=3, kw=3, ih=8, iw=8)


@pytest.mark.parametrize("scheme", [Reuse.NO_REUSE, Reuse.FILTER_REUSE,
                                    Reuse.IFMAP_REUSE])
def test_sparse_program_equals_dense_with_zeroed_weights(scheme):
    """The paper's core sparse claim, machine-checked: a program whose
    Sparse PC Inc skips pruned-weight MACs computes exactly what the
    dense program computes on zeroed weights."""
    rng = np.random.default_rng(11)
    w = rng.normal(size=(SMALL.out_ch, SMALL.in_ch, 3, 3)).astype(np.float32)
    x = rng.normal(size=(SMALL.in_ch, SMALL.ih, SMALL.iw,
                         SMALL.batch)).astype(np.float32)
    n_items = 16
    pruned = {(o, k) for o in range(SMALL.out_ch) for k in range(SMALL.k)
              if rng.random() < 0.6}
    g = build_conv_program(SMALL, scheme, n_pes=8, items_per_block=2,
                           channel=0, n_items=n_items)
    vecs = conv_sparse_vectors(g, SMALL, scheme, pruned,
                               items_per_block=2, n_items=n_items)
    gs = apply_pruning(g, vecs)

    state = MachineState(n_pes=8, opm_entries=4096)
    seed_dram(state, SMALL, w, x)
    run_graph(gs, state)

    wz = w.copy()
    for (o, k) in pruned:
        dy, dx = divmod(k, SMALL.kw)
        wz[o, 0, dy, dx] = 0.0
    items = panel_items(SMALL, scheme, n_items=n_items)
    want = conv_reference(SMALL, wz, x, channel=0, items=items)
    got = read_psums(state, SMALL, items)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sparse_reduces_cycles_and_energy():
    g = build_conv_program(SMALL, Reuse.ALL_REUSE, n_pes=8,
                           items_per_block=2, n_items=16)
    rng = np.random.default_rng(0)
    gs = apply_pruning(g, random_sparse_vectors(g, 0.35, rng))
    cfg = MachineConfig(n_pes=8)
    rd, rs = simulate(g, cfg), simulate(gs, cfg)
    assert rs.cycles < rd.cycles
    assert rs.energy_pj < rd.energy_pj
    assert rs.executed_cal_instrs < rd.executed_cal_instrs


@given(keep=st.floats(0.05, 1.0), seed=st.integers(0, 2 ** 16))
@settings(max_examples=25, deadline=None)
def test_random_vectors_never_invalidate_first_pc(keep, seed):
    g = build_conv_program(SMALL, Reuse.NO_REUSE, n_pes=8,
                           items_per_block=2, n_items=16)
    vecs = random_sparse_vectors(g, keep, np.random.default_rng(seed))
    for _t, b in g.all_blocks():
        if b.name in vecs:
            v = vecs[b.name]
            assert len(v) == len(b.instrs)
            assert v[0]


@given(keep=st.floats(0.1, 0.9))
@settings(max_examples=10, deadline=None)
def test_executed_pcs_subset_of_valid(keep):
    g = build_conv_program(SMALL, Reuse.FILTER_REUSE, n_pes=8,
                           items_per_block=2, n_items=16)
    vecs = random_sparse_vectors(g, keep, np.random.default_rng(1))
    gs = apply_pruning(g, vecs)
    for _t, b in gs.all_blocks():
        if b.name not in vecs:
            continue
        valid = vecs[b.name]
        for pc in b.executed_pcs():
            assert valid[pc], (b.name, pc)


def test_prune_weights_keeps_fraction():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(64, 64)).astype(np.float32)
    wp = prune_weights(w, 0.25, rng)
    frac = np.count_nonzero(wp) / w.size
    assert abs(frac - 0.25) < 0.02
    # surviving weights are the largest-magnitude ones
    assert np.abs(wp[wp != 0]).min() >= np.abs(w[wp == 0]).max() - 1e-6
