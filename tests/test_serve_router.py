"""Multi-replica request router: policy semantics, backpressure, and
token parity.  Replicas are in-process engines (one device), so every
routing decision here is deterministic."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import (Request, RequestRouter, ServeEngine,
                         ServePrograms, greedy_generate)

PAGE = 8


@pytest.fixture(scope="module")
def qwen3():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def programs(qwen3):
    _, model, _ = qwen3
    return ServePrograms(model)


def make_replicas(model, params, programs, n, **kw):
    kw = dict(max_batch=2, n_pages=32, page_size=PAGE,
              max_pages_per_seq=8, chunk_size=16, programs=programs, **kw)
    return [ServeEngine(model, params, **kw) for _ in range(n)]


def grouped_trace(cfg, n_groups, per_group, *, prefix_len=24,
                  tail_len=6, gen=6, seed=5):
    """Round-robin interleaved requests from ``n_groups`` shared-prefix
    groups: g0, g1, ..., g0, g1, ... — rid % n_groups is the group."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size,
                             size=(prefix_len,)).astype(np.int32)
                for _ in range(n_groups)]
    reqs = []
    for i in range(n_groups * per_group):
        tail = rng.integers(0, cfg.vocab_size,
                            size=(tail_len,)).astype(np.int32)
        reqs.append(Request(
            rid=i, prompt=np.concatenate([prefixes[i % n_groups], tail]),
            max_new_tokens=gen))
    return reqs


# ------------------------------------------------------------- parity
def test_router_token_parity_and_affinity_partitioning(qwen3, programs):
    """Routed streams match the sequential oracle bit for bit, every
    request finishes exactly once, and prefix affinity pins each
    prompt group to exactly one replica."""
    cfg, model, params = qwen3
    reqs = grouped_trace(cfg, n_groups=2, per_group=4)
    gen = 6
    oracle = {
        r.rid: np.asarray(greedy_generate(
            model, params, {"tokens": r.prompt[None]}, gen,
            cache_len=len(r.prompt) + gen))[0]
        for r in reqs}
    router = RequestRouter(
        make_replicas(model, params, programs, 2), policy="prefix")
    done = router.run(reqs)
    assert sorted(r.rid for r in done) == list(range(len(reqs)))
    for r in done:
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), oracle[r.rid],
            err_msg=f"request {r.rid} diverged")
    group_homes = {}
    for i, eng in enumerate(router.replicas):
        for r in eng.finished:
            group_homes.setdefault(r.rid % 2, set()).add(i)
        eng.cache.check_invariants()
    assert all(len(homes) == 1 for homes in group_homes.values()), \
        group_homes
    assert router.n_affinity_hits >= len(reqs) - 2


def test_prefix_affinity_beats_round_robin(qwen3, programs):
    """On an interleaved shared-prefix trace, affinity routing reuses
    strictly more prefix KV (and ingests strictly fewer prompt chunks)
    than round-robin, which scatters each group across replicas."""
    cfg, model, params = qwen3

    # 3 groups over 2 replicas: round-robin (i % 2) is misaligned with
    # the group pattern (i % 3), so it scatters every group across
    # both replicas; with 2 groups it would accidentally route
    # perfectly
    def serve(policy):
        reps = make_replicas(model, params, programs, 2)
        router = RequestRouter(reps, policy=policy)
        router.run(grouped_trace(cfg, n_groups=3, per_group=4))
        shared = sum(e.cache.n_shared_tokens for e in reps)
        chunks = sum(e.n_prefill_chunks for e in reps)
        return shared, chunks

    aff_shared, aff_chunks = serve("prefix")
    rr_shared, rr_chunks = serve("round-robin")
    # round-robin alternates groups across replicas, so every replica
    # still ends up holding every prefix — but only after paying the
    # cold ingestion once per (group, replica) pair instead of once
    # per group
    assert aff_shared > rr_shared, (aff_shared, rr_shared)
    assert aff_chunks < rr_chunks, (aff_chunks, rr_chunks)


def test_backpressure_holds_but_never_drops(qwen3, programs):
    """With a 1-request in-flight cap per replica, dispatch stalls
    (queue holds) but every request still completes exactly once and
    the cap is never exceeded."""
    cfg, model, params = qwen3
    reqs = grouped_trace(cfg, n_groups=2, per_group=4)
    router = RequestRouter(
        make_replicas(model, params, programs, 2), policy="prefix",
        max_inflight=1)
    for r in reqs:
        router.submit(r)
    held = False
    while router.step():
        held |= bool(router.queue)
        for eng in router.replicas:
            assert eng.n_inflight <= 1
    assert held, "cap was meant to stall dispatch at least once"
    done = sorted(r.rid for e in router.replicas for r in e.finished)
    assert done == list(range(len(reqs)))


def test_least_loaded_balances_outstanding_tokens(qwen3, programs):
    """A burst of equal requests splits evenly under least-loaded (and
    round-robin by construction)."""
    cfg, model, params = qwen3
    for policy in ("least-loaded", "round-robin"):
        router = RequestRouter(
            make_replicas(model, params, programs, 2), policy=policy)
        router.run(grouped_trace(cfg, n_groups=4, per_group=2, seed=9))
        assert router.n_dispatched == [4, 4], (policy,
                                               router.n_dispatched)


def test_heterogeneous_fleet_routes_around_small_replica(qwen3,
                                                         programs):
    """A request only the big replica can admit must route there (never
    crash dispatch on the small one); one no replica can admit is
    rejected at submit."""
    cfg, model, params = qwen3
    big = ServeEngine(model, params, max_batch=2, n_pages=32,
                      page_size=PAGE, max_pages_per_seq=10,
                      chunk_size=16, programs=programs)
    small = ServeEngine(model, params, max_batch=2, n_pages=6,
                        page_size=PAGE, max_pages_per_seq=4,
                        chunk_size=16, programs=programs)
    router = RequestRouter([small, big], policy="least-loaded")
    rng = np.random.default_rng(2)
    # needs 7+ pages: beyond small's budget, fine for big
    tall = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(40,)).astype(np.int32),
                    max_new_tokens=12) for i in range(3)]
    done = router.run(tall)
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert router.n_dispatched == [0, 3]
    with pytest.raises(ValueError, match="page budget"):
        router.submit(Request(rid=9, prompt=np.arange(8, dtype=np.int32),
                              max_new_tokens=10_000))


def test_router_rejects_bad_config_and_requests(qwen3, programs):
    cfg, model, params = qwen3
    reps = make_replicas(model, params, programs, 1)
    with pytest.raises(ValueError, match="policy"):
        RequestRouter(reps, policy="fastest")
    with pytest.raises(ValueError):
        RequestRouter([])
    router = RequestRouter(reps)
    with pytest.raises(ValueError, match="page budget"):
        router.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                              max_new_tokens=10_000))
    assert router.n_inflight == 0
