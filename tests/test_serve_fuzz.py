"""Serve-conformance chaos fuzzer: seeded random mixed traces — ragged
arrivals, cancels, page-pressure preemptions, speculation on/off, knobs
(``prefill_batch`` / ``chunk_size`` / ``spec_k`` / pool size) drawn per
case — driven through fused and unfused engines with allocator
invariants checked after EVERY step, and every finished stream asserted
bitwise against the sequential greedy oracle (cancelled streams must be
an oracle prefix: confirmed tokens never un-confirm).

``drive_and_check`` is the reusable conformance harness: any test file
(or future PR) can drive a backend through a trace and inherit the full
invariant + parity bar.  A tp=2 arm reruns a subset of cases sharded
(skipped below 2 devices; CI's multidevice job forces host devices);
an elastic-churn arm reruns cases on a router whose fleet is grown and
drained mid-trace (live requests migrating between replicas).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import Request, ServeEngine
from repro.serve.step import (ServePrograms, make_decode_step,
                              make_prefill_step)

MAX_LEN = 48          # oracle cache capacity: covers every drawn case
N_CASES = 20
POOLS = [22, 30]      # pages; the small pool forces preemption/replay
CHUNKS = [8, 16]
PREFILL_BATCHES = [1, 3]
SPEC_KS = [0, 3]
PROMPT_LENS = [5, 9, 12, 16, 21, 27]


@pytest.fixture(scope="module")
def bundle():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # ONE program bundle for every fuzz engine: the cases vary knobs,
    # not the model, so all arms share one jit compile cache — that is
    # what keeps 20+ cases inside the tier-1 time budget
    programs = ServePrograms(model)
    return cfg, model, params, programs


@pytest.fixture(scope="module")
def oracle(bundle):
    """Sequential greedy oracle with module-cached jits (one prefill
    wrapper retracing per prompt length, one decode wrapper) and
    memoized streams — semantically ``greedy_generate`` per request."""
    cfg, model, params, _ = bundle
    prefill = jax.jit(make_prefill_step(model, max_len=MAX_LEN))
    decode = jax.jit(make_decode_step(model))
    memo = {}

    def run(prompt: np.ndarray, gen: int) -> np.ndarray:
        key = (prompt.tobytes(), gen)
        if key not in memo:
            last, cache = prefill(params, {"tokens": prompt[None]})
            tok = np.argmax(np.asarray(last), -1).astype(np.int32)[:,
                                                                   None]
            out = [tok]
            tok = jax.numpy.asarray(tok)
            for _ in range(gen - 1):
                tok, cache = decode(params, cache, tok)
                out.append(np.asarray(tok))
            memo[key] = np.concatenate(out, axis=1)[0]
        return memo[key]
    return run


# ---------------------------------------------------------- the harness
def drive_and_check(engine, trace, *, oracle=None, cancels=None,
                    events=None, max_steps=2000, telemetry=None):
    """Drive ``engine`` through ``trace`` step by step and enforce the
    serve-conformance bar.  Returns {rid: np.ndarray(generated)}.

    ``telemetry``: the stack's ``Telemetry`` (tracing on) — adds the
    trace-exactness sweep to the bar: every request's span is
    well-formed (``telemetry.check_spans``), span token/replay counts
    reconcile with the request state and ``stats()`` counters, and the
    metrics-registry dispatch-identity audit is clean.

    * ``engine`` is any ``ServeBackend`` — a single engine, a router,
      or an elastic controller (anything with a ``replicas`` list gets
      every live replica's allocator checked);
    * ``trace``: Requests with integer ``arrival`` times; all are
      submitted upfront and admission follows the synthetic clock
      (``step(now=t)`` with t = 0, 1, 2, ...), so arrival raggedness
      is deterministic — no wall clock anywhere.
    * allocator invariants (``cache.check_invariants``: refcounts,
      free list, null page) are asserted after EVERY step;
    * ``cancels``: {step t: [rid, ...]} applied before that step;
    * ``events``: {step t: [fn, ...]} — arbitrary chaos callbacks
      (e.g. elastic scale-up/drain) applied to the backend before that
      step, before the step's cancels;
    * ``oracle``: rid -> expected stream.  Finished requests must match
      bitwise; cancelled requests must be a strict prefix (tokens
      already streamed were confirmed and can never change).
    """
    cancels = cancels or {}
    events = events or {}
    for r in trace:
        engine.submit(r)
    cancelled = set()
    t = 0
    while True:
        for fn in events.get(t, ()):
            fn(engine)
        for rid in cancels.get(t, ()):
            if engine.cancel(rid):
                cancelled.add(rid)
        more = engine.step(now=float(t))
        for cache in ([e.cache for e in engine.replicas]
                      if hasattr(engine, "replicas")
                      else [engine.cache]):
            cache.check_invariants()
        t += 1
        assert t < max_steps, "engine failed to drain the trace"
        if not more and t > max((r.arrival for r in trace), default=0):
            break
    done = {r.rid: np.asarray(r.generated, np.int32)
            for r in engine.finished}
    if telemetry is not None:
        from repro.serve.telemetry import check_spans
        check_spans(trace, cancelled=cancelled, backend=engine)
    if oracle is not None:
        for r in trace:
            want = oracle(r.prompt, r.max_new_tokens)
            if r.rid in done:
                np.testing.assert_array_equal(
                    done[r.rid], want[:len(done[r.rid])],
                    err_msg=f"rid {r.rid} diverged from oracle")
                assert len(done[r.rid]) == r.max_new_tokens
            elif r.rid in cancelled:
                got = np.asarray(r.generated, np.int32)
                np.testing.assert_array_equal(
                    got, want[:len(got)],
                    err_msg=f"cancelled rid {r.rid} not oracle prefix")
            else:
                raise AssertionError(f"rid {r.rid} neither finished "
                                     "nor cancelled")
    return done


def _case(seed: int, cfg):
    """One seeded chaos case: trace + engine knobs + cancel schedule."""
    rng = np.random.default_rng(1000 + seed)
    n = int(rng.integers(3, 7))
    reqs = []
    for i in range(n):
        L = int(rng.choice(PROMPT_LENS))
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(L,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(2, 9)),
                            arrival=float(rng.integers(0, 6))))
    knobs = dict(max_batch=4, page_size=8, max_pages_per_seq=8,
                 n_pages=int(rng.choice(POOLS)),
                 chunk_size=int(rng.choice(CHUNKS)),
                 prefill_batch=int(rng.choice(PREFILL_BATCHES)),
                 spec_k=int(rng.choice(SPEC_KS)),
                 prefix_sharing=bool(rng.integers(0, 2)))
    cancels = {}
    if rng.random() < 0.4:
        cancels[int(rng.integers(1, 12))] = \
            [int(rng.integers(0, n))]
    return reqs, knobs, cancels


def _fresh(reqs):
    # reset BOTH engine-filled lists: dataclasses.replace copies field
    # references, so reusing a trace list would alias spans across arms
    return [dataclasses.replace(r, generated=[], trace=[])
            for r in reqs]


@pytest.mark.parametrize("seed", range(N_CASES))
def test_fuzz_fused_and_unfused_match_oracle(bundle, oracle, seed):
    cfg, model, params, programs = bundle
    reqs, knobs, cancels = _case(seed, cfg)
    streams = {}
    for fused in (True, False):
        eng = ServeEngine(model, params, fused=fused,
                          programs=programs, **knobs)
        streams[fused] = drive_and_check(eng, _fresh(reqs),
                                         oracle=oracle,
                                         cancels=cancels)
    # requests that finished in both arms streamed identical tokens.
    # (A cancel can land while a request is still inflight in one arm
    # but after it finished in the other — fused promotion joins decode
    # one step later, so step counts legitimately shift — which is why
    # this is an intersection, not an equality, of finished sets; each
    # arm was already held to the oracle individually above.)
    for rid in streams[True].keys() & streams[False].keys():
        np.testing.assert_array_equal(streams[True][rid],
                                      streams[False][rid])


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="tp=2 arm needs 2 devices (CI forces host "
                           "devices; locally: XLA_FLAGS=--xla_force_"
                           "host_platform_device_count=2)")
@pytest.mark.parametrize("seed", range(3))
def test_fuzz_tp2_matches_oracle(bundle, oracle, seed):
    from repro.serve.parallel import TPServePrograms
    cfg, model, params, _ = bundle
    tp_programs = TPServePrograms(model, tp=2)
    reqs, knobs, cancels = _case(seed, cfg)
    eng = ServeEngine(model, params, fused=True, programs=tp_programs,
                      **knobs)
    drive_and_check(eng, _fresh(reqs), oracle=oracle, cancels=cancels)


@pytest.mark.parametrize("seed", range(6))
def test_fuzz_fault_recovery_matches_oracle(bundle, oracle, seed):
    """The fault arm: the same chaos traces, served by a fleet where
    one replica carries a seeded scripted fault (crash or stall, drawn
    by ``FaultInjector.seeded``) and a seeded kill-switch event hard-
    fails a live replica mid-trace (``RequestRouter.fail`` — the
    external-health-checker analog, so every case sees >=1 failure
    even if the scripted fault lands on an idle replica).  Lost
    requests are rebuilt from the recovery journal and replayed on
    survivors, and the bar is the FULL conformance bar — allocator
    invariants every step, bitwise oracle parity, exact cancels,
    span-trace exactness (telemetry sweep), and the fleet dispatch
    identity after the crash-folds."""
    from repro.serve import FaultInjector, RequestRouter
    from repro.serve.telemetry import Telemetry
    cfg, model, params, programs = bundle
    reqs, knobs, cancels = _case(seed, cfg)
    tel = Telemetry(trace=True)

    def mk():
        return ServeEngine(model, params, fused=True,
                           programs=programs, telemetry=tel, **knobs)

    faulty = FaultInjector.seeded(mk(), seed, horizon=10)
    router = RequestRouter([faulty, mk(), mk()], policy="prefix",
                           stall_patience=3, telemetry=tel)
    rng = np.random.default_rng(3000 + seed)

    def kill(r, _rng=rng):
        live = [i for i in range(len(r.replicas))
                if not r.is_draining(i)]
        if len(live) > 1:
            r.fail(live[int(_rng.integers(0, len(live)))])
    # early enough that the trace is still live on every seed (the
    # loop always reaches t=3 while work remains): the kill is
    # guaranteed, the scripted fault is extra chaos on top
    events = {int(rng.integers(1, 4)): [kill]}
    drive_and_check(router, _fresh(reqs), oracle=oracle,
                    cancels=cancels, events=events, telemetry=tel)
    assert router.n_failures >= 1
    assert len(router._journal) == 0      # every stream reached an end
    st = router.stats()
    assert st["n_total_dispatches"] == (
        st["n_prefill_dispatches"] + st["n_decode_steps"]
        + st["n_replay_steps"] - st["n_fused_dispatches"])
    assert st["n_replay_steps"] >= router.n_recovery_replayed_tokens


@pytest.mark.parametrize("seed", range(6))
def test_fuzz_elastic_churn_matches_oracle(bundle, oracle, seed):
    """The elastic-churn arm: the same chaos traces, served by a
    router whose fleet is mutated MID-TRACE by seeded scale-up and
    graceful-drain events (the primitives the elastic controller
    composes).  Drains migrate live requests — extracted at their
    confirmed-token frontier and re-admitted on a surviving replica —
    so the bar is the full conformance bar: allocator invariants on
    every live replica every step, every finished stream bitwise vs
    the oracle, cancels (including ones racing a drain) exact."""
    from repro.serve import RequestRouter
    cfg, model, params, programs = bundle
    reqs, knobs, cancels = _case(seed, cfg)

    def mk():
        return ServeEngine(model, params, fused=True,
                           programs=programs, **knobs)

    router = RequestRouter([mk(), mk()], policy="prefix")
    rng = np.random.default_rng(2000 + seed)
    events = {}
    for t in rng.choice(np.arange(1, 14),
                        size=int(rng.integers(2, 5)), replace=False):
        def churn(r, _rng=rng):
            live = [i for i in range(len(r.replicas))
                    if not r.is_draining(i)]
            grow = len(r.replicas) < 4 and (len(live) < 2
                                            or _rng.random() < 0.5)
            if grow:
                r.add_replica(mk())
            elif len(live) > 1:
                r.drain(int(_rng.choice(live)))
        events.setdefault(int(t), []).append(churn)
    drive_and_check(router, _fresh(reqs), oracle=oracle,
                    cancels=cancels, events=events)
    # membership churn happened and nothing was lost or double-counted
    assert router.n_joined >= 2
    st = router.stats()
    assert st["n_total_dispatches"] == (
        st["n_prefill_dispatches"] + st["n_decode_steps"]
        + st["n_replay_steps"] - st["n_fused_dispatches"])
