"""Sharding-rules engine: divisibility, exclusivity, soft fallback."""
import jax
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding import DEFAULT_RULES, logical_spec

pytestmark = pytest.mark.skipif(len(jax.devices()) != 1,
                                reason="mesh built from 1 cpu device")


def mesh11():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def test_single_device_mesh_never_shards():
    m = mesh11()
    spec = logical_spec(("batch", "seq", "act_ff"), (32, 128, 256), m)
    assert spec == P()


class FakeMesh:
    """Duck-typed mesh: axis sizes without real devices."""
    def __init__(self, sizes):
        self._sizes = sizes
        self.axis_names = tuple(sizes)

    @property
    def devices(self):
        import numpy as np
        return np.empty(tuple(self._sizes.values()))


def fm(pod=2, data=16, model=16):
    return FakeMesh({"pod": pod, "data": data, "model": model})


def test_divisible_dims_get_all_candidate_axes():
    spec = logical_spec(("batch", None), (256, 7), fm())
    assert spec == P(("pod", "data"))


def test_non_divisible_falls_back_to_prefix_then_replicated():
    # 16 % 32 != 0 for (pod,data) product; 16 % 2 == 0 for pod alone
    spec = logical_spec(("batch",), (16,), fm())
    assert spec == P("pod")
    spec = logical_spec(("batch",), (3,), fm())
    assert spec == P()


def test_axis_exclusivity_first_dim_wins():
    # both dims want "model": only the first gets it
    spec = logical_spec(("ff", "vocab"), (64, 64), fm())
    assert spec == P("model")       # second entry dropped->trailing None


def test_soft_mode_emits_unconstrained():
    spec = logical_spec(("act_heads",), (10,), fm(), soft=True)
    assert spec[0] is P.UNCONSTRAINED
    spec = logical_spec(("act_heads",), (32,), fm(), soft=True)
    assert spec == P("model")


@given(dim=st.integers(1, 4096))
@settings(max_examples=60, deadline=None)
def test_never_emits_non_divisible_sharding(dim):
    spec = logical_spec(("batch", "ff"), (dim, dim), fm())
    sizes = {"pod": 2, "data": 16, "model": 16}
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        assert dim % prod == 0
