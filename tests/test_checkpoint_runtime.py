"""Checkpoint/restart, fault-tolerant driver, straggler + elastic policy."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.runtime import DriverConfig, StragglerMonitor, TrainDriver, \
    plan_elastic_mesh


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"w": jnp.ones((2, 2), jnp.bfloat16),
                  "s": jnp.asarray(3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(tmp_path, 7, t)
    assert latest_step(tmp_path) == 7
    got, step = restore_checkpoint(tmp_path, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_marker_and_gc(tmp_path):
    t = tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, t, keep=2)
    assert latest_step(tmp_path) == 5
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2      # GC keeps last 2


def test_restore_missing_leaf_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        restore_checkpoint(tmp_path, {"a": jnp.zeros(3),
                                      "b": jnp.zeros(3)})


# ---------------------------------------------------------------- driver
def _toy_step():
    def step(params, opt, batch):
        p = jax.tree.map(lambda x: x - 0.1 * batch["g"], params)
        return p, opt, {"loss": jnp.sum(p["w"] ** 2)}
    return jax.jit(step)


def test_driver_recovers_from_injected_fault(tmp_path):
    faults = {12}

    def fault_hook(step):
        if step in faults:
            faults.discard(step)              # fail once
            raise RuntimeError("injected device loss")

    drv = TrainDriver(
        DriverConfig(total_steps=20, ckpt_dir=str(tmp_path), ckpt_every=5,
                     max_restarts=2),
        _toy_step(),
        lambda s: {"g": jnp.asarray(float(s % 3))},
        fault_hook=fault_hook)
    params = {"w": jnp.ones((4,))}
    p, o = drv.run(params, {})
    kinds = [e.kind for e in drv.events]
    assert "restart" in kinds
    assert latest_step(tmp_path) == 20
    # the restart resumed from step 10's checkpoint, not from scratch
    restarts = [e for e in drv.events if e.kind == "restart"]
    assert restarts[0].step == 12


def test_driver_gives_up_after_max_restarts(tmp_path):
    def always_fail(step):
        raise RuntimeError("permafault")
    drv = TrainDriver(
        DriverConfig(total_steps=5, ckpt_dir=str(tmp_path),
                     max_restarts=2),
        _toy_step(), lambda s: {"g": jnp.asarray(0.0)},
        fault_hook=always_fail)
    with pytest.raises(RuntimeError, match="max_restarts"):
        drv.run({"w": jnp.ones(2)}, {})


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(threshold=2.0, warmup=3)
    for i in range(10):
        ev = m.observe(i, 1.0)
        assert ev is None
    ev = m.observe(10, 5.0)
    assert ev is not None and ev.ratio > 2.0
    # EMA not poisoned by the outlier
    assert m.ema == pytest.approx(1.0, rel=0.05)


# ---------------------------------------------------------------- elastic
def test_elastic_mesh_keeps_model_axis():
    shape, axes = plan_elastic_mesh(480, model_parallel=16, pods=2)
    assert axes[-1] == "model" and shape[-1] == 16
    assert shape[0] * shape[1] * shape[2] <= 480


def test_elastic_mesh_drops_pod_before_data():
    shape, axes = plan_elastic_mesh(20, model_parallel=16, pods=2)
    assert axes == ("data", "model")
    assert shape == (1, 16)


def test_elastic_mesh_none_when_infeasible():
    assert plan_elastic_mesh(8, model_parallel=16) is None
