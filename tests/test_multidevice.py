"""Multi-device integration tests.

These need >1 device while the rest of the suite must see exactly one
(the dry-run owns the 512-device setting), so each test runs in a
subprocess with its own XLA_FLAGS.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_devices(n: int, code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_train_step_runs_on_mesh():
    print(run_devices(8, """
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import build_model
        from repro.models.base import abstract_params
        from repro.sharding import tree_shardings, logical_spec
        from repro.data.pipeline import SyntheticPipeline
        from repro.train.step import make_train_step
        from repro.train.optimizer import init_opt_state, opt_state_specs

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = configs.get_smoke("llama4-scout-17b-a16e")
        model = build_model(cfg)
        pspecs = model.param_specs()
        pshard = tree_shardings(pspecs, mesh)
        params = model.init(jax.random.PRNGKey(0))
        params = jax.device_put(params, pshard)
        opt = init_opt_state(params)
        oshard = tree_shardings(opt_state_specs(pspecs), mesh)
        opt = jax.device_put(opt, oshard)
        batch = SyntheticPipeline(cfg, batch=8, seq=32).device_batch(0)
        bshard = {k: NamedSharding(mesh, P("data"))
                  for k in batch}
        batch = {k: jax.device_put(v, NamedSharding(
                     mesh, P(*((\"data\",) + (None,) * (v.ndim - 1)))))
                 for k, v in batch.items()}
        step = jax.jit(make_train_step(model, cfg, n_micro=2),
                       out_shardings=(pshard, oshard, None))
        with mesh:
            p, o, m = step(params, opt, batch)
        loss = float(m["loss"])
        assert np.isfinite(loss), loss
        print("mesh train ok", loss)
    """))


def test_moe_shardmap_matches_single_device():
    print(run_devices(8, """
        import jax, numpy as np, jax.numpy as jnp
        from repro import configs
        from repro.models import build_model
        from repro.data.pipeline import SyntheticPipeline
        cfg = configs.get_smoke("deepseek-moe-16b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = SyntheticPipeline(cfg, batch=8, seq=32).device_batch(0)
        # single-device reference (local _moe_compute path)
        ref, _ = model.apply(params, batch, train=False)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh:
            got, _ = jax.jit(lambda p, b: model.apply(p, b, train=False)
                             )(params, batch)
        # expert-parallel routing has per-shard capacity: tiny numeric
        # differences only where capacity drops differ
        close = np.mean(np.isclose(np.asarray(ref, np.float32),
                                   np.asarray(got, np.float32),
                                   rtol=3e-2, atol=3e-2))
        assert close > 0.98, close
        print("moe shard_map ok", close)
    """))


def test_checkpoint_elastic_restore_8_to_4():
    print(run_devices(8, """
        import jax, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.runtime import plan_elastic_mesh

        from repro.launch.mesh import make_mesh
        mesh8 = make_mesh((2, 4), ("data", "model"))
        x = jax.device_put(np.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh8, P("data", "model")))
        d = tempfile.mkdtemp()
        save_checkpoint(d, 3, {"x": x})

        # device loss: only 4 devices survive -> elastic plan
        shape, axes = plan_elastic_mesh(4, model_parallel=4)
        assert shape == (1, 4), shape
        mesh4 = jax.sharding.Mesh(
            np.array(jax.devices()[:4]).reshape(shape), axes)
        sh4 = {"x": NamedSharding(mesh4, P("data", "model"))}
        got, step = restore_checkpoint(d, {"x": x}, shardings=sh4)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(got["x"]),
                                      np.arange(64.0).reshape(8, 8))
        assert len(got["x"].sharding.device_set) == 4
        print("elastic restore ok")
    """))


def test_decode_runs_sharded_with_kv_seq_partitioning():
    print(run_devices(8, """
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models import build_model
        from repro.models.base import abstract_params
        from repro.sharding import tree_shardings
        from repro.data.pipeline import SyntheticPipeline

        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = configs.get_smoke("qwen3-0.6b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = SyntheticPipeline(cfg, batch=4, seq=32).device_batch(0)
        # headroom: capacity > prompt so the decode write has a slot
        ref_last, ref_cache = model.prefill(params, batch, max_len=48)
        cshard = tree_shardings(model.cache_specs(4, 48), mesh)
        cache = jax.device_put(ref_cache, cshard)
        tok = batch["tokens"][:, :1]
        with mesh:
            got, _ = jax.jit(model.decode_step)(params, cache, tok)
        want, _ = model.decode_step(params, ref_cache, tok)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-2)
        print("sharded decode ok")
    """))
