"""Continuous-batching serve engine: paged-attention kernel vs oracle,
page-allocator invariants, and token-exact parity of continuous-batched
decode against the sequential ``greedy_generate`` oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_ref)
from repro.models import build_model
from repro.serve import PagedKVCache, Request, ServeEngine, greedy_generate


# ---------------------------------------------------------------- model
@pytest.fixture(scope="module")
def qwen3():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def rnd(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------- kernel
@pytest.mark.parametrize("h,kvh,d", [(4, 4, 32), (8, 2, 64), (4, 1, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_vs_ref(h, kvh, d, dtype):
    B, P, ps, n = 3, 16, 8, 5
    q = rnd(0, (B, h, d), dtype)
    kp = rnd(1, (P, ps, kvh, d), dtype)
    vp = rnd(2, (P, ps, kvh, d), dtype)
    rng = np.random.default_rng(0)
    # distinct non-null pages per sequence, ragged lengths
    ids = rng.permutation(np.arange(1, P))[:B * n].reshape(B, n)
    tbl = jnp.asarray(ids, jnp.int32)
    lens = jnp.asarray([n * ps, 9, 17], jnp.int32)
    got = paged_attention(q, kp, vp, tbl, lens, interpret=True)
    want = paged_attention_ref(q, kp, vp, tbl, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_paged_ref_matches_contiguous_decode_attention():
    """Gathering pages reproduces contiguous-cache decode attention
    exactly (padding contributes exact zeros)."""
    from repro.models.components import decode_attention
    B, H, KVH, Dh, ps = 2, 4, 2, 16, 4
    S = 3 * ps
    k = rnd(3, (B, S, KVH, Dh), jnp.bfloat16)
    v = rnd(4, (B, S, KVH, Dh), jnp.bfloat16)
    q = rnd(5, (B, 1, H, Dh), jnp.bfloat16)
    pos = 10
    # lay the contiguous cache out as pages 1..3 per sequence
    kp = jnp.concatenate([jnp.zeros((1, ps, KVH, Dh), jnp.bfloat16),
                          k.reshape(B * 3, ps, KVH, Dh)])
    vp = jnp.concatenate([jnp.zeros((1, ps, KVH, Dh), jnp.bfloat16),
                          v.reshape(B * 3, ps, KVH, Dh)])
    tbl = (jnp.arange(B * 3, dtype=jnp.int32).reshape(B, 3) + 1)
    want = decode_attention(q, k, v, pos, window=None)
    got = paged_attention_ref(q[:, 0], kp, vp, tbl,
                              jnp.full((B,), pos, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want[:, 0], np.float32))


# ------------------------------------------------------------ allocator
def make_cache(model, **kw):
    kw = {"max_batch": 4, "n_pages": 12, "page_size": 8,
          "max_pages_per_seq": 6, **kw}
    return PagedKVCache(model, **kw)


def test_allocator_alloc_free_reuse(qwen3):
    _, model, _ = qwen3
    c = make_cache(model)
    assert c.free_pages == 11            # page 0 reserved
    assert c.alloc_slot(0, 17) is not None       # 3 pages
    assert c.free_pages == 8
    assert c.alloc_slot(1, 8) is not None        # 1 page
    c.check_invariants()
    pages0 = set(c.used_pages(0))
    c.free_slot(0)
    assert c.free_pages == 10
    c.check_invariants()
    # freed pages come back around
    assert c.alloc_slot(2, 40) is not None       # 5 pages
    assert set(c.used_pages(2)) & pages0
    c.check_invariants()


def test_allocator_headroom_growth_and_exhaustion(qwen3):
    _, model, _ = qwen3
    c = make_cache(model, n_pages=4)     # 3 usable
    # 1 full page + the decode-headroom reserve fits in 3
    assert c.alloc_slot(0, 8) is not None
    c.lengths[0] = 8
    assert c.ensure_headroom(0)          # token 8 -> needs page 2
    assert len(c.used_pages(0)) == 2
    c.lengths[0] = 16
    assert c.ensure_headroom(0)
    c.lengths[0] = 24
    assert not c.ensure_headroom(0)      # free list empty now
    c.check_invariants()


def test_allocator_rejects_oversubscription(qwen3):
    _, model, _ = qwen3
    c = make_cache(model)
    assert c.alloc_slot(0, 8 * 10) is None   # > max_pages_per_seq
    assert c.free_pages == 11
    tight = make_cache(model, n_pages=5)     # 4 usable
    assert tight.alloc_slot(0, 8 * 4) is None   # no headroom page left
    assert tight.free_pages == 4
    c.check_invariants()
    tight.check_invariants()


@given(sizes=st.lists(st.integers(1, 40), min_size=1, max_size=8))
@settings(max_examples=25, deadline=None)
def test_allocator_invariants_random_churn(qwen3, sizes):
    _, model, _ = qwen3
    c = make_cache(model, max_batch=8, n_pages=16, max_pages_per_seq=8)
    live = []
    for i, s in enumerate(sizes):
        if c.alloc_slot(i, s) is not None:
            live.append(i)
        c.check_invariants()
        if len(live) > 2:                # churn: free the oldest
            c.free_slot(live.pop(0))
            c.check_invariants()
    for slot in live:
        c.free_slot(slot)
    c.check_invariants()
    assert c.free_pages == 15


# ---------------------------------------------------------------- parity
def test_engine_token_exact_vs_greedy_generate(qwen3):
    """Continuous-batched decode == per-request sequential greedy, token
    for token, with ragged prompts and more requests than slots."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(7)
    lens, gen = [9, 17, 24, 12, 31, 8], 10
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    oracle = {
        i: np.asarray(greedy_generate(model, params, {"tokens": p[None]},
                                      gen, cache_len=len(p) + gen))[0]
        for i, p in enumerate(prompts)}

    eng = ServeEngine(model, params, max_batch=3, n_pages=24,
                      page_size=8, max_pages_per_seq=8)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert len(done) == len(prompts)
    for r in done:
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), oracle[r.rid],
            err_msg=f"request {r.rid} diverged")
    eng.cache.check_invariants()
    # prompt KV outlives its request in the prefix trie; draining the
    # trie must return every page to the free list
    eng.cache.release_prefix_pages(len(eng.cache.prefix))
    eng.cache.check_invariants()
    assert eng.cache.free_pages == 23    # everything returned
    assert eng.n_decode_steps < sum(lens) // min(lens) * gen


def test_engine_preemption_recovers_token_exact(qwen3):
    """Page pressure forces a mid-flight eviction; the preempted request
    is recomputed on readmission and still matches the oracle.

    gen is kept short: the random-init smoke model degenerates into
    long repeated-token plateaus where bf16 hidden states sit on
    rounding knife-edges, and XLA CPU's reduction partitioning can
    shift under machine load — docs/serving.md (parity section)
    documents the caveat.  Sharing is off so page pressure is
    predictable (4+4+3 prompt pages + decode growth against 12);
    prefix sharing gets its own tests below."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(11)
    lens, gen = [30, 28, 18], 8
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    oracle = {
        i: np.asarray(greedy_generate(model, params, {"tokens": p[None]},
                                      gen, cache_len=len(p) + gen))[0]
        for i, p in enumerate(prompts)}
    eng = ServeEngine(model, params, max_batch=3, n_pages=13,
                      page_size=8, max_pages_per_seq=8,
                      prefix_sharing=False)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert sum(r.n_preemptions for r in done) >= 1, \
        "page budget was meant to force a preemption"
    assert eng.n_replay_steps >= 1, \
        "readmission should replay pre-preemption tokens"
    for r in done:
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), oracle[r.rid])
    eng.cache.check_invariants()


def test_chunked_prefill_long_prompt_parity(qwen3):
    """A prompt spanning several chunks and context buckets ingests
    incrementally and still reproduces the oracle exactly."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=(70,)).astype(np.int32)
    gen = 6
    oracle = np.asarray(greedy_generate(
        model, params, {"tokens": prompt[None]}, gen,
        cache_len=len(prompt) + gen))[0]
    eng = ServeEngine(model, params, max_batch=2, n_pages=16,
                      page_size=8, max_pages_per_seq=12, chunk_size=16,
                      bucket_edges=[2, 4, 8, 12])
    done = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=gen)])
    assert eng.n_prefill_chunks == 5          # ceil(70 / 16)
    np.testing.assert_array_equal(
        np.asarray(done[0].generated, np.int32), oracle)
    eng.cache.check_invariants()


# ----------------------------------------------------- prefix sharing
def test_prefix_sharing_cow_token_exact(qwen3):
    """Requests sharing a prompt prefix diverge mid-page: later
    requests attach the cached pages (copy-on-write protects the
    partial one) and every stream still matches its unshared oracle."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
    gen = 6
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size,
                                            size=(7,)).astype(np.int32)])
               for _ in range(3)]
    oracle = {
        i: np.asarray(greedy_generate(model, params, {"tokens": p[None]},
                                      gen, cache_len=len(p) + gen))[0]
        for i, p in enumerate(prompts)}
    eng = ServeEngine(model, params, max_batch=2, n_pages=32,
                      page_size=8, max_pages_per_seq=8, chunk_size=16)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    # requests 1 and 2 reuse the 20-token prefix: 2 full pages plus a
    # copy-on-write fork of the partial third page
    assert eng.cache.n_shared_tokens >= 2 * 20
    assert eng.cache.n_cow >= 2
    for r in done:
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), oracle[r.rid],
            err_msg=f"request {r.rid} diverged")
    eng.cache.check_invariants()


def test_shared_page_refcounts_and_eviction(qwen3):
    """A shared page must survive its donor: freeing one reader (or the
    trie reference) never frees a page while refcount > 1."""
    _, model, _ = qwen3
    c = make_cache(model)                     # 11 usable pages
    prompt = np.arange(20, dtype=np.int32)    # 2 full pages + 4 tokens
    assert c.alloc_slot(0, 20, prompt=prompt) == 0
    c.lengths[0] = 20                         # simulate full ingest
    c.register_prefix(0, prompt)
    c.check_invariants()
    free_before = c.free_pages
    # second reader: shares 2 full pages + a COW fork of the partial
    # (capped one short of the full prompt)
    shared = c.alloc_slot(1, 20, prompt=prompt)
    assert shared == 19
    assert c.n_cow == 1
    assert c.free_pages == free_before - 1    # only the COW copy
    assert c.used_pages(1)[:2] == c.used_pages(0)[:2]
    assert c.used_pages(1)[2] != c.used_pages(0)[2]
    c.check_invariants()
    # donor eviction: its pages stay resident (trie + reader refs)
    c.free_slot(0)
    assert c.free_pages == free_before - 1
    c.check_invariants()
    # trie eviction frees only the now-unreferenced partial page
    assert c.release_prefix_pages(len(c.prefix)) == 3
    assert c.free_pages == free_before
    c.check_invariants()
    # last reader out: everything returns
    c.free_slot(1)
    assert c.free_pages == 11
    c.check_invariants()


def test_prefix_cache_lookup_partial_and_exact(qwen3):
    """PrefixCache trie semantics: exact full-page descent, partial
    longest-common-prefix hits, and the always-compute-one-token cap."""
    from repro.serve.prefix import PrefixCache
    t = PrefixCache(4)
    t.insert(np.arange(10), [11, 12, 13])     # 2 full pages + tail (8,9)
    # identical prompt: capped one short of full coverage
    pages, shared = t.lookup(np.arange(10))
    assert shared == 9 and [p for p, _ in pages] == [11, 12, 13]
    # divergence mid-page-2: only the exact full page + partial match
    q = np.array([0, 1, 2, 3, 4, 5, 6, 99, 8, 9])
    pages, shared = t.lookup(q)
    assert shared == 7 and [p for p, _ in pages] == [11, 12]
    assert pages[-1] == (12, 3)
    # no hit at all
    pages, shared = t.lookup(np.array([7, 7, 7, 7]))
    assert shared == 0 and pages == []


def test_engine_rejects_unsupported_family():
    cfg = configs.get_smoke("rwkv6-3b")
    model = build_model(cfg)
    with pytest.raises(ValueError, match="paged decode"):
        ServeEngine(model, model.init(jax.random.PRNGKey(0)))


def test_oversized_request_rejected_at_submit(qwen3):
    """A request that could never be admitted fails fast instead of
    spinning the engine forever."""
    cfg, model, params = qwen3
    prompt = np.arange(8, dtype=np.int32)
    eng = ServeEngine(model, params, max_batch=2, n_pages=4,
                      page_size=8, max_pages_per_seq=8)
    with pytest.raises(ValueError, match="page budget"):
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=40))
    # engine still serves admissible work afterwards
    done = eng.run([Request(rid=1, prompt=prompt, max_new_tokens=4)])
    assert len(done) == 1 and len(done[0].generated) == 4
