"""Continuous-batching serve engine: paged-attention kernel vs oracle,
page-allocator invariants, and token-exact parity of continuous-batched
decode against the sequential ``greedy_generate`` oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_ref)
from repro.models import build_model
from repro.serve import PagedKVCache, Request, ServeEngine, greedy_generate


# ---------------------------------------------------------------- model
@pytest.fixture(scope="module")
def qwen3():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def rnd(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# --------------------------------------------------------------- kernel
@pytest.mark.parametrize("h,kvh,d", [(4, 4, 32), (8, 2, 64), (4, 1, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_kernel_vs_ref(h, kvh, d, dtype):
    B, P, ps, n = 3, 16, 8, 5
    q = rnd(0, (B, h, d), dtype)
    kp = rnd(1, (P, ps, kvh, d), dtype)
    vp = rnd(2, (P, ps, kvh, d), dtype)
    rng = np.random.default_rng(0)
    # distinct non-null pages per sequence, ragged lengths
    ids = rng.permutation(np.arange(1, P))[:B * n].reshape(B, n)
    tbl = jnp.asarray(ids, jnp.int32)
    lens = jnp.asarray([n * ps, 9, 17], jnp.int32)
    got = paged_attention(q, kp, vp, tbl, lens, interpret=True)
    want = paged_attention_ref(q, kp, vp, tbl, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_paged_ref_matches_contiguous_decode_attention():
    """Gathering pages reproduces contiguous-cache decode attention
    exactly (padding contributes exact zeros)."""
    from repro.models.components import decode_attention
    B, H, KVH, Dh, ps = 2, 4, 2, 16, 4
    S = 3 * ps
    k = rnd(3, (B, S, KVH, Dh), jnp.bfloat16)
    v = rnd(4, (B, S, KVH, Dh), jnp.bfloat16)
    q = rnd(5, (B, 1, H, Dh), jnp.bfloat16)
    pos = 10
    # lay the contiguous cache out as pages 1..3 per sequence
    kp = jnp.concatenate([jnp.zeros((1, ps, KVH, Dh), jnp.bfloat16),
                          k.reshape(B * 3, ps, KVH, Dh)])
    vp = jnp.concatenate([jnp.zeros((1, ps, KVH, Dh), jnp.bfloat16),
                          v.reshape(B * 3, ps, KVH, Dh)])
    tbl = (jnp.arange(B * 3, dtype=jnp.int32).reshape(B, 3) + 1)
    want = decode_attention(q, k, v, pos, window=None)
    got = paged_attention_ref(q[:, 0], kp, vp, tbl,
                              jnp.full((B,), pos, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want[:, 0], np.float32))


# ------------------------------------------------------------ allocator
def make_cache(model, **kw):
    kw = {"max_batch": 4, "n_pages": 12, "page_size": 8,
          "max_pages_per_seq": 6, **kw}
    return PagedKVCache(model, **kw)


def test_allocator_alloc_free_reuse(qwen3):
    _, model, _ = qwen3
    c = make_cache(model)
    assert c.free_pages == 11            # page 0 reserved
    assert c.alloc_slot(0, 17)           # 3 pages
    assert c.free_pages == 8
    assert c.alloc_slot(1, 8)            # 1 page
    c.check_invariants()
    pages0 = set(c.used_pages(0))
    c.free_slot(0)
    assert c.free_pages == 10
    c.check_invariants()
    # freed pages come back around
    assert c.alloc_slot(2, 40)           # 5 pages
    assert set(c.used_pages(2)) & pages0
    c.check_invariants()


def test_allocator_headroom_growth_and_exhaustion(qwen3):
    _, model, _ = qwen3
    c = make_cache(model, n_pages=4)     # 3 usable
    assert c.alloc_slot(0, 8)            # exactly 1 full page
    assert c.ensure_headroom(0)          # token 8 -> needs page 2
    assert len(c.used_pages(0)) == 2
    c.lengths[0] = 16
    assert c.ensure_headroom(0)
    c.lengths[0] = 24
    assert not c.ensure_headroom(0)      # free list empty now
    c.check_invariants()


def test_allocator_rejects_oversubscription(qwen3):
    _, model, _ = qwen3
    c = make_cache(model)
    assert not c.alloc_slot(0, 8 * 10)   # > max_pages_per_seq
    assert not c.can_admit(8 * 12)
    assert c.free_pages == 11
    c.check_invariants()


@given(sizes=st.lists(st.integers(1, 40), min_size=1, max_size=8))
@settings(max_examples=25, deadline=None)
def test_allocator_invariants_random_churn(qwen3, sizes):
    _, model, _ = qwen3
    c = make_cache(model, max_batch=8, n_pages=16, max_pages_per_seq=8)
    live = []
    for i, s in enumerate(sizes):
        if c.alloc_slot(i, s):
            live.append(i)
        c.check_invariants()
        if len(live) > 2:                # churn: free the oldest
            c.free_slot(live.pop(0))
            c.check_invariants()
    for slot in live:
        c.free_slot(slot)
    c.check_invariants()
    assert c.free_pages == 15


# ---------------------------------------------------------------- parity
def test_engine_token_exact_vs_greedy_generate(qwen3):
    """Continuous-batched decode == per-request sequential greedy, token
    for token, with ragged prompts and more requests than slots."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(7)
    lens, gen = [9, 17, 24, 12, 31, 8], 10
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    oracle = {
        i: np.asarray(greedy_generate(model, params, {"tokens": p[None]},
                                      gen, cache_len=len(p) + gen))[0]
        for i, p in enumerate(prompts)}

    eng = ServeEngine(model, params, max_batch=3, n_pages=24,
                      page_size=8, max_pages_per_seq=8)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert len(done) == len(prompts)
    for r in done:
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), oracle[r.rid],
            err_msg=f"request {r.rid} diverged")
    eng.cache.check_invariants()
    assert eng.cache.free_pages == 23    # everything returned
    assert eng.n_decode_steps < sum(lens) // min(lens) * gen


def test_engine_preemption_recovers_token_exact(qwen3):
    """Page pressure forces a mid-flight eviction; the preempted request
    is recomputed on readmission and still matches the oracle."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(11)
    lens, gen = [30, 28, 26, 25], 14
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    oracle = {
        i: np.asarray(greedy_generate(model, params, {"tokens": p[None]},
                                      gen, cache_len=len(p) + gen))[0]
        for i, p in enumerate(prompts)}
    eng = ServeEngine(model, params, max_batch=3, n_pages=14,
                      page_size=8, max_pages_per_seq=8)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert sum(r.n_preemptions for r in done) >= 1, \
        "page budget was meant to force a preemption"
    for r in done:
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), oracle[r.rid])
    eng.cache.check_invariants()


def test_engine_rejects_unsupported_family():
    cfg = configs.get_smoke("rwkv6-3b")
    model = build_model(cfg)
    with pytest.raises(ValueError, match="paged decode"):
        ServeEngine(model, model.init(jax.random.PRNGKey(0)))


def test_oversized_request_rejected_at_submit(qwen3):
    """A request that could never be admitted fails fast instead of
    spinning the engine forever."""
    cfg, model, params = qwen3
    prompt = np.arange(8, dtype=np.int32)
    eng = ServeEngine(model, params, max_batch=2, n_pages=4,
                      page_size=8, max_pages_per_seq=8)
    with pytest.raises(ValueError, match="page budget"):
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=40))
    # engine still serves admissible work afterwards
    done = eng.run([Request(rid=1, prompt=prompt, max_new_tokens=4)])
    assert len(done) == 1 and len(done[0].generated) == 4
