"""Speculative decoding on the paged serve engine: bitwise equivalence
of the multi-token verify program against sequential decode, token-exact
parity of the speculative engine vs ``greedy_generate`` under every
PR 2 composition (chunked prefill, prefix sharing/COW, preemption), and
allocator invariants under random speculative accept/reject churn."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import build_model
from repro.serve import (DraftModelDrafter, PagedKVCache,
                         PromptLookupDrafter, Request, ServeEngine,
                         greedy_generate)


@pytest.fixture(scope="module")
def qwen3():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def oracles(model, params, prompts, gen):
    return {i: np.asarray(
        greedy_generate(model, params, {"tokens": p[None]}, gen,
                        cache_len=len(p) + gen))[0]
        for i, p in enumerate(prompts)}


def assert_parity(done, oracle):
    for r in done:
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32), oracle[r.rid],
            err_msg=f"request {r.rid} diverged")


# --------------------------------------------------------- verify step
def test_verify_step_bitwise_matches_sequential_decode(qwen3):
    """One verify call over T tokens returns logits AND page contents
    bit-identical to T sequential decode_step_paged calls — the whole
    speculation parity guarantee reduces to this equivalence."""
    cfg, model, params = qwen3
    B, ps, n_pages, npps, T = 3, 8, 32, 6, 5
    rng = np.random.default_rng(0)
    shape = (cfg.n_layers, n_pages, ps, cfg.n_kv_heads, cfg.head_dim)
    k_pages = jnp.asarray(rng.normal(size=shape), jnp.bfloat16)
    v_pages = jnp.asarray(rng.normal(size=shape), jnp.bfloat16)
    tables = np.zeros((B, npps), np.int32)
    tables[:, :4] = rng.permutation(np.arange(1, n_pages))[:B * 4] \
        .reshape(B, 4)
    lengths = np.asarray([9, 17, 3], np.int32)     # ragged positions
    toks = rng.integers(0, cfg.vocab_size, size=(B, T)).astype(np.int32)

    st_ = {"k_pages": k_pages, "v_pages": v_pages,
           "page_tables": jnp.asarray(tables),
           "lengths": jnp.asarray(lengths)}
    seq = []
    decode = jax.jit(model.decode_step_paged)
    for t in range(T):
        lg, st_ = decode(params, st_, jnp.asarray(toks[:, t:t + 1]))
        seq.append(np.asarray(lg))
    seq = np.stack(seq, axis=1)                    # (B, T, V)

    st2 = {"k_pages": k_pages, "v_pages": v_pages,
           "page_tables": jnp.asarray(tables),
           "lengths": jnp.asarray(lengths)}
    ver, st2 = jax.jit(model.verify_step_paged)(params, st2,
                                                jnp.asarray(toks))
    np.testing.assert_array_equal(seq, np.asarray(ver))
    np.testing.assert_array_equal(
        np.asarray(st_["k_pages"], np.float32),
        np.asarray(st2["k_pages"], np.float32))
    np.testing.assert_array_equal(
        np.asarray(st_["v_pages"], np.float32),
        np.asarray(st2["v_pages"], np.float32))


# ------------------------------------------------------- engine parity
def test_spec_engine_token_exact_vs_greedy_generate(qwen3):
    """Speculation on, more requests than slots, ragged prompts: every
    stream matches the sequential oracle token for token, and every
    page returns to the free list."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(7)
    lens, gen = [9, 17, 24, 12, 31, 8], 10
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    oracle = oracles(model, params, prompts, gen)
    eng = ServeEngine(model, params, max_batch=3, n_pages=24,
                      page_size=8, max_pages_per_seq=8, spec_k=4)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert len(done) == len(prompts)
    assert_parity(done, oracle)
    assert eng.n_spec_rounds > 0 and eng.n_drafted > 0
    eng.cache.check_invariants()
    eng.cache.release_prefix_pages(len(eng.cache.prefix))
    eng.cache.check_invariants()
    assert eng.cache.free_pages == 23

    # a second, repeated workload warms the cross-request n-gram index:
    # acceptance must rise while the streams stay bit-identical
    drafted0, acc0 = eng.n_drafted, eng.n_draft_accepted
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert_parity(done, oracle)
    warm_rate = (eng.n_draft_accepted - acc0) / (eng.n_drafted - drafted0)
    assert warm_rate > 0.5, f"warm accept rate {warm_rate:.2f}"


def test_spec_engine_preemption_token_exact(qwen3):
    """Page pressure forces preemption mid-speculation; the evicted
    request recomputes (replay) and still matches the oracle."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(11)
    lens, gen = [30, 28, 18], 8
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    oracle = oracles(model, params, prompts, gen)
    # n_pages=9 runs the pool dry mid-speculation under the fused step's
    # one-chunk-per-step admission pacing (13 did under the unfused one).
    eng = ServeEngine(model, params, max_batch=3, n_pages=9,
                      page_size=8, max_pages_per_seq=8,
                      prefix_sharing=False, spec_k=4)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert sum(r.n_preemptions for r in done) >= 1
    assert_parity(done, oracle)
    eng.cache.check_invariants()


def test_spec_engine_sharing_chunking_token_exact(qwen3):
    """The full composition: chunked prefill + COW prefix sharing +
    speculation, with prompts diverging mid-page."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
    gen = 6
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size,
                                            size=(7,)).astype(np.int32)])
               for _ in range(3)]
    oracle = oracles(model, params, prompts, gen)
    eng = ServeEngine(model, params, max_batch=2, n_pages=32,
                      page_size=8, max_pages_per_seq=8, chunk_size=16,
                      spec_k=4)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert eng.cache.n_shared_tokens >= 2 * 20
    assert eng.cache.n_cow >= 2
    assert_parity(done, oracle)
    eng.cache.check_invariants()


def test_spec_engine_eos_stops_at_first_occurrence(qwen3):
    """A verify round can bank several tokens at once; anything banked
    after the first eos must be discarded (the oracle stops there)."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=(22,)).astype(np.int32)
    gen = 10
    oracle = oracles(model, params, [prompt], gen)[0]
    eos = int(oracle[4])
    stop = int(np.nonzero(oracle == eos)[0][0])    # first occurrence
    eng = ServeEngine(model, params, max_batch=2, n_pages=16,
                      page_size=8, max_pages_per_seq=8, spec_k=4,
                      eos_id=eos)
    done = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=gen)])
    np.testing.assert_array_equal(
        np.asarray(done[0].generated, np.int32), oracle[:stop + 1])
    eng.cache.check_invariants()


def test_draft_model_drafter_rejection_path(qwen3):
    """A random-init draft model proposes garbage: near-total rejection
    must leave streams exact (speculation can only change speed), and
    detach must drop per-slot draft state."""
    cfg, model, params = qwen3
    dcfg = configs.get_smoke("qwen2-0.5b")
    dmodel = build_model(dcfg)
    drafter = DraftModelDrafter(dmodel,
                                dmodel.init(jax.random.PRNGKey(1)),
                                cfg_target=cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in (9, 14)]
    gen = 6
    oracle = oracles(model, params, prompts, gen)
    eng = ServeEngine(model, params, max_batch=2, n_pages=16,
                      page_size=8, max_pages_per_seq=8, spec_k=3,
                      drafter=drafter)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert eng.n_drafted > 0
    assert_parity(done, oracle)
    assert not drafter._slots          # all slots detached at finish
    eng.cache.check_invariants()


def test_draft_model_vocab_mismatch_rejected(qwen3):
    import dataclasses
    cfg, model, _ = qwen3
    bad = dataclasses.replace(configs.get_smoke("stablelm-1.6b"),
                              vocab_size=cfg.vocab_size + 1)
    dmodel = build_model(bad)
    with pytest.raises(ValueError, match="vocab"):
        DraftModelDrafter(dmodel, None, cfg_target=cfg)


# ------------------------------------------------------------- drafter
def test_prompt_lookup_drafter_semantics():
    """Lag-by-one indexing: a trailing plateau finds its own earlier
    occurrence, cross-request reuse works inside one scope, and
    distinct scopes never share n-gram statistics."""
    d = PromptLookupDrafter(max_ngram=3, min_ngram=1, scope_tokens=4)
    ra = Request(rid=0, prompt=np.asarray([1, 2, 3, 4], np.int32),
                 max_new_tokens=32)
    ra.generated = [7, 7, 7]
    # plateau: trailing (7, 7) hits the earlier (7, 7) -> 7 occurrence
    assert d.propose(0, ra, 4) == [7]
    ra.generated = [7, 7, 7, 7]
    # the (7,7,7)->7 entry points at the live frontier: one confirmed
    # continuation token so far (the source list keeps growing)
    assert d.propose(0, ra, 4) == [7]
    # same scope, different request: the motif transfers
    rb = Request(rid=1, prompt=np.asarray([1, 2, 3, 4], np.int32),
                 max_new_tokens=32)
    rb.generated = [7]
    assert d.propose(1, rb, 3) == [7, 7, 7]
    # different scope: isolated index, no draft
    rc = Request(rid=2, prompt=np.asarray([9, 9, 9, 9], np.int32),
                 max_new_tokens=32)
    rc.generated = [7]
    assert d.propose(2, rc, 3) == []
    d.detach(0)
    d.detach(1)
    d.detach(2)
    assert not d._slots


def _scoped_request(rid, scope_token, generated):
    r = Request(rid=rid,
                prompt=np.full((4,), scope_token, np.int32),
                max_new_tokens=64)
    r.generated = list(generated)
    return r


def test_prompt_lookup_index_evicts_lru_scope_only():
    """At the entry budget the index drops whole least-recently-used
    scopes; the scope in use survives (the old wholesale reset cooled
    every workload whenever one overgrew)."""
    d = PromptLookupDrafter(max_ngram=2, min_ngram=1, scope_tokens=4,
                            max_entries=24)
    # three workloads populate three scopes, oldest first
    for i, tok in enumerate((1, 2, 3)):
        d.propose(i, _scoped_request(i, tok, [7, 8, 7, 8, 7]), 0)
    assert len(d._scopes) == 3 and d.n_scope_evictions == 0
    # touch scope 1 so scope 0 is now the stalest
    d.propose(1, _scoped_request(10, 2, [7, 8, 7, 8, 7, 8]), 0)
    # a fourth workload overflows the budget -> scope 0 evicted
    d.propose(3, _scoped_request(3, 4, [7, 8, 7, 8, 7]), 0)
    assert d.n_scope_evictions >= 1
    scopes = set(d._scopes)
    assert (1,) * 4 not in scopes, "evicted the hot scope, not the LRU"
    assert (4,) * 4 in scopes, "the in-use scope must survive"
    # surviving scopes still draft; the evicted one restarts cold
    rb = _scoped_request(20, 2, [7])
    assert d.propose(4, rb, 2) == [8, 7]
    rc = _scoped_request(21, 1, [7])
    assert d.propose(5, rc, 2) == []
    assert d._n_entries == sum(len(ix) for ix in d._scopes.values())


def test_prompt_lookup_single_giant_scope_resets_itself():
    """A single scope exceeding the whole budget resets in place
    instead of looping the LRU forever."""
    d = PromptLookupDrafter(max_ngram=2, min_ngram=1, scope_tokens=4,
                            max_entries=8)
    seq = list(range(40))                 # 40 distinct unigram entries
    d.propose(0, _scoped_request(0, 1, seq), 0)
    assert d.n_scope_evictions >= 1
    assert d._n_entries <= 8
    assert len(d._scopes) == 1           # scope still registered


# -------------------------------------------- allocator spec invariants
def make_cache(model, **kw):
    kw = {"max_batch": 4, "n_pages": 24, "page_size": 8,
          "max_pages_per_seq": 12, **kw}
    return PagedKVCache(model, **kw)


def test_ensure_headroom_multi_token_and_rollback(qwen3):
    """A k+1 write window spanning a page boundary allocates ahead;
    rollback returns exactly the pages past the confirmed frontier."""
    _, model, _ = qwen3
    c = make_cache(model)
    assert c.alloc_slot(0, 14) is not None        # 2 pages
    c.lengths[0] = 14
    free0 = c.free_pages
    # window 14..20 crosses into page 3
    assert c.ensure_headroom(0, 7)
    assert len(c.used_pages(0)) == 3
    assert c.free_pages == free0 - 1
    # nothing accepted: the speculative page comes straight back
    assert c.rollback_spec(0) == 1
    assert c.free_pages == free0
    c.check_invariants()
    # partial acceptance into the new page: it is kept
    assert c.ensure_headroom(0, 7)
    c.lengths[0] = 17
    assert c.rollback_spec(0) == 0
    assert len(c.used_pages(0)) == 3
    c.check_invariants()


def test_rollback_never_touches_shared_prompt_pages(qwen3):
    """Speculative rollback only releases private growth — donated
    (trie-referenced) and reader-shared prompt pages keep their
    refcounts."""
    _, model, _ = qwen3
    c = make_cache(model)
    prompt = np.arange(20, dtype=np.int32)
    assert c.alloc_slot(0, 20, prompt=prompt) == 0
    c.lengths[0] = 20
    c.register_prefix(0, prompt)
    shared = c.alloc_slot(1, 20, prompt=prompt)
    assert shared == 19
    for slot in (0, 1):
        c.lengths[slot] = 20
        assert c.ensure_headroom(slot, 5)          # 20..24 -> page 4
        n = c.rollback_spec(slot)
        assert n == 1
        c.check_invariants()
    # trie + both readers still agree on the shared full pages
    assert c.used_pages(0)[:2] == c.used_pages(1)[:2]


@given(ops=st.lists(st.integers(0, 10 ** 6), min_size=1, max_size=40))
@settings(max_examples=25, deadline=None)
def test_spec_churn_invariants_random(qwen3, ops):
    """Random speculative accept/reject sequences over slots sharing a
    donated prompt: free-list and refcount invariants hold after every
    round, the frontier page is always covered, and draining returns
    every page."""
    _, model, _ = qwen3
    c = make_cache(model)
    prompt = np.arange(12, dtype=np.int32)
    assert c.alloc_slot(0, 12, prompt=prompt) == 0
    c.lengths[0] = 12
    c.register_prefix(0, prompt)
    assert c.alloc_slot(1, 12, prompt=prompt) is not None
    c.lengths[1] = 12
    for v in ops:
        slot = v % 2
        n_draft = (v // 2) % 5
        accepted = (v // 10) % (n_draft + 2)       # 0 .. n_draft+1
        if c.ensure_headroom(slot, n_draft + 1):
            c.lengths[slot] += accepted
        c.rollback_spec(slot)                      # also after failures
        c.check_invariants()
        used = len(c.used_pages(slot))
        assert used <= int(c.lengths[slot]) // c.page_size + 1
        assert used >= c.pages_for(int(c.lengths[slot]))
    c.free_slot(0)
    c.free_slot(1)
    c.release_prefix_pages(len(c.prefix))
    c.check_invariants()
    assert c.free_pages == 23


@given(seed=st.integers(0, 10 ** 6), k=st.integers(1, 6))
@settings(max_examples=4, deadline=None)
def test_spec_engine_random_traces_token_exact(qwen3, seed, k):
    """Property-style end-to-end: random prompts and draft depths stay
    bit-identical to the oracle with sharing + chunking enabled."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(seed)
    lens = rng.choice([6, 10, 19], size=3)
    gen = int(rng.integers(3, 7))
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    oracle = oracles(model, params, prompts, gen)
    eng = ServeEngine(model, params, max_batch=2, n_pages=24,
                      page_size=8, max_pages_per_seq=6, chunk_size=8,
                      spec_k=k)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert_parity(done, oracle)
    eng.cache.check_invariants()
