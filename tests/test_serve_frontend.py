"""Async streaming front-end: stream-vs-batch token parity on the real
engine (sharing/spec/preemption/cancel), and scheduling policy (WFQ
weights, rate limits, SLO preemption) on a model-free fake backend."""
import asyncio

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import (
    Request, ServeBackend, ServeFrontend, ServeOptions, StreamEvent,
    TenantPolicy, greedy_generate,
)


@pytest.fixture(scope="module")
def qwen3():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n=6, plen=20, shared=0, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, shared, dtype=np.int32)
    return [np.concatenate([prefix,
                            rng.integers(0, cfg.vocab_size, plen,
                                         dtype=np.int32)])
            for _ in range(n)]


def _oracle(model, params, prompts, gen):
    out = []
    for p in prompts:
        toks = greedy_generate(model, params, {"tokens": p[None]}, gen,
                               cache_len=len(p) + gen)
        out.append([int(t) for t in np.asarray(toks)[0]])
    return out


def _backend(model, params, **kw):
    reqs = [Request(rid=0, prompt=np.zeros(64, np.int32),
                    max_new_tokens=16)]
    opts = ServeOptions(batch=kw.pop("batch", 3), page_size=8,
                        chunk_size=16, **kw)
    return opts.sized_for(reqs).build(model, params)


# ----------------------------------------------------- fake backend
class FakeBackend:
    """Deterministic ServeBackend stand-in: each step confirms one
    token (rid*1000 + index) per dispatched request.  Lets the
    scheduling-policy tests run without a model."""

    def __init__(self, capacity=1):
        self._capacity = capacity
        self.active = {}
        self.events = []
        self.dispatch_order = []

    @property
    def capacity(self):
        return self._capacity

    @property
    def n_inflight(self):
        return len(self.active)

    def check_admissible(self, req):
        pass

    def submit(self, req):
        assert len(self.active) < self._capacity, "frontend over-dispatched"
        self.active[req.rid] = req
        self.dispatch_order.append(req.rid)

    def step(self, now=float("inf")):
        for rid, req in list(self.active.items()):
            req.generated.append(rid * 1000 + len(req.generated))
            done = len(req.generated) >= req.max_new_tokens
            if done:
                req.finish_time = now
                del self.active[rid]
            self.events.append(StreamEvent(rid=rid,
                                           tokens=(req.generated[-1],),
                                           finished=done))
        return bool(self.active)

    def drain_events(self):
        ev, self.events = self.events, []
        return ev

    def extract(self, rid):
        return self.active.pop(rid, None)

    def cancel(self, rid):
        return self.extract(rid) is not None

    def run(self, requests, *, realtime=False):
        raise NotImplementedError

    def stats(self):
        return {}


def test_fake_backend_satisfies_protocol():
    assert isinstance(FakeBackend(), ServeBackend)


# ------------------------------------------------------------ parity
def test_stream_matches_batch_run(qwen3):
    """Streamed tokens are bitwise-equal to the offline ServeEngine.run
    path and the greedy oracle, with prefix sharing AND speculation on
    (tokens arrive in bursts; content is unchanged)."""
    cfg, model, params = qwen3
    prompts = _prompts(cfg, shared=16)
    gen = 8
    want = _oracle(model, params, prompts, gen)

    eng = _backend(model, params, spec_k=3)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)], realtime=False)
    assert sorted((r.rid, tuple(r.generated)) for r in done) \
        == [(i, tuple(t)) for i, t in enumerate(want)]

    fe = ServeFrontend(_backend(model, params, spec_k=3))
    streams = [fe.submit(p, gen) for p in prompts]
    for s, toks in zip(streams, want):
        assert list(s) == toks
    st = fe.stats()
    assert st["n_completed"] == len(prompts) and st["n_inflight"] == 0


def test_cancel_mid_stream_and_resubmit_reuses_trie(qwen3):
    """cancel() mid-flight ends the stream; already-yielded tokens
    were confirmed (valid prefix of the oracle); resubmitting streams
    the full oracle answer and re-shares the cancelled request's
    prompt pages from the prefix trie."""
    cfg, model, params = qwen3
    prompts = _prompts(cfg, n=2)
    gen = 8
    want = _oracle(model, params, prompts, gen)
    eng = _backend(model, params)
    fe = ServeFrontend(eng)
    s0, s1 = (fe.submit(p, gen) for p in prompts)
    it = iter(s0)
    head = [next(it) for _ in range(3)]
    assert head == want[0][:3]
    shared_before = eng.cache.n_shared_tokens
    assert s0.cancel()
    assert not s0.cancel()                     # idempotent
    with pytest.raises(StopIteration):
        next(it)
    assert list(s1) == want[1]                 # unaffected neighbor
    s0b = fe.submit(prompts[0], gen)
    assert list(s0b) == want[0]
    # the resubmitted prompt re-shared pages the first attempt donated
    assert eng.cache.n_shared_tokens > shared_before
    assert fe.stats()["n_cancelled"] == 1


def test_cancel_while_queued():
    """Cancelling a not-yet-dispatched stream removes it before it
    ever reaches the backend."""
    be = FakeBackend(capacity=1)
    fe = ServeFrontend(be)
    s0 = fe.submit([1, 2], 3)
    s1 = fe.submit([3, 4], 3)
    assert s1.cancel()
    list(s0)
    assert not fe.busy and be.dispatch_order == [s0.rid]
    assert s1.cancelled and list(s1) == []


def test_async_consumption(qwen3):
    cfg, model, params = qwen3
    prompts = _prompts(cfg, n=3)
    gen = 6
    want = _oracle(model, params, prompts, gen)

    async def go():
        fe = ServeFrontend(_backend(model, params))
        task = asyncio.create_task(fe.serve())

        async def consume(p):
            return [t async for t in fe.submit(p, gen)]

        outs = await asyncio.gather(*(consume(p) for p in prompts))
        fe.close()
        await task
        return outs

    assert asyncio.run(go()) == want


# ------------------------------------------------------------- policy
def test_wfq_weighted_share():
    """Equal-cost backlogs from two tenants dispatch ~proportionally
    to their weights (stride scheduling, capacity-1 backend)."""
    be = FakeBackend(capacity=1)
    fe = ServeFrontend(be, tenants={"gold": TenantPolicy(weight=3.0),
                                    "free": TenantPolicy(weight=1.0)})
    streams = [fe.submit([1, 2, 3, 4], 2, tenant=t)
               for t in ("gold", "free") for _ in range(12)]
    fe.drain()
    assert all(s.finished for s in streams)
    first16 = be.dispatch_order[:16]
    # rids 0..11 are gold, 12..23 free
    gold = sum(1 for rid in first16 if rid < 12)
    assert 10 <= gold <= 13, first16    # ~12/16 = weight 3 of 4


def test_wfq_idle_tenant_earns_no_credit():
    """A tenant that sat idle while another streamed does not get an
    unbounded catch-up burst: it re-joins at the current virtual clock
    and shares from there on."""
    be = FakeBackend(capacity=1)
    fe = ServeFrontend(be, tenants={"a": TenantPolicy(),
                                    "b": TenantPolicy()})
    for _ in range(6):
        fe.submit([1, 2], 2, tenant="a")
    for _ in range(4):                   # a streams alone for a while
        fe.pump()
    for _ in range(6):
        fe.submit([1, 2], 2, tenant="b")
    fe.drain()
    tail = be.dispatch_order[-8:]
    a_tail = sum(1 for rid in tail if rid < 6)
    assert 2 <= a_tail <= 6, be.dispatch_order   # interleaved, no b-burst


def test_rate_limit_throttles_sustained_load():
    """A rate-limited tenant overdraws once, then waits out its debt:
    admissions are spaced by cost/rate in clock units, while an
    unlimited tenant proceeds freely."""
    be = FakeBackend(capacity=2)
    fe = ServeFrontend(be, tenants={
        "lim": TenantPolicy(rate=1.0),    # 1 cost unit per step
        "unl": TenantPolicy()})
    cost = 4 + 2                          # prompt 4 + gen 2
    lim = [fe.submit([1, 2, 3, 4], 2, tenant="lim") for _ in range(3)]
    unl = [fe.submit([1, 2, 3, 4], 2, tenant="unl") for _ in range(3)]
    t_lim, t_unl = [], []
    step = 0
    while fe.busy:
        step += 1
        n_before = len(be.dispatch_order)
        fe.pump(now=float(step))
        for rid in be.dispatch_order[n_before:]:
            (t_lim if any(s.rid == rid for s in lim)
             else t_unl).append(step)
    assert all(s.finished for s in lim + unl)
    # unlimited tenant admitted as fast as capacity allowed
    assert t_unl[-1] - t_unl[0] <= 4
    # limited tenant: successive admissions spaced by ~cost/rate (the
    # initial burst credit — one clock unit's worth — shaves at most
    # burst/rate off the first gap)
    gaps = [b - a for a, b in zip(t_lim, t_lim[1:])]
    assert all(g >= cost - 1 for g in gaps), (t_lim, gaps)


def test_slo_interactive_preempts_batch():
    """With every slot full of batch work, an interactive arrival
    preempts the cheapest-to-replay victim, which later resumes and
    still finishes; slo_aware=False leaves batch work alone."""
    for aware, expect_preempt in ((True, 1), (False, 0)):
        be = FakeBackend(capacity=2)
        fe = ServeFrontend(be, slo_aware=aware)
        batch = [fe.submit([1, 2], 8) for _ in range(2)]
        fe.pump()                         # both dispatched, 1 token each
        inter = fe.submit([3, 4], 2, slo_class="interactive")
        fe.drain()
        assert fe.stats()["n_slo_preemptions"] == expect_preempt
        assert all(s.finished for s in batch + [inter])
        if aware:
            # victim kept its confirmed tokens and finished its budget
            victim = min(batch, key=lambda s: s.rid)
            assert len(victim.req.generated) == 8
            assert victim.req.n_preemptions == 1
            # interactive finished before the preempted victim resumed
            # its last token
            assert inter.req.finish_time <= victim.req.finish_time


def test_slo_preemption_parity_on_engine(qwen3):
    """SLO preemption on the real engine: the preempted batch request
    replays and still matches the oracle bitwise."""
    cfg, model, params = qwen3
    prompts = _prompts(cfg, n=3)
    gen = 16
    want = _oracle(model, params, prompts, gen)
    fe = ServeFrontend(_backend(model, params, batch=2))
    b0 = fe.submit(prompts[0], gen)
    b1 = fe.submit(prompts[1], gen)
    for _ in range(3):                    # let batch work get going
        fe.pump()
    hi = fe.submit(prompts[2], gen, slo_class="interactive")
    fe.drain()
    assert fe.stats()["n_slo_preemptions"] >= 1
    assert [list(b0), list(b1), list(hi)] == want


def test_submit_rejects_inadmissible(qwen3):
    cfg, model, params = qwen3
    fe = ServeFrontend(_backend(model, params))
    with pytest.raises(ValueError):
        fe.submit(np.zeros(100000, np.int32), 4)      # never fits
    with pytest.raises(ValueError):
        fe.submit([1, 2], 4, slo_class="platinum")    # unknown class
    with pytest.raises(ValueError):
        fe.submit([1, 2], 4, rid=fe.submit([3, 4], 2).rid)
