"""Per-kernel validation: shape/dtype sweeps in interpret mode against
the pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import gemm_dataflow as gd
from repro.kernels import block_sparse as bs
from repro.kernels import lut_activation as lut
from repro.kernels import flash_attention as fa


def rnd(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------- gemm
@pytest.mark.parametrize("dataflow", list(gd.Dataflow))
@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 128, 384),
                                   (200, 130, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_dataflow(dataflow, m, n, k, dtype):
    a = rnd(0, (m, k), dtype)
    b = rnd(1, (k, n), dtype)
    got = gd.matmul(a, b, dataflow, bm=128, bn=128, bk=128, interpret=True)
    want = gd.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


def test_gemm_traffic_ordering_matches_paper():
    """All-Reuse < Ifmap/Filter < No-Reuse (paper Table 6 / Fig 13)."""
    m = n = k = 2048
    t = {df: gd.modeled_traffic(m, n, k, df)["total_bytes"]
         for df in gd.Dataflow}
    assert t[gd.Dataflow.OUTPUT_STATIONARY] < t[gd.Dataflow.INPUT_STATIONARY]
    assert t[gd.Dataflow.OUTPUT_STATIONARY] < t[gd.Dataflow.WEIGHT_STATIONARY]
    assert t[gd.Dataflow.INPUT_STATIONARY] < t[gd.Dataflow.NO_REUSE]
    assert t[gd.Dataflow.WEIGHT_STATIONARY] < t[gd.Dataflow.NO_REUSE]


# ---------------------------------------------------------- block sparse
@pytest.mark.parametrize("density", [0.0, 0.25, 0.6, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_sparse(density, dtype):
    m, k, n = 128, 512, 384
    bm = bk = bn = 128
    a = rnd(2, (m, k), dtype)
    b = rnd(3, (k, n), dtype)
    rng = np.random.default_rng(0)
    mask = rng.random((k // bk, n // bn)) < density
    got = bs.matmul(a, b, mask, bm=bm, bn=bn, bk=bk, interpret=True)
    want = bs.matmul_block_sparse_ref(a, b, jnp.asarray(mask), bk, bn)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 8)


def test_block_sparse_savings():
    mask = np.array([[1, 0], [0, 0], [1, 1]], bool)
    s = bs.sparse_savings(mask)
    assert s["tiles_live"] == 3
    assert abs(s["flops_saved_frac"] - 0.5) < 1e-9


# ------------------------------------------------------------------ lut
@pytest.mark.parametrize("name", ["sigmoid", "tanh", "gelu", "exp"])
def test_lut_activation(name):
    x = jnp.linspace(-7.9, 7.9, 512 * 256).reshape(512, 256)
    got = lut.apply_lut(x, name, interpret=True)
    want = lut.lut_ref(x, lut.table_for(name))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)   # bit-exact vs oracle
    # close to the exact function (16-bit grid accuracy, paper §3.9)
    exact = lut.TABLES[name](x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                               atol=2e-3, rtol=1e-2)


def test_lut_exactness_on_grid():
    """Exact for 16-bit-quantized inputs — the paper's accuracy claim."""
    idx = jnp.arange(0, 1 << 16, 257)
    x = (idx.astype(jnp.float32) * (16.0 / (1 << 16)) - 8.0).reshape(1, -1)
    x = jnp.pad(x, ((0, 0), (0, 256 - x.shape[1] % 256)))
    got = lut.apply_lut(x, "tanh", interpret=True)
    want = jnp.tanh(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


# ------------------------------------------------------------ attention
@pytest.mark.parametrize("sq,skv,h,kvh,d", [
    (256, 256, 4, 4, 64),
    (256, 512, 8, 2, 64),     # GQA + longer kv (prefill-style)
    (512, 512, 4, 1, 128),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(sq, skv, h, kvh, d, causal, dtype):
    # causal + sq < skv is the ragged-offset case: queries sit at the
    # END of kv (attention_ref's tril(k=skv-sq)), which the kernel
    # expresses as q_offset = skv - sq
    q_offset = skv - sq if causal else 0
    b = 2
    q = rnd(4, (b, sq, h, d), dtype)
    k = rnd(5, (b, skv, kvh, d), dtype)
    v = rnd(6, (b, skv, kvh, d), dtype)
    got = fa.attention(q, k, v, causal=causal, bq=128, bkv=128,
                       q_offset=q_offset, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    want = fa.attention_ref(qf, kf, vf, causal=causal)
    want = want.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_attention():
    """Kernel agrees with the model-side chunked-flash jnp path."""
    from repro.models.components import flash_attention as model_flash
    b, s, h, kvh, d = 2, 256, 8, 2, 64
    q = rnd(7, (b, s, h, d), jnp.float32)
    k = rnd(8, (b, s, kvh, d), jnp.float32)
    v = rnd(9, (b, s, kvh, d), jnp.float32)
    got = fa.attention(q, k, v, causal=True, bq=128, bkv=128,
                       interpret=True)
    want = model_flash(q, k, v, causal=True, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
