"""Fault tolerance: seeded fault injection (serve/faults.py), the
recovery journal (serve/recovery.py), the router's FAILED path
(crash + stall watchdog), elastic crash repair, and front-end load
shedding under degraded capacity.

Unit tests run the injector/journal against a model-free dummy; the
integration tests drive real engines and hold recovered streams to the
same bar as everything else in the stack: bitwise parity with the
sequential greedy oracle, every stream delivered exactly once, and the
fleet dispatch identity intact after the crash-fold.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import (
    ElasticController, ElasticPolicy, FaultInjector, ReplicaFailure,
    Request, RequestJournal, RequestRouter, ServeEngine, ServeFrontend,
    ServePrograms, ShedRejection, StreamEvent, greedy_generate,
    parse_fault_spec,
)

GEN = 6


# ================================================== unit: FaultInjector
class _Dummy:
    """Minimal ServeBackend stand-in: each step retires one request."""

    capacity = 4

    def __init__(self, n=3):
        self.uid = "d0"
        self.n_stepped = 0
        self._inflight = n
        self.finished = []

    @property
    def n_inflight(self):
        return self._inflight

    def check_admissible(self, req):
        pass

    def submit(self, req):
        self._inflight += 1

    def step(self, now=float("inf")):
        self.n_stepped += 1
        if self._inflight:
            self._inflight -= 1
        return bool(self._inflight)

    def drain_events(self):
        return []

    def extract(self, rid):
        return None

    def extract_all(self):
        return []

    def cancel(self, rid):
        return False

    def stats(self):
        return {"n_steps": float(self.n_stepped)}


def test_injector_crash_is_permanent():
    d = _Dummy(5)
    inj = FaultInjector(d, crash_at=3)
    assert inj.step() and inj.step()          # steps 1-2 pass through
    assert d.n_stepped == 2
    with pytest.raises(ReplicaFailure) as ei:
        inj.step()
    assert ei.value.kind == "crash" and inj.dead
    assert d.n_stepped == 2                   # crash fired BEFORE work
    # dead = unresponsive: the whole protocol raises from here on
    for call in (inj.step, lambda: inj.submit(None), inj.drain_events,
                 lambda: inj.extract(0), inj.extract_all,
                 lambda: inj.cancel(0),
                 lambda: inj.check_admissible(None)):
        with pytest.raises(ReplicaFailure):
            call()
    # ... except externally-scraped surfaces the crash-fold needs
    assert inj.stats() == {"n_steps": 2.0}
    assert inj.n_inflight == 3 and inj.capacity == 4
    inj.mark_dead("stall")                    # idempotent, keeps kind
    assert inj.fault_kind == "crash"


def test_injector_stall_window_heals():
    d = _Dummy(2)
    inj = FaultInjector(d, stall_at=2, stall_for=2)
    assert inj.step() is True                 # step 1 delegates
    assert inj.step() is True and inj.stalled  # steps 2-3: wedged but
    assert inj.step() is True and inj.stalled  # busy (work is held)
    assert d.n_stepped == 1                   # no progress in-window
    assert inj.step() is False                # step 4: healed, drains
    assert d.n_stepped == 2 and not inj.stalled and not inj.dead


def test_injector_seeded_schedules_replay():
    for seed in range(20):
        a = FaultInjector.seeded(_Dummy(), seed)
        b = FaultInjector.seeded(_Dummy(), seed)
        assert (a.crash_at, a.stall_at, a.stall_for) \
            == (b.crash_at, b.stall_at, b.stall_for)
    kinds = {("crash" if FaultInjector.seeded(_Dummy(), s).crash_at
              is not None else "stall") for s in range(20)}
    assert kinds == {"crash", "stall"}        # both arms get exercised


def test_injector_validation():
    with pytest.raises(ValueError):
        FaultInjector(_Dummy(), stall_at=1, stall_for=-1)
    with pytest.raises(ValueError):
        FaultInjector(_Dummy(), stall_for=3)  # stall_for sans stall_at


def test_parse_fault_spec():
    assert parse_fault_spec("0:crash@12, 1:stall@8x5") == [
        (0, {"crash_at": 12}), (1, {"stall_at": 8, "stall_for": 5})]
    assert parse_fault_spec("2:stall@6") == \
        [(2, {"stall_at": 6, "stall_for": 4})]
    assert parse_fault_spec("") == []
    for bad in ("0:boom@3", "crash@3", "0:crash", "0@crash:3"):
        with pytest.raises(ValueError):
            parse_fault_spec(bad)


# ================================================ unit: RequestJournal
def _req(rid, arrival=0.0, plen=4):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                   max_new_tokens=8, arrival=arrival)


def test_journal_tracks_confirmed_frontier_and_reconstructs():
    j = RequestJournal()
    r1, r2, r4 = _req(1, arrival=1.0), _req(2, arrival=0.5), \
        _req(4, arrival=0.2)
    for r in (r1, r2, r4):
        j.assign(r, 0)
    j.observe([StreamEvent(rid=1, tokens=(5, 6), finished=False),
               StreamEvent(rid=2, tokens=(9,), finished=True),
               StreamEvent(rid=3, tokens=(7,), finished=False)])
    assert j.entry(1).confirmed == 2
    assert 2 not in j                 # finished streams need no recovery
    assert 3 not in j                 # unknown rids are ignored
    r1.generated.extend([5, 6, 7])    # 7 generated but never drained
    lost = j.lost(0)
    assert [e.req.rid for e in lost] == [4, 1]     # oldest-first
    assert len(j) == 0
    req, burden = RequestJournal.reconstruct(lost[1])
    assert req is r1                  # the SAME object, rebuilt in place
    assert r1.generated == [5, 6] and r1.prefill_pos == 0
    assert burden == 1                # replay all but the last confirmed
    req, burden = RequestJournal.reconstruct(lost[0])
    assert burden == 0 and req.generated == []


def test_journal_reassignment_keeps_frontier():
    j = RequestJournal()
    r = _req(7)
    r.generated.extend([1, 2])        # migration-style: arrives mid-stream
    j.assign(r, 0)
    assert j.entry(7).confirmed == 2
    j.unassign(7)                     # re-queued: no location, kept entry
    assert j.lost(0) == [] and 7 in j
    j.assign(r, 1)                    # re-dispatch: frontier persists
    assert j.entry(7).replica == 1 and j.entry(7).confirmed == 2
    j.discard(7)
    j.discard(7)                      # idempotent
    assert 7 not in j


# ======================================================== integration
@pytest.fixture(scope="module")
def qwen3():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def programs(qwen3):
    _, model, _ = qwen3
    return ServePrograms(model)


def _mk(model, params, programs, **kw):
    return ServeEngine(model, params, max_batch=2, n_pages=32,
                       page_size=8, max_pages_per_seq=8, chunk_size=16,
                       programs=programs, **kw)


def _reqs(cfg, n=6, plen=20, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, plen,
                                               dtype=np.int32),
                    max_new_tokens=GEN) for i in range(n)]


def _oracle(model, params, reqs):
    return {r.rid: [int(t) for t in np.asarray(greedy_generate(
        model, params, {"tokens": r.prompt[None]}, r.max_new_tokens,
        cache_len=len(r.prompt) + r.max_new_tokens))[0]]
        for r in reqs}


def _check_parity(done, want):
    for r in done:
        assert r.generated == want[r.rid], f"rid {r.rid} diverged"


def _check_identity(st):
    assert st["n_total_dispatches"] == (
        st["n_prefill_dispatches"] + st["n_decode_steps"]
        + st["n_replay_steps"] - st["n_fused_dispatches"]), st


def _check_streams(events, done):
    """Zero dropped, zero duplicated: concatenating the drained events
    per rid reproduces each finished request's stream exactly, with
    exactly one terminal event."""
    toks, fins = {}, {}
    for ev in events:
        toks.setdefault(ev.rid, []).extend(ev.tokens)
        fins[ev.rid] = fins.get(ev.rid, 0) + bool(ev.finished)
    for r in done:
        assert toks.get(r.rid, []) == list(r.generated), r.rid
        assert fins.get(r.rid, 0) == 1, (r.rid, fins.get(r.rid))


def test_crash_recovery_token_parity(qwen3, programs):
    """A replica that crashes mid-decode loses nothing: its requests
    are rebuilt from the journal, replayed on the survivor, and every
    stream matches the oracle bitwise — with the fleet dispatch
    identity intact after the crash-fold."""
    cfg, model, params = qwen3
    reqs = _reqs(cfg)
    want = _oracle(model, params, reqs)
    inj = FaultInjector(_mk(model, params, programs), crash_at=6)
    router = RequestRouter([inj, _mk(model, params, programs)],
                           policy="round-robin", stall_patience=3)
    done = router.run(reqs)
    assert sorted(r.rid for r in done) == list(range(len(reqs)))
    _check_parity(done, want)
    assert router.n_failures == 1
    assert router.n_recovered_requests >= 1
    assert router.n_recovery_replayed_tokens >= 1
    assert router.failed_rids and len(router.replicas) == 1
    assert router.n_departed == 1
    st = router.stats()
    _check_identity(st)
    assert st["n_replay_steps"] >= router.n_recovery_replayed_tokens
    assert len(router._journal) == 0          # nothing left unprotected
    _check_streams(router.drain_events(), done)


def test_stall_watchdog_fails_wedged_replica(qwen3, programs):
    """A replica that answers but never progresses misses the progress
    deadline, is declared FAILED, and its requests recover with exact
    parity."""
    cfg, model, params = qwen3
    reqs = _reqs(cfg, seed=1)
    want = _oracle(model, params, reqs)
    inj = FaultInjector(_mk(model, params, programs),
                        stall_at=2, stall_for=50)
    router = RequestRouter([inj, _mk(model, params, programs)],
                           policy="round-robin", stall_patience=3)
    done = router.run(reqs)
    assert sorted(r.rid for r in done) == list(range(len(reqs)))
    _check_parity(done, want)
    assert router.n_failures == 1 and len(router.replicas) == 1
    assert inj.dead                  # watchdog made the verdict final
    _check_identity(router.stats())
    _check_streams(router.drain_events(), done)


def test_stall_shorter_than_patience_heals(qwen3, programs):
    """A transient stall below the watchdog threshold is invisible:
    no failure, no recovery, full parity, fleet intact."""
    cfg, model, params = qwen3
    reqs = _reqs(cfg, seed=2)
    want = _oracle(model, params, reqs)
    inj = FaultInjector(_mk(model, params, programs),
                        stall_at=2, stall_for=2)
    router = RequestRouter([inj, _mk(model, params, programs)],
                           policy="round-robin", stall_patience=6)
    done = router.run(reqs)
    assert sorted(r.rid for r in done) == list(range(len(reqs)))
    _check_parity(done, want)
    assert router.n_failures == 0 and router.n_recovered_requests == 0
    assert len(router.replicas) == 2 and not inj.dead
    _check_streams(router.drain_events(), done)


def test_extract_cancel_graceful_on_dead_replica(qwen3, programs):
    """Regression (the PR's small fix): extract/cancel of a rid living
    on a dead replica — before the router has even noticed the death —
    return None/False instead of raising, and stay idempotent through
    the recovery that follows."""
    cfg, model, params = qwen3
    reqs = _reqs(cfg, seed=3)
    want = _oracle(model, params, reqs)
    inj = FaultInjector(_mk(model, params, programs))
    router = RequestRouter([inj, _mk(model, params, programs)],
                           policy="round-robin", stall_patience=3)
    assert router.extract(999) is None        # unknown rid: graceful
    assert router.cancel(999) is False
    for r in reqs:
        router.submit(r)
    router.step()                             # rids 0,2,4 land on inj
    held = sorted({r.rid for r in (list(inj.waiting)
                                   + list(inj.prefilling.values())
                                   + list(inj.active.values()))})
    assert held == [0, 2, 4]
    inj.mark_dead()                           # dies behind router's back
    assert router.extract(0) is None          # no KeyError, no raise
    assert router.cancel(0) is False
    router.step()                             # detection + recovery
    assert router.n_failures == 1
    assert set(held) <= router.failed_rids
    got = router.extract(0)                   # recovered: now reachable
    assert got is not None and got.rid == 0
    assert got.generated == want[0][:len(got.generated)]
    assert router.cancel(0) is False          # already extracted
    assert router.cancel(2) is True
    assert router.cancel(2) is False          # idempotent double-cancel
    while router.step():
        pass
    finished = sorted(r.rid for r in router.finished)
    assert finished == [1, 3, 4, 5]           # 0 extracted, 2 cancelled
    _check_parity(router.finished, want)
    assert len(router._journal) == 0


def test_elastic_repair_restores_capacity(qwen3, programs):
    """The controller replaces a crash-lost replica via the factory:
    the fleet returns to min_replicas, degradation clears, and a
    front-end accepts batch work again afterwards."""
    cfg, model, params = qwen3
    reqs = _reqs(cfg, seed=4)
    want = _oracle(model, params, reqs)

    def mk():
        return _mk(model, params, programs)

    inj = FaultInjector(mk(), crash_at=4)
    router = RequestRouter([inj, mk()], policy="round-robin",
                           stall_patience=3)
    ctrl = ElasticController(router, mk, policy=ElasticPolicy(
        min_replicas=2, max_replicas=2, scale_interval=64,
        repair_backoff=1))
    done = ctrl.run(reqs)
    assert sorted(r.rid for r in done) == list(range(len(reqs)))
    _check_parity(done, want)
    assert router.n_failures == 1
    assert ctrl.n_repairs == 1 and ctrl.n_repair_failures == 0
    assert not ctrl.degraded and len(router.replicas) == 2
    _check_identity(ctrl.stats())
    # repaired fleet takes batch work at the front door again
    fe = ServeFrontend(ctrl)
    extra = Request(rid=100, prompt=reqs[0].prompt,
                    max_new_tokens=GEN, slo_class="batch")
    s = fe.submit_request(extra)
    fe.drain()
    assert s.finished and list(s) == want[0]


def test_repair_backoff_and_bounded_budget(qwen3, programs):
    """A persistently failing factory spends the bounded retry budget
    under exponential backoff and then stops; the fleet stays degraded
    but the survivors still finish every stream exactly."""
    cfg, model, params = qwen3
    reqs = _reqs(cfg, seed=5)
    want = _oracle(model, params, reqs)
    calls = []

    def bad_factory():
        calls.append(1)
        raise RuntimeError("no capacity for a replacement")

    inj = FaultInjector(_mk(model, params, programs), crash_at=3)
    router = RequestRouter([inj, _mk(model, params, programs)],
                           policy="round-robin", stall_patience=3)
    ctrl = ElasticController(router, bad_factory, policy=ElasticPolicy(
        min_replicas=2, max_replicas=2, scale_interval=1000,
        repair_backoff=1, repair_budget=2))
    done = ctrl.run(reqs)
    assert sorted(r.rid for r in done) == list(range(len(reqs)))
    _check_parity(done, want)
    assert router.n_failures == 1
    assert ctrl.n_repairs == 0
    assert ctrl.n_repair_failures == 2 == len(calls)  # budget-bounded
    assert ctrl.degraded and len(router.replicas) == 1


def test_frontend_sheds_batch_while_degraded(qwen3, programs):
    """Graceful degradation at the front door: while the fleet sits
    below its replica floor, batch-class submits get a typed
    ShedRejection and interactive traffic keeps flowing — and every
    accepted stream still finishes with exact parity."""
    cfg, model, params = qwen3
    reqs = _reqs(cfg, n=7, seed=6)
    want = _oracle(model, params, reqs)

    def bad_factory():
        raise RuntimeError("no capacity")

    inj = FaultInjector(_mk(model, params, programs), crash_at=2)
    router = RequestRouter([inj, _mk(model, params, programs)],
                           policy="round-robin", stall_patience=3)
    ctrl = ElasticController(router, bad_factory, policy=ElasticPolicy(
        min_replicas=2, max_replicas=2, scale_interval=1000,
        repair_budget=0))
    fe = ServeFrontend(ctrl)
    live = [fe.submit(reqs[i].prompt, GEN, rid=i,
                      slo_class="interactive") for i in range(4)]
    live.append(fe.submit(reqs[4].prompt, GEN, rid=4,
                          slo_class="batch"))   # pre-crash: accepted
    while not ctrl.degraded and fe.busy:
        fe.pump()
    assert ctrl.degraded
    with pytest.raises(ShedRejection) as ei:
        fe.submit(reqs[5].prompt, GEN, rid=5, slo_class="batch")
    assert ei.value.rid == 5 and ei.value.slo_class == "batch"
    live.append(fe.submit(reqs[6].prompt, GEN, rid=6,
                          slo_class="interactive"))  # still flows
    fe.drain()
    assert fe.n_shed == 1 and fe.stats()["n_shed"] == 1.0
    assert all(s.finished for s in live)
    for s in live:
        assert list(s) == want[s.rid], f"rid {s.rid} diverged"
