"""Loop-aware HLO cost model: validated against XLA's cost_analysis on
loop-free programs, and against known trip counts on scans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.launch import hlo_cost


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_cost(c):
    """``compiled.cost_analysis()`` returns a one-element list on the
    pinned jax 0.4.37 and a bare dict on newer versions."""
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_matches_cost_analysis_on_plain_matmul():
    xs = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(lambda a, b: a @ b, xs, xs)
    ours = hlo_cost.analyze_module(c.as_text(), 1)
    theirs = _xla_cost(c)
    assert ours.flops == pytest.approx(theirs["flops"], rel=0.01)


def test_scan_flops_multiplied_by_trip_count():
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)

    def f(x, w):
        return lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
    c = _compile(f, xs, ws)
    ours = hlo_cost.analyze_module(c.as_text(), 1)
    want = 12 * 2 * 128 ** 3
    assert ours.flops == pytest.approx(want, rel=0.05)
    # XLA's own analysis undercounts by the trip count — the reason
    # this module exists:
    assert _xla_cost(c)["flops"] < want / 6


def test_scan_carry_bytes_not_inflated_by_buffer():
    """dus-rooted fusions must count the update, not the whole stacked
    output buffer, per iteration."""
    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)

    def f(x, w):
        return lax.scan(lambda c, wi: (c @ wi, c.sum()), x, w)
    c = _compile(f, xs, ws)
    ours = hlo_cost.analyze_module(c.as_text(), 1)
    # loose upper bound: per iter ~ 3 x (128x128x4) + eps; 64 iters
    per_iter = 6 * 128 * 128 * 4
    assert ours.bytes < 64 * per_iter * 4


def test_collectives_counted_with_ring_factors():
    hlo = """
HloModule m

ENTRY %main.1 (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%p0), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %ar = f32[64,64]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
}
"""
    c = hlo_cost.analyze_module(hlo, 8)
    size = 64 * 64 * 4
    assert c.coll_bytes["all-gather"] == pytest.approx(size * 3 / 4)
    assert c.coll_bytes["all-reduce"] == pytest.approx(2 * size * 3 / 4)
    assert c.coll_ops["all-gather"] == 1


def test_collectives_inside_loops_multiplied():
    hlo = """
HloModule m

%body (t: (s32[], f32[32])) -> (s32[], f32[32]) {
  %t = (s32[], f32[32]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[32] get-tuple-element(%t), index=1
  %ar = f32[32]{0} all-reduce(%x), replica_groups=[1,8]<=[8]
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %r = (s32[], f32[32]) tuple(%ni, %ar)
}

%cond (t: (s32[], f32[32])) -> pred[] {
  %t = (s32[], f32[32]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main.2 (p: f32[32]) -> (s32[], f32[32]) {
  %p = f32[32]{0} parameter(0)
  %z = s32[] constant(0)
  %t = (s32[], f32[32]) tuple(%z, %p)
  ROOT %w = (s32[], f32[32]) while(%t), condition=%cond, body=%body
}
"""
    c = hlo_cost.analyze_module(hlo, 8)
    assert c.coll_ops["all-reduce"] == 5      # trip count from condition
    assert c.coll_bytes["all-reduce"] == pytest.approx(
        5 * 2 * 32 * 4 * 7 / 8)


def test_transcendentals_and_elementwise():
    xs = jax.ShapeDtypeStruct((1024,), jnp.float32)
    c = _compile(lambda x: jnp.tanh(x) + x * 2, xs)
    ours = hlo_cost.analyze_module(c.as_text(), 1)
    assert ours.transcendentals >= 1024
    assert ours.flops >= 2 * 1024
