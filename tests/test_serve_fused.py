"""Dispatch accounting for the fused engine step.

Pins the economic claim of the fused uber-program at the counter level
(token-level parity is the fuzzer's job, tests/test_serve_fuzz.py):

* every steady-state mixed step — decode work AND a prefill chunk in
  flight — is exactly ONE program launch (``n_total_dispatches`` +1,
  ``n_fused_dispatches`` +1);
* with ``fused=False`` the engine reproduces the PR 5 two-dispatch
  counts exactly (pinned trace, pinned numbers);
* degenerate mixes (prefill-only ramp, decode-only tail) never fuse and
  match the unfused engine dispatch-for-dispatch;
* the counter identity holds after any run:
  ``total = prefill_dispatches + decode_steps + replay_steps - fused``
  (each fused launch is counted once in total but carries one prefill
  dispatch and one decode step).
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import Request, ServeEngine
from repro.serve.step import ServePrograms

KEYS = ["n_prefill_dispatches", "n_prefill_chunks", "n_decode_steps",
        "n_replay_steps", "n_fused_dispatches", "n_total_dispatches"]


@pytest.fixture(scope="module")
def bundle():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, ServePrograms(model)


def _prompts(cfg, n, length, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         size=(length,)).astype(np.int32)
            for _ in range(n)]


def _drive(engine, prompts, gen):
    """Run to drain; returns (final stats, per-step counter deltas,
    {rid: tokens})."""
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=gen,
                              arrival=0.0))
    deltas, prev, steps = [], {k: 0 for k in KEYS}, 0
    while engine.step(now=0.0):
        cur = {k: engine.stats()[k] for k in KEYS}
        deltas.append({k: cur[k] - prev[k] for k in KEYS})
        prev = cur
        steps += 1
        assert steps < 500
    stats = engine.stats()
    ident = (stats["n_prefill_dispatches"] + stats["n_decode_steps"]
             + stats["n_replay_steps"] - stats["n_fused_dispatches"])
    assert stats["n_total_dispatches"] == ident, \
        "counter identity total = prefill + decode + replay - fused"
    return stats, deltas, {r.rid: list(r.generated)
                           for r in engine.finished}


def _engine(model, params, programs, *, fused, prefill_batch=2):
    return ServeEngine(model, params, fused=fused, programs=programs,
                       max_batch=4, n_pages=64, page_size=8,
                       max_pages_per_seq=8, chunk_size=8,
                       prefill_batch=prefill_batch,
                       prefix_sharing=False)


def test_stats_expose_dispatch_counters(bundle):
    _, model, params, programs = bundle
    s = _engine(model, params, programs, fused=True).stats()
    assert s["n_fused_dispatches"] == 0
    assert s["n_total_dispatches"] == 0


def test_fused_one_launch_per_steady_state_step(bundle):
    """Saturating trace (6 reqs x 16-tok prompts, chunk 8, group 2,
    4 slots): once the batch is warm every mixed step must be a single
    launch."""
    cfg, model, params, programs = bundle
    prompts = _prompts(cfg, 6, 16)
    eng = _engine(model, params, programs, fused=True)
    stats, deltas, toks = _drive(eng, prompts, gen=6)

    fused_steps = [d for d in deltas if d["n_fused_dispatches"]]
    assert len(fused_steps) == 4
    for d in fused_steps:
        # one fused launch covers that step's chunk AND decode work
        assert d["n_fused_dispatches"] == 1
        assert d["n_decode_steps"] == 1
        assert d["n_prefill_dispatches"] >= 1
    # steady state proper (past the first step's admission ramp, which
    # legitimately runs standalone chunk dispatches while no request
    # is decoding yet): ONE launch per step, the tentpole claim
    assert [d["n_total_dispatches"] for d in fused_steps[1:]] \
        == [1, 1, 1]
    # full-run pins for this trace
    assert stats["n_fused_dispatches"] == 4
    assert stats["n_prefill_dispatches"] == 6
    assert stats["n_prefill_chunks"] == 12
    assert stats["n_total_dispatches"] == 14
    assert set(toks) == set(range(6))


def test_unfused_reproduces_two_dispatch_counts(bundle):
    """Same trace, ``fused=False``: exact PR 5 batched-prefill + PR 3
    decode counts — 6 chunk dispatches (3 groups x 2 chunks), 11 decode
    steps, nothing fused, 17 total launches."""
    cfg, model, params, programs = bundle
    prompts = _prompts(cfg, 6, 16)
    eng = _engine(model, params, programs, fused=False)
    stats, deltas, _ = _drive(eng, prompts, gen=6)
    assert stats["n_fused_dispatches"] == 0
    assert stats["n_prefill_dispatches"] == 6
    assert stats["n_prefill_chunks"] == 12
    assert stats["n_decode_steps"] == 11
    assert stats["n_replay_steps"] == 0
    assert stats["n_total_dispatches"] == 17
    assert all(d["n_fused_dispatches"] == 0 for d in deltas)


def test_fused_and_unfused_stream_identically(bundle):
    cfg, model, params, programs = bundle
    prompts = _prompts(cfg, 6, 16)
    runs = {}
    for fused in (True, False):
        eng = _engine(model, params, programs, fused=fused)
        _, _, runs[fused] = _drive(eng, prompts, gen=6)
    assert runs[True] == runs[False]


def test_degenerate_mixes_match_unfused_dispatch_for_dispatch(bundle):
    """prefill_batch >= n requests: the whole trace is a prefill-only
    ramp followed by a decode-only tail — no step is mixed, so the
    fused engine must fall back to the standalone programs and produce
    byte-identical counters to the unfused engine."""
    cfg, model, params, programs = bundle
    prompts = _prompts(cfg, 3, 16, seed=11)
    stats = {}
    for fused in (True, False):
        eng = _engine(model, params, programs, fused=fused,
                      prefill_batch=3)
        stats[fused], _, _ = _drive(eng, prompts, gen=5)
    assert stats[True]["n_fused_dispatches"] == 0
    assert stats[True] == stats[False]
