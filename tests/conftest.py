"""Suite-wide setup: make ``import hypothesis`` always resolvable.

Real hypothesis (a declared test dependency, see pyproject.toml) is
preferred; hermetic environments without it fall back to the minimal
deterministic shim so all test modules still collect and run.
"""
import importlib.util
import sys
from pathlib import Path

try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", Path(__file__).parent / "_hypothesis_fallback.py")
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
