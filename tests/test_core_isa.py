"""ISA + ExeBlock IR + interpreter unit/property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isa
from repro.core.exeblock import ExeBlock, ExecutionGraph, Task
from repro.core.interpreter import MachineState, run_graph
from repro.core.isa import Instr, Op, Stage


# ---------------------------------------------------------------- encoding
@given(
    op=st.sampled_from(list(Op)),
    f0=st.integers(0, 0xFFFF), f1=st.integers(0, 0xFFFF),
    f2=st.integers(0, 0xFFFF),
    inc=st.integers(0, 0xFF), lut=st.integers(0, 0xF),
)
@settings(max_examples=300)
def test_encode_decode_roundtrip(op, f0, f1, f2, inc, lut):
    if op is not Op.ST:
        lut = 0
    ins = Instr(op, f0=f0, f1=f1, f2=f2, sparse_pc_inc=inc, lookup_type=lut)
    assert isa.decode(isa.encode(ins)) == ins


def test_instruction_count_is_eleven():
    assert len(Op) == 11  # the Very-RISC ISA has exactly 11 instructions


def test_every_op_has_exactly_one_stage():
    assert set(isa.OP_STAGE) == set(Op)


def test_lut_only_on_st():
    with pytest.raises(ValueError):
        Instr(Op.ADD, lookup_type=3)


def test_field_range_checks():
    with pytest.raises(ValueError):
        Instr(Op.ADD, f0=1 << 16)
    with pytest.raises(ValueError):
        Instr(Op.ADD, sparse_pc_inc=256)


# ---------------------------------------------------------------- exeblock
def test_stage_order_enforced():
    with pytest.raises(ValueError):
        ExeBlock("b", [Instr(Op.ADD), isa.make_ld(0, 0)])


def test_stage_pcs():
    b = ExeBlock("b", [isa.make_ld(0, 0), isa.make_ld(1, 1),
                       Instr(Op.ADD, f0=0, f1=1, f2=2),
                       isa.make_st(2, 9)])
    assert b.stage_pcs.range(Stage.LD) == range(0, 2)
    assert b.stage_pcs.range(Stage.CAL) == range(2, 3)
    assert not b.stage_pcs.has(Stage.FLOW)
    assert b.stage_pcs.range(Stage.ST) == range(3, 4)


def test_max_successors():
    with pytest.raises(ValueError):
        ExeBlock("b", [], successors=["a", "b", "c", "d"])


def test_task_cycle_detection():
    a = ExeBlock("a", [], successors=["b"])
    b = ExeBlock("b", [], successors=["a"])
    t = Task(task_id=0, blocks=[a, b])
    with pytest.raises(ValueError):
        t.topo_order()


# ------------------------------------------------------------- interpreter
def _graph_of(instrs, **kw):
    b = ExeBlock("b", instrs, **kw)
    return ExecutionGraph("g", [Task(task_id=0, blocks=[b])])


def test_ld_cal_st_roundtrip():
    state = MachineState(n_pes=4)
    state.dram_write(0, np.full(8, 3.0, np.float32))
    state.dram_write(1, np.full(8, 4.0, np.float32))
    g = _graph_of([isa.make_ld(0, 0), isa.make_ld(1, 1),
                   Instr(Op.MADD, f0=0, f1=1, f2=2),
                   isa.make_st(2, 100)])
    run_graph(g, state)
    np.testing.assert_allclose(state.dram_read(100), 12.0)


@given(st.lists(st.sampled_from([Op.ADD, Op.SUB, Op.MUL, Op.MAX, Op.MIN,
                                 Op.MADD]), min_size=1, max_size=12),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=60, deadline=None)
def test_random_cal_chains_match_numpy(ops, seed):
    """Random CAL chains over 4 OPM slots == straight numpy evaluation."""
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(4, 8)).astype(np.float32)
    opm = vals.copy()
    instrs = []
    addrs = rng.integers(0, 4, size=(len(ops), 3))
    for op, (a, b, c) in zip(ops, addrs):
        instrs.append(Instr(op, f0=int(a), f1=int(b), f2=int(c)))
        fa, fb, fc = opm[a].copy(), opm[b].copy(), opm[c].copy()
        if op is Op.ADD:
            opm[c] = fa + fb
        elif op is Op.SUB:
            opm[c] = fa - fb
        elif op is Op.MUL:
            opm[c] = fa * fb
        elif op is Op.MAX:
            opm[c] = np.maximum(fa, fb)
        elif op is Op.MIN:
            opm[c] = np.minimum(fa, fb)
        else:
            opm[c] = fa * fb + fc
    state = MachineState(n_pes=1)
    state.pes[0].opm[:4] = vals
    g = _graph_of(instrs)
    run_graph(g, state)
    np.testing.assert_allclose(state.pes[0].opm[:4], opm, rtol=1e-5)


def test_preread_semantics_one_time_capture():
    """PREREAD captures the value at pre-read time; injected right before
    the consumer it is semantically transparent."""
    state = MachineState(n_pes=1)
    state.pes[0].opm[0, :] = 2.0
    state.pes[0].opm[1, :] = 5.0
    g = _graph_of([Instr(Op.PREREAD0, f0=0),
                   Instr(Op.MUL, f0=0, f1=1, f2=2)])
    run_graph(g, state)
    np.testing.assert_allclose(state.pes[0].opm[2], 10.0)


def test_raw_forwarding_transparent():
    state = MachineState(n_pes=1)
    state.pes[0].opm[0, :] = 1.0
    state.pes[0].opm[1, :] = 2.0
    g = _graph_of([Instr(Op.ADD, f0=0, f1=1, f2=2),    # 3
                   Instr(Op.MUL, f0=2, f1=1, f2=3)])   # immediately reuse
    run_graph(g, state)
    np.testing.assert_allclose(state.pes[0].opm[3], 6.0)


def test_copy_moves_data_between_pes():
    state = MachineState(n_pes=4)
    state.pes[0].opm[7, :] = 42.0
    g = _graph_of([isa.make_copy(7, 9, 3)])
    run_graph(g, state)
    np.testing.assert_allclose(state.pes[3].opm[9], 42.0)


def test_st_with_lut_applies_table():
    from repro.core import lut
    state = MachineState(n_pes=1)
    state.pes[0].opm[0, :] = 0.5
    g = _graph_of([isa.make_st(0, 50, lookup_type=2)])  # tanh
    run_graph(g, state)
    got = state.dram_read(50)
    np.testing.assert_allclose(got, np.tanh(0.5), atol=1 / 256)
    # the table is exact for Q8.8-representable inputs
    np.testing.assert_allclose(got, lut.apply_lookup(2, np.full(8, 0.5)))


def test_sparse_skipping_equals_dense_with_zero_weights():
    """Sparse-PC-Inc skipping == executing with zeroed (pruned) weights."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=(6, 8)).astype(np.float32)
    x = rng.normal(size=(6, 8)).astype(np.float32)
    keep = np.array([True, False, True, True, False, True])
    instrs = ([isa.make_ld(i, i) for i in range(6)]
              + [isa.make_ld(6 + i, 6 + i) for i in range(6)]
              + [isa.make_ld(12, 12)]
              + [Instr(Op.MADD, f0=i, f1=6 + i, f2=12) for i in range(6)]
              + [isa.make_st(12, 99)])

    # dense run with pruned weights zeroed
    state_d = MachineState(n_pes=1)
    wz = np.where(keep[:, None], w, 0.0).astype(np.float32)
    state_d.dram_write_array(0, wz)
    state_d.dram_write_array(6, x)
    run_graph(_graph_of(list(instrs)), state_d)

    # sparse run: skip the pruned MADDs entirely
    b = ExeBlock("b", list(instrs))
    valid = [True] * 13 + list(keep) + [True]
    b.apply_sparse_vector(valid)
    state_s = MachineState(n_pes=1)
    state_s.dram_write_array(0, w)  # un-zeroed weights: skipping must prune
    state_s.dram_write_array(6, x)
    run_graph(ExecutionGraph("g", [Task(task_id=0, blocks=[b])]), state_s)

    np.testing.assert_allclose(state_s.dram_read(99), state_d.dram_read(99),
                               rtol=1e-5)


@given(st.lists(st.booleans(), min_size=1, max_size=40))
@settings(max_examples=100)
def test_sparse_pc_inc_walk_visits_exactly_valid_pcs(bits):
    bits[0] = True
    instrs = [Instr(Op.ADD, f0=0, f1=1, f2=2) for _ in bits]
    b = ExeBlock("b", instrs)
    b.apply_sparse_vector(bits)
    assert b.executed_pcs() == [i for i, v in enumerate(bits) if v]
