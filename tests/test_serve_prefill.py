"""Batched chunked prefill: up to ``prefill_batch`` PREFILLING requests
ingest one prompt chunk each per program dispatch, with token streams
bitwise identical to the serialized one-request-per-dispatch path and
to the sequential ``greedy_generate`` oracle — across burst admission,
ragged prompts straddling chunk boundaries, preemption mid-prefill,
in-burst prefix sharing (the admission-order registration invariant),
and speculative decode downstream."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import Request, ServeEngine, greedy_generate


@pytest.fixture(scope="module")
def qwen3():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def oracle_streams(model, params, prompts, gen):
    return {
        i: np.asarray(greedy_generate(model, params, {"tokens": p[None]},
                                      gen, cache_len=len(p) + gen))[0]
        for i, p in enumerate(prompts)}


def run_engine(model, params, prompts, gen, **kw):
    eng = ServeEngine(model, params, **kw)
    done = eng.run([Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)])
    assert len(done) == len(prompts)
    eng.cache.check_invariants()
    return eng, {r.rid: np.asarray(r.generated, np.int32) for r in done}


def test_burst_admission_coingests_and_matches_oracle(qwen3):
    """A burst of short prompts shares prefill dispatches (the tentpole
    perf property) and every stream still matches the sequential
    oracle bit for bit."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(7)
    lens, gen = [9, 17, 24, 12, 31, 8], 8
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    want = oracle_streams(model, params, prompts, gen)
    kw = dict(max_batch=4, n_pages=40, page_size=8, max_pages_per_seq=8,
              chunk_size=16)
    serial, got_s = run_engine(model, params, prompts, gen,
                               prefill_batch=1, **kw)
    batched, got_b = run_engine(model, params, prompts, gen,
                                prefill_batch=4, **kw)
    for i in want:
        np.testing.assert_array_equal(got_s[i], want[i])
        np.testing.assert_array_equal(got_b[i], want[i])
    # same chunks, fewer program launches; the serialized arm is 1:1
    assert serial.n_prefill_dispatches == serial.n_prefill_chunks
    assert batched.n_prefill_chunks == serial.n_prefill_chunks
    assert batched.n_prefill_dispatches < serial.n_prefill_dispatches
    assert batched.stats()["prefill_rows_mean"] > 1.0


def test_ragged_lengths_straddle_chunk_boundaries(qwen3):
    """Prompt lengths on, one past, and one short of chunk multiples —
    per-row (start, valid) bookkeeping must stay exact when rows of
    different depths share a dispatch."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(13)
    lens, gen, chunk = [15, 16, 17, 32, 33, 31], 6, 16
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    want = oracle_streams(model, params, prompts, gen)
    _, got = run_engine(model, params, prompts, gen, prefill_batch=6,
                        max_batch=6, n_pages=56, page_size=8,
                        max_pages_per_seq=8, chunk_size=chunk)
    for i in want:
        np.testing.assert_array_equal(got[i], want[i],
                                      err_msg=f"request {i} diverged")


def test_preempt_mid_prefill_and_replay_parity(qwen3):
    """Page pressure preempts co-ingesting requests mid-flight; the
    recompute-readmission replay still reproduces the oracle."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(11)
    lens, gen = [30, 28, 18], 8
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]
    want = oracle_streams(model, params, prompts, gen)
    eng, got = run_engine(model, params, prompts, gen, prefill_batch=3,
                          max_batch=3, n_pages=13, page_size=8,
                          max_pages_per_seq=8, prefix_sharing=False)
    assert eng.n_replay_steps >= 1, \
        "trace was sized to force preemption + replay"
    for i in want:
        np.testing.assert_array_equal(got[i], want[i])


def test_prefix_sharing_fires_inside_coingested_burst(qwen3):
    """The admission-order registration invariant survives batching:
    the first of a same-prefix burst ingests alone (the others defer
    until it donates to the trie), so in-burst sharing still fires —
    and the COW forks keep every stream exact."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
    gen = 6
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size,
                                            size=(7,)).astype(np.int32)])
               for _ in range(4)]
    want = oracle_streams(model, params, prompts, gen)
    eng, got = run_engine(model, params, prompts, gen, prefill_batch=4,
                          max_batch=4, n_pages=48, page_size=8,
                          max_pages_per_seq=8, chunk_size=16)
    # requests 1..3 each reuse the 20-token prefix from request 0's
    # registration; co-ingesting them alongside it would have found an
    # empty trie
    assert eng.cache.n_shared_tokens >= 3 * 20
    assert eng.cache.n_cow >= 3
    # sharers co-ingested with each other after deferring: strictly
    # fewer launches than the serialized path's one-per-chunk
    assert eng.n_prefill_dispatches < eng.n_prefill_chunks
    for i in want:
        np.testing.assert_array_equal(got[i], want[i],
                                      err_msg=f"request {i} diverged")


def test_unrelated_burst_does_not_defer(qwen3):
    """Deferral is only for would-be sharers: distinct prompts co-admit
    immediately even with sharing enabled (a probe of the cold trie
    plus pairwise LCPs below the half-page threshold)."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab_size, size=(17,)).astype(np.int32)
               for _ in range(4)]
    eng, _ = run_engine(model, params, prompts, 4, prefill_batch=4,
                        max_batch=4, n_pages=40, page_size=8,
                        max_pages_per_seq=8, chunk_size=16)
    # 4 requests x 2 chunks each, one co-ingested group per wave
    assert eng.stats()["prefill_rows_mean"] >= 2.0


def test_spec_decode_downstream_of_batched_prefill(qwen3):
    """Speculation composes: VERIFYING rounds over slots promoted out
    of one co-ingested burst keep the spec-off streams bit for bit."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
    gen = 8
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size,
                                            size=(7,)).astype(np.int32)])
               for _ in range(4)]
    want = oracle_streams(model, params, prompts, gen)
    eng, got = run_engine(model, params, prompts, gen, prefill_batch=4,
                          spec_k=4, max_batch=4, n_pages=48, page_size=8,
                          max_pages_per_seq=8, chunk_size=16)
    assert eng.n_spec_rounds >= 1
    for i in want:
        np.testing.assert_array_equal(got[i], want[i])


def test_prefill_batch_one_is_the_serialized_path(qwen3):
    """``prefill_batch=1`` (the default) keeps the PR 2 dispatch
    accounting: one request per dispatch, admission gated on an empty
    prefill set."""
    cfg, model, params = qwen3
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in (24, 9)]
    eng, _ = run_engine(model, params, prompts, 4, max_batch=2,
                        n_pages=24, page_size=8, max_pages_per_seq=8,
                        chunk_size=16)
    assert eng.prefill_batch == 1
    assert eng.n_prefill_dispatches == eng.n_prefill_chunks == 3
    assert eng.stats()["prefill_rows_mean"] == 1.0
