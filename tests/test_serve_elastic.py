"""Elastic-fleet conformance: live migration round-trips, graceful
drain semantics, departed-replica stats accounting, and the
demand-driven controller.

The migration story rests on two already-proven mechanisms — exact
recompute-replay (a request's confirmed tokens replay bit-exactly
through any replica's decode program) and trie donation (a prompt
prefix resident on the target rebuilds by refcount attach, not byte
copy).  These tests pin the composition: extract a live population at
random frontiers, re-admit elsewhere, and nothing observable changes
but the serving replica.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serve import (ElasticController, ElasticPolicy, Request,
                         RequestRouter, ServeBackend, ServeEngine)
from repro.serve.step import (ServePrograms, make_decode_step,
                              make_prefill_step)
from test_serve_fuzz import drive_and_check

MAX_LEN = 64          # oracle cache capacity: covers every case below
KNOBS = dict(max_batch=4, page_size=8, n_pages=30, max_pages_per_seq=8,
             chunk_size=8, prefill_batch=2, spec_k=0)


@pytest.fixture(scope="module")
def bundle():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # ONE program bundle for every engine in this module: replicas of
    # one fleet share a compile cache by construction, and the test
    # fleets all serve the same model
    return cfg, model, params, ServePrograms(model)


@pytest.fixture(scope="module")
def oracle(bundle):
    """Sequential greedy oracle with module-cached jits and memoized
    streams — semantically ``greedy_generate`` per request."""
    cfg, model, params, _ = bundle
    prefill = jax.jit(make_prefill_step(model, max_len=MAX_LEN))
    decode = jax.jit(make_decode_step(model))
    memo = {}

    def run(prompt: np.ndarray, gen: int) -> np.ndarray:
        key = (prompt.tobytes(), gen)
        if key not in memo:
            last, cache = prefill(params, {"tokens": prompt[None]})
            tok = np.argmax(np.asarray(last), -1).astype(np.int32)[:,
                                                                   None]
            out = [tok]
            tok = jax.numpy.asarray(tok)
            for _ in range(gen - 1):
                tok, cache = decode(params, cache, tok)
                out.append(np.asarray(tok))
            memo[key] = np.concatenate(out, axis=1)[0]
        return memo[key]
    return run


def _mk(bundle, **over):
    _, model, params, programs = bundle
    return ServeEngine(model, params, programs=programs,
                       **{**KNOBS, **over})


def _trace(cfg, seed, n, gen=(3, 8), lens=(5, 21), arrival=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(int(rng.integers(*lens)),)
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(*gen)),
                    arrival=float(arrival))
            for i in range(n)]


# ------------------------------------------------- migration round-trip
@pytest.mark.parametrize("seed", range(4))
def test_migration_roundtrip_token_exact(bundle, oracle, seed):
    """Extract a random live population (random confirmed-token
    frontiers: some waiting, some mid-prefill, some decoding) and
    re-admit it on a FRESH replica: every stream resumes token-exact,
    and the source pool leaks nothing — every page not pinned by the
    source's prefix trie returns to its free list."""
    cfg = bundle[0]
    rng = np.random.default_rng(300 + seed)
    reqs = _trace(cfg, 400 + seed, int(rng.integers(2, 5)))
    src = _mk(bundle)
    free0 = src.cache.free_pages
    for r in reqs:
        src.submit(r)
    for _ in range(int(rng.integers(1, 7))):      # random frontier
        src.step()
    migrated = src.extract_all()
    # everything left, nothing double-tracked
    assert src.n_inflight == 0
    assert sorted(r.rid for r in migrated) \
        == sorted(r.rid for r in reqs if not r.finished)
    src.cache.check_invariants()
    # the only pages still out are the trie's (the source keeps its
    # prefix cache until retired); refcounts returned to baseline
    assert src.cache.free_pages == free0 - len(src.cache.prefix.pages())
    # fresh replica: confirmed tokens replay, streams finish bitwise
    dst = _mk(bundle)
    done = drive_and_check(dst, sorted(migrated,
                                       key=lambda r: (r.arrival, r.rid)),
                           oracle=oracle)
    for r in reqs:
        assert r.finished and len(r.generated) == r.max_new_tokens
    assert set(done) == {r.rid for r in migrated}


def test_migration_reuses_resident_prefix(bundle, oracle):
    """A migrated request whose prompt prefix is already resident on
    the target rebuilds its prompt pages via TRIE DONATION: the
    re-admission reports shared tokens (a refcount attach), not a
    re-prefill of the shared run."""
    cfg = bundle[0]
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, size=(16,)).astype(np.int32)

    def with_suffix(rid, n):
        sfx = rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
        return Request(rid=rid, prompt=np.concatenate([prefix, sfx]),
                       max_new_tokens=6)
    warm, mover = with_suffix(0, 5), with_suffix(1, 7)
    # target already served a same-prefix request -> prefix resident
    dst = _mk(bundle)
    drive_and_check(dst, [warm], oracle=oracle)
    # source serves the mover past its first confirmed tokens
    src = _mk(bundle)
    src.submit(mover)
    for _ in range(4):
        src.step()
    assert mover.generated, "mover should be mid-decode before moving"
    [got] = src.extract_all()
    assert got is mover
    shared_before = dst.cache.n_shared_tokens
    drive_and_check(dst, [mover], oracle=oracle)
    # donation observed on re-admission: the request saw a prefix hit
    # and the target's shared-token counter grew — no byte copy exists
    # to count, sharing is the only mechanism that can produce this
    assert mover.shared_tokens >= 8        # >= one full page of prefix
    assert dst.cache.n_shared_tokens > shared_before
    np.testing.assert_array_equal(
        np.asarray(mover.generated, np.int32),
        oracle(mover.prompt, mover.max_new_tokens))


def test_migration_no_leak_without_sharing(bundle):
    """With the prefix trie off there is nothing to pin pages:
    extract_all returns the pool to its exact baseline."""
    cfg = bundle[0]
    src = _mk(bundle, prefix_sharing=False)
    free0 = src.cache.free_pages
    for r in _trace(cfg, 11, 3):
        src.submit(r)
    for _ in range(3):
        src.step()
    src.extract_all()
    src.cache.check_invariants()
    assert src.cache.free_pages == free0


# -------------------------------------------------------- drain semantics
def test_draining_replica_accepts_no_new_admissions(bundle, oracle):
    cfg = bundle[0]
    router = RequestRouter([_mk(bundle), _mk(bundle)],
                           policy="least-loaded")
    survivor = router.replicas[1]
    router.drain(0)
    # the DRAINING window is observable before the next step executes
    assert router.is_draining(0) and not router.is_draining(1)
    assert router.n_live == 1
    assert router.capacity == survivor.max_batch
    reqs = _trace(cfg, 21, 4)
    drive_and_check(router, reqs, oracle=oracle)
    # every dispatch went to the survivor; the drained replica is gone
    assert router.replicas == [survivor]
    assert survivor.n_inflight == 0
    assert len(survivor.finished) == len(reqs)
    assert router.stats()["n_routed"] == len(reqs)


def test_drain_migrates_every_inflight_request(bundle, oracle):
    cfg = bundle[0]
    router = RequestRouter([_mk(bundle), _mk(bundle)],
                           policy="least-loaded")
    reqs = _trace(cfg, 22, 6, gen=(6, 10))
    for r in reqs:
        router.submit(r)
    for t in range(3):                       # both replicas now busy
        router.step(now=float(t))
    victim = router.replicas[0]
    inflight = victim.n_inflight
    assert inflight > 0
    router.drain(victim)
    router.step(now=3.0)                     # drain executes here
    assert victim not in router.replicas
    assert victim.n_inflight == 0            # finished or migrated
    assert router.n_migrations == inflight
    # drive the survivors dry; parity for every stream incl. migrated
    t = 4
    while router.step(now=float(t)):
        t += 1
    for r in reqs:
        assert len(r.generated) == r.max_new_tokens
        np.testing.assert_array_equal(
            np.asarray(r.generated, np.int32),
            oracle(r.prompt, r.max_new_tokens))
    assert {r.rid for r in router.finished} == {r.rid for r in reqs}


def test_drain_guards_and_idempotence(bundle):
    router = RequestRouter([_mk(bundle), _mk(bundle)])
    router.drain(0)
    router.drain(0)                          # re-drain: no-op
    assert router.is_draining(0)
    with pytest.raises(ValueError):
        router.drain(1)                      # never empty the fleet
    router.step()
    assert len(router.replicas) == 1 and router.n_departed == 1
    with pytest.raises(ValueError):
        router.drain(0)                      # still the last one


def test_cancel_during_drain_stays_idempotent(bundle, oracle):
    cfg = bundle[0]
    router = RequestRouter([_mk(bundle), _mk(bundle)],
                           policy="least-loaded")
    reqs = _trace(cfg, 23, 4, gen=(6, 10))
    for r in reqs:
        router.submit(r)
    for t in range(2):
        router.step(now=float(t))
    victim = router.replicas[0]
    held = [r.rid for r in list(victim.prefilling.values())
            + list(victim.active.values())]
    assert held
    router.drain(victim)
    # cancel a request the draining replica holds, before the drain
    # pump runs: it must not resurface via migration, and a second
    # cancel finds nothing
    assert router.cancel(held[0]) is True
    assert router.cancel(held[0]) is False
    t = 2
    while router.step(now=float(t)):
        t += 1
    assert router.cancel(held[0]) is False   # still gone post-drain
    done = {r.rid for r in router.finished}
    assert held[0] not in done
    assert done == {r.rid for r in reqs} - {held[0]}
    for r in reqs:                           # parity incl. the prefix
        want = oracle(r.prompt, r.max_new_tokens)
        got = np.asarray(r.generated, np.int32)
        np.testing.assert_array_equal(got, want[:len(got)])


# ------------------------------------------------------ stats accounting
def test_stats_survive_replica_departure(bundle):
    """The satellite fix pinned: a departed replica's counters stay in
    the fleet aggregate, so cumulative counters never regress and the
    dispatch identity holds across membership churn."""
    cfg = bundle[0]
    router = RequestRouter([_mk(bundle), _mk(bundle)],
                           policy="least-loaded")
    reqs = _trace(cfg, 24, 6)
    drive_and_check(router, reqs)
    before = router.stats()
    assert before["n_routed"] == len(reqs)
    router.drain(0)
    router.step()                            # departure happens here
    after = router.stats()
    assert after["n_replicas"] == 1 and after["n_departed"] == 1
    for k in ("n_total_dispatches", "n_prefill_dispatches",
              "n_decode_steps", "n_replay_steps", "n_fused_dispatches",
              "n_engine_steps", "n_routed", "n_shared_tokens"):
        assert after[k] == before[k], f"{k} changed on departure"
    assert after["n_total_dispatches"] == (
        after["n_prefill_dispatches"] + after["n_decode_steps"]
        + after["n_replay_steps"] - after["n_fused_dispatches"])
    # the completion log survives too
    assert {r.rid for r in router.finished} == {r.rid for r in reqs}


# ----------------------------------------------------------- controller
def test_controller_scales_with_demand(bundle, oracle):
    """Burst -> the fleet grows the same control round; trough (long
    tail requests only) -> patience expires and replicas drain, with
    every stream still oracle-exact."""
    cfg = bundle[0]
    short = _trace(cfg, 25, 8, gen=(3, 5))
    long_ = [dataclasses.replace(r, rid=100 + r.rid, max_new_tokens=24)
             for r in _trace(cfg, 26, 2, lens=(5, 12))]
    router = RequestRouter([_mk(bundle)], policy="least-loaded")
    ctl = ElasticController(
        router, lambda: _mk(bundle),
        policy=ElasticPolicy(min_replicas=1, max_replicas=3,
                             scale_interval=2, scale_down_patience=1,
                             alpha=0.8))
    assert isinstance(ctl, ServeBackend)
    drive_and_check(ctl, short + long_, oracle=oracle)
    st = ctl.stats()
    assert st["n_scale_ups"] >= 1, "burst never grew the fleet"
    assert st["n_replicas_peak"] >= 2
    assert st["n_scale_downs"] >= 1, "trough never shrank the fleet"
    assert st["n_migrations"] >= 0   # drains may or may not catch work
    assert st["n_routed"] == len(short) + len(long_) + st["n_migrations"]
    assert len(router.replicas) < st["n_replicas_peak"]


def test_controller_capacity_reports_potential(bundle):
    router = RequestRouter([_mk(bundle)])
    ctl = ElasticController(router, lambda: _mk(bundle),
                            policy=ElasticPolicy(max_replicas=3))
    # a front-end throttling at CURRENT size would starve the control
    # loop of the very demand it scales on
    assert ctl.capacity == 3 * KNOBS["max_batch"]
    assert router.capacity == KNOBS["max_batch"]


def test_policy_validation():
    with pytest.raises(ValueError):
        ElasticPolicy(min_replicas=3, max_replicas=2)
    with pytest.raises(ValueError):
        ElasticPolicy(scale_interval=0)
    with pytest.raises(ValueError):
        ElasticPolicy(target_load=0)
