"""Per-architecture smoke tests (brief requirement (f)): a reduced
config of each family runs forward + one train step on CPU with correct
shapes and no NaNs; prefill->decode agrees with the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.train.optimizer import init_opt_state
from repro.train.step import make_loss_fn, make_train_step

B, S = 4, 32


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = configs.get_smoke(name)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            batch = SyntheticPipeline(cfg, batch=B, seq=S).device_batch(0)
            cache[name] = (cfg, model, params, batch)
        return cache[name]
    return get


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_forward_shapes_and_finite(built, name):
    cfg, model, params, batch = built(name)
    logits, aux = model.apply(params, batch, train=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_train_step_decreases_loss(built, name):
    cfg, model, params, batch = built(name)
    step = jax.jit(make_train_step(model, cfg, n_micro=2))
    opt = init_opt_state(params)
    p, o, m0 = step(params, opt, batch)
    losses = [float(m0["loss"])]
    for _ in range(3):
        p, o, m = step(p, o, batch)   # same batch: loss must drop
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_prefill_matches_forward(built, name):
    cfg, model, params, batch = built(name)
    logits, _ = model.apply(params, batch, train=False)
    last, cache = model.prefill(params, batch)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(logits[:, -1], np.float32), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_decode_step_extends_consistently(built, name):
    """decode(prefill(x), t) == forward(x + t)[-1] — the cache carries
    exactly the state the full forward would rebuild.

    MoE archs: capacity drops depend on how many tokens compete, which
    legitimately differs between a 1-token decode and a full forward —
    so the check runs with capacity_factor large enough that nothing is
    dropped in either mode (isolates cache correctness)."""
    cfg, model, params, batch = built(name)
    if cfg.moe is not None:
        # capacity drops legitimately differ between 1-token decode and
        # a full forward; disable them to isolate cache correctness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
        from repro.models import build_model as _bm
        model = _bm(cfg)
    nxt = batch["tokens"][:, -1:]
    # reference forward padded to a chunk/window multiple; causality
    # makes positions > S irrelevant to the compared logits at S
    pad = 32
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate(
        [batch["tokens"], jnp.tile(nxt, (1, pad))], axis=1)
    if "mrope_positions" in batch:
        mp = batch["mrope_positions"]
        extra = mp[:, :, -1:] + 1 + jnp.arange(pad)[None, None]
        ext["mrope_positions"] = jnp.concatenate([mp, extra], axis=2)
    ext["labels"] = jnp.pad(batch["labels"], ((0, 0), (0, pad)))
    _, cache = model.prefill(params, batch, max_len=S + 8)
    got, _ = model.decode_step(params, cache, nxt)
    want, _ = model.apply(params, ext, train=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want[:, S], np.float32),
                               rtol=4e-2, atol=4e-2)


@pytest.mark.parametrize("name", ["recurrentgemma-2b", "rwkv6-3b"])
def test_sub_quadratic_state_is_constant_size(built, name):
    """long_500k eligibility: decode state must not grow with history."""
    cfg, model, params, batch = built(name)
    specs_a = model.cache_specs(B, 64)
    specs_b = model.cache_specs(B, 65536)
    import math
    size = lambda t: sum(  # noqa: E731
        math.prod(ps.shape) for ps in jax.tree.leaves(
            t, is_leaf=lambda x: hasattr(x, "axes")))
    sa, sb = size(specs_a), size(specs_b)
    # hybrid: local-attn ring may grow up to `window` then stop
    assert sb <= sa * (cfg.local_window // 16 if cfg.family == "hybrid"
                       else 1.01)


def test_moe_param_accounting():
    cfg = configs.get("llama4-scout-17b-a16e")
    total, active = cfg.n_params(), cfg.n_active_params()
    assert 1.0e11 < total < 1.2e11          # ~109B total
    assert 1.5e10 < active < 2.0e10         # ~17B active
    dense = configs.get("qwen1.5-110b")
    assert 1.0e11 < dense.n_params() < 1.25e11
    assert dense.n_params() == dense.n_active_params()
