"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
this suite uses (``given`` / ``settings`` / ``strategies``), installed by
conftest.py only when the real package is absent.

It is *not* a property-based testing engine: no shrinking, no example
database — just deterministic pseudo-random example generation so the
property tests still exercise many inputs per run.  The draw sequence is
seeded from the test name, so failures are reproducible.
"""
from __future__ import annotations

import inspect
import random
import zlib

__version__ = "0.0-fallback"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda r: r.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(r):
        n = r.randint(min_size, max_size)
        return [elements.example_from(r) for _ in range(n)]
    return _Strategy(draw)


class strategies:
    """Namespace mirror so ``from hypothesis import strategies as st``
    and ``st.integers`` both resolve."""
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(f):
        f._fallback_max_examples = max_examples
        return f
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(f):
        n = getattr(f, "_fallback_max_examples", 20)
        params = list(inspect.signature(f).parameters)
        # hypothesis semantics: positional strategies fill the RIGHTMOST
        # non-keyword-strategy params; anything left over is a pytest
        # fixture the runner must request by exposing it in its own
        # signature.
        non_kw = [p for p in params if p not in kw_strategies]
        pos_names = non_kw[len(non_kw) - len(arg_strategies):] \
            if arg_strategies else []
        fixture_names = [p for p in non_kw if p not in pos_names]

        def runner(**fixtures):
            rnd = random.Random(zlib.crc32(f.__qualname__.encode()))
            for i in range(n):
                drawn = {name: s.example_from(rnd)
                         for name, s in zip(pos_names, arg_strategies)}
                drawn.update((k, s.example_from(rnd))
                             for k, s in kw_strategies.items())
                try:
                    f(**fixtures, **drawn)
                except BaseException:
                    print(f"[hypothesis-fallback] falsifying example "
                          f"#{i}: {drawn!r}")
                    raise

        runner.__signature__ = inspect.Signature(
            [inspect.Parameter(name, inspect.Parameter.POSITIONAL_OR_KEYWORD)
             for name in fixture_names])
        # plain attribute copy (functools.wraps would set __wrapped__,
        # making pytest see the strategy params as fixture requests)
        runner.__name__ = f.__name__
        runner.__qualname__ = f.__qualname__
        runner.__doc__ = f.__doc__
        runner.__module__ = f.__module__
        return runner
    return deco
