"""Tensor-parallel serving: token streams from a sharded engine must be
bit-identical to the single-device engine across every serve feature
(chunked prefill, prefix sharing/COW, speculative decode,
preemption/replay).

Sharded runs need >1 device while the rest of the suite must see
exactly one, so (like test_multidevice.py) each scenario runs in a
subprocess with its own forced-host-device XLA_FLAGS.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_devices(n: int, code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=1800)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_tp2_token_parity_sharing_and_spec():
    """tp=2 vs single device on one trace exercising chunked prefill,
    prefix sharing with mid-page COW divergence, and speculative
    decode — streams must match bit for bit, and the page arrays must
    actually be sharded across devices."""
    print(run_devices(8, """
        import jax, numpy as np
        from repro import configs
        from repro.models import build_model
        from repro.serve import Request, ServeEngine

        cfg = configs.get_smoke("qwen3-0.6b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(3)
        prefix = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
        prompts = [np.concatenate([prefix,
                                   rng.integers(0, cfg.vocab_size,
                                                size=(7,)).astype(np.int32)])
                   for _ in range(3)]
        # a long unshared prompt spanning several chunks rides along
        prompts.append(rng.integers(0, cfg.vocab_size,
                                    size=(40,)).astype(np.int32))

        def trace():
            return [Request(rid=i, prompt=p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]

        kw = dict(max_batch=2, n_pages=40, page_size=8,
                  max_pages_per_seq=8, chunk_size=16, spec_k=4)
        ref = ServeEngine(model, params, **kw)
        want = {r.rid: list(r.generated) for r in ref.run(trace())}
        tp = ServeEngine(model, params, tp=2, **kw)
        assert len(tp.cache.k_pages.sharding.device_set) == 2, \\
            tp.cache.k_pages.sharding
        got = {r.rid: list(r.generated) for r in tp.run(trace())}
        assert want == got, (want, got)
        assert tp.cache.n_cow >= 2 and tp.n_spec_rounds >= 1
        tp.cache.check_invariants()
        print("tp2 sharing+spec parity ok", tp.n_spec_rounds)
    """))


def test_tp2_batched_prefill_parity():
    """Batched chunked prefill (prefill_batch > 1) composed with
    tensor parallelism: a tp=2 engine co-ingesting a burst must stream
    bit-identically to the single-device *serialized* engine — the
    per-row tables/starts/valids are replicated control metadata, the
    gathered context and page scatter shard on KV heads."""
    print(run_devices(8, """
        import jax, numpy as np
        from repro import configs
        from repro.models import build_model
        from repro.serve import Request, ServeEngine

        cfg = configs.get_smoke("qwen3-0.6b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(13)
        prefix = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
        prompts = [np.concatenate([prefix,
                                   rng.integers(0, cfg.vocab_size,
                                                size=(7,)).astype(np.int32)])
                   for _ in range(3)]
        # ragged unshared prompts straddling chunk boundaries ride along
        prompts += [rng.integers(0, cfg.vocab_size,
                                 size=(L,)).astype(np.int32)
                    for L in (15, 33)]

        def trace():
            return [Request(rid=i, prompt=p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]

        kw = dict(max_batch=4, n_pages=64, page_size=8,
                  max_pages_per_seq=8, chunk_size=16)
        ref = ServeEngine(model, params, prefill_batch=1, **kw)
        want = {r.rid: list(r.generated) for r in ref.run(trace())}
        tp = ServeEngine(model, params, tp=2, prefill_batch=4, **kw)
        got = {r.rid: list(r.generated) for r in tp.run(trace())}
        assert want == got, (want, got)
        assert tp.n_prefill_dispatches < tp.n_prefill_chunks, \\
            "burst was meant to co-ingest"
        assert tp.cache.n_shared_tokens >= 2 * 20, \\
            "in-burst sharing must fire under tp too"
        tp.cache.check_invariants()
        print("tp2 batched-prefill parity ok",
              tp.n_prefill_dispatches, tp.n_prefill_chunks)
    """))


def test_tp2_preemption_replay_parity():
    """Page pressure forces eviction + recompute-replay on the sharded
    engine; the replayed stream still matches the single-device one."""
    print(run_devices(8, """
        import jax, numpy as np
        from repro import configs
        from repro.models import build_model
        from repro.serve import Request, ServeEngine

        cfg = configs.get_smoke("qwen3-0.6b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        lens, gen = [30, 28, 18], 8
        prompts = [rng.integers(0, cfg.vocab_size,
                                size=(L,)).astype(np.int32) for L in lens]

        def trace():
            return [Request(rid=i, prompt=p, max_new_tokens=gen)
                    for i, p in enumerate(prompts)]

        kw = dict(max_batch=3, n_pages=13, page_size=8,
                  max_pages_per_seq=8, prefix_sharing=False)
        ref = ServeEngine(model, params, **kw)
        want = {r.rid: list(r.generated) for r in ref.run(trace())}
        tp = ServeEngine(model, params, tp=2, **kw)
        got = {r.rid: list(r.generated) for r in tp.run(trace())}
        assert tp.n_replay_steps >= 1, "trace was sized to force replay"
        assert want == got, (want, got)
        tp.cache.check_invariants()
        print("tp2 preemption parity ok", tp.n_replay_steps)
    """))


def test_tp4_token_parity():
    """tp=4 on a 4-KV-head config (the smoke qwen3 has only 2 KV
    heads); also checks the TP engine composes with an explicit
    ServePrograms-style shared bundle across two replicas."""
    print(run_devices(8, """
        import jax, numpy as np
        from repro.configs.base import ModelConfig
        from repro.models import build_model
        from repro.serve import Request, ServeEngine
        from repro.serve.parallel import TPServePrograms

        cfg = ModelConfig(name="tp4-test", family="dense", n_layers=2,
                          d_model=64, n_heads=8, n_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=256,
                          qk_norm=True, tie_embeddings=True,
                          attn_kv_chunk=16)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, 256, size=(L,)).astype(np.int32)
                   for L in (9, 21, 14)]

        def trace():
            return [Request(rid=i, prompt=p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]

        kw = dict(max_batch=2, n_pages=24, page_size=8,
                  max_pages_per_seq=8)
        ref = ServeEngine(model, params, **kw)
        want = {r.rid: list(r.generated) for r in ref.run(trace())}
        progs = TPServePrograms(model, tp=4)
        a = ServeEngine(model, params, programs=progs, **kw)
        b = ServeEngine(model, params, programs=progs, **kw)
        got_a = {r.rid: list(r.generated) for r in a.run(trace())}
        got_b = {r.rid: list(r.generated) for r in b.run(trace())}
        assert want == got_a == got_b, (want, got_a, got_b)
        print("tp4 parity ok (shared programs)")
    """))


def test_tp2_parity_bias_gelu_untied_family():
    """The other sharded param shapes: qkv biases (sharded with their
    heads), gelu w1/b1 (sharded hidden), layernorm, and an untied
    unembedding head (replicated) — still bitwise, spec on."""
    print(run_devices(8, """
        import jax, numpy as np
        from repro.configs.base import ModelConfig
        from repro.models import build_model
        from repro.serve import Request, ServeEngine

        cfg = ModelConfig(name="tp-bias-test", family="dense",
                          n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, head_dim=16, d_ff=128,
                          vocab_size=256, qkv_bias=True,
                          mlp_kind="gelu", norm_kind="layernorm",
                          tie_embeddings=False, attn_kv_chunk=16)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(3))
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, 256, size=(L,)).astype(np.int32)
                   for L in (9, 21, 14)]

        def trace():
            return [Request(rid=i, prompt=p, max_new_tokens=6)
                    for i, p in enumerate(prompts)]

        kw = dict(max_batch=2, n_pages=24, page_size=8,
                  max_pages_per_seq=8, spec_k=3)
        want = {r.rid: list(r.generated)
                for r in ServeEngine(model, params, **kw).run(trace())}
        got = {r.rid: list(r.generated)
               for r in ServeEngine(model, params, tp=2,
                                    **kw).run(trace())}
        assert want == got, (want, got)
        print("bias/gelu/untied tp2 parity ok")
    """))


def test_tp_validation_rejects_bad_configs():
    """Divisibility and family checks fail fast, without any mesh."""
    from repro import configs
    from repro.models import build_model
    from repro.serve.parallel import validate_tp

    model = build_model(configs.get_smoke("qwen3-0.6b"))
    validate_tp(model, 2)                     # 4 heads / 2 kv heads
    with pytest.raises(ValueError, match="does not divide"):
        validate_tp(model, 4)                 # kv heads indivisible
    moe = build_model(configs.get_smoke("deepseek-moe-16b"))
    with pytest.raises(ValueError):
        validate_tp(moe, 2)
