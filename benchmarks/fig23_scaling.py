"""Fig 23 — energy-efficiency projection vs PE count.

Per the paper: per-component energies stay constant as the array grows
except the NoCs, whose hops-per-request grow ~ sqrt(#PEs).  We take the
measured 64-PE energy breakdown of All-Reuse AlexNet_CONV2 and project.
Paper: +23.1% total energy at 4096 PEs (so efficiency scales well)."""
from __future__ import annotations

import math

from repro.core.dataflows import ALEXNET_CONV2, Reuse
from repro.core.machine import MachineConfig, simulate

from .common import conv_instances, fmt_table, save

PES = (64, 128, 256, 512, 1024, 2048, 4096)


def run() -> dict:
    cfg = MachineConfig()
    r = simulate(conv_instances(ALEXNET_CONV2, Reuse.ALL_REUSE, 8), cfg)
    e = r.energy_breakdown
    e_noc = e["noc"]
    e_rest = r.energy_pj - e_noc
    rows = []
    for n in PES:
        scale = math.sqrt(n / 64)
        total = e_rest + e_noc * scale
        rows.append({"pes": n,
                     "noc_scale": f"{scale:.2f}x",
                     "energy_vs_64pe": f"{total / r.energy_pj:.3f}x"})
    print("\n== Fig 23: energy projection vs #PEs (paper: 1.231x @ 4096) ==")
    print(fmt_table(rows, ["pes", "noc_scale", "energy_vs_64pe"]))
    save("fig23_scaling", rows)
    at4096 = float(rows[-1]["energy_vs_64pe"].rstrip("x"))
    return {"rows": rows, "overhead_at_4096": at4096 - 1.0,
            "paper_target": 0.231}


if __name__ == "__main__":
    run()
