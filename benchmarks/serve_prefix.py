"""Prefix-cache sharing under a shared-system-prompt Poisson trace —
the serve engine with copy-on-write prefix sharing enabled vs the same
engine recomputing every prompt from scratch.

This is the serving face of the paper's multi-level reuse argument:
the KV pages of a common prompt prefix are a reusable operand, and the
prefix trie is the "programmable LD stage" that stages them once for N
consumers instead of re-running the whole prefill dataflow per request.
Reports tokens/s, time-to-first-token, prefill chunks executed, and
prompt tokens served from cache; asserts the >=1.3x speedup gate and
that sharing leaves every generated stream bit-identical.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.kv_cache import pages_needed
from repro.launch.serve import synth_requests

from .common import fmt_table, save, warm_serve_arms

ARCH = "qwen3-0.6b"


def _trace(eng, reqs):
    # snapshot cumulative counters so the warmup run's contribution is
    # excluded from the measured numbers
    chunks0, shared0, cow0 = (eng.n_prefill_chunks,
                              eng.cache.n_shared_tokens, eng.cache.n_cow)
    t0 = time.perf_counter()
    done = eng.run(reqs, realtime=True)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    return {"tokens": {r.rid: np.asarray(r.generated, np.int32)
                       for r in done},
            "tok_per_s": n_tok / max(dt, 1e-9),
            "ttft_mean_s": float(np.mean([r.ttft for r in done])),
            "prefill_chunks": eng.n_prefill_chunks - chunks0,
            "shared_tokens": eng.cache.n_shared_tokens - shared0,
            "cow": eng.cache.n_cow - cow0}


def run(smoke: bool = False, batch: int = 4) -> dict:
    n_req = 8 if smoke else 12
    # prefix deliberately straddles a page boundary so every sharing
    # admission exercises the copy-on-write fork of the partial page
    prefix_len, unique_len, gen = (68, 8, 8) if smoke else (100, 16, 16)
    page_size, chunk = 8, 16
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    total = prefix_len + unique_len + gen
    per_seq = pages_needed(total, page_size) + 2
    n_pages = 2 + batch * per_seq + pages_needed(total, page_size)

    # high arrival rate: the queue builds immediately, so both modes
    # are measured at saturation (the batching regime of interest)
    def fresh(seed):
        return synth_requests(cfg, n_req, unique_len, gen, rate=500.0,
                              seed=seed, prefix_len=prefix_len)

    engines = {
        share: ServeEngine(model, params, max_batch=batch,
                           n_pages=n_pages, page_size=page_size,
                           max_pages_per_seq=pages_needed(total, page_size),
                           chunk_size=chunk, prefix_sharing=share)
        for share in (True, False)}
    # compiles every program at the arms' exact pool shape (distinct
    # prefix seed, so the measured run's trie starts cold for its own
    # prefix)
    warm_serve_arms(engines.values(), lambda: fresh(99)[:2])

    shared = _trace(engines[True], fresh(1))
    unshared = _trace(engines[False], fresh(1))

    parity = all(
        np.array_equal(shared["tokens"][rid], unshared["tokens"][rid])
        for rid in unshared["tokens"])
    speedup = shared["tok_per_s"] / unshared["tok_per_s"]
    rows = [
        {"system": "sharing off (recompute prefix)",
         "tok_per_s": f"{unshared['tok_per_s']:.1f}",
         "ttft_ms": f"{unshared['ttft_mean_s'] * 1e3:.0f}",
         "prefill_chunks": unshared["prefill_chunks"],
         "cached_tok": 0},
        {"system": "sharing on (COW prefix cache)",
         "tok_per_s": f"{shared['tok_per_s']:.1f}",
         "ttft_ms": f"{shared['ttft_mean_s'] * 1e3:.0f}",
         "prefill_chunks": shared["prefill_chunks"],
         "cached_tok": shared["shared_tokens"]},
    ]
    print(f"\n== Prefix sharing: {n_req} reqs, {prefix_len}-tok shared "
          f"system prompt + {unique_len}-tok tail, gen {gen} ==")
    print(fmt_table(rows, ["system", "tok_per_s", "ttft_ms",
                           "prefill_chunks", "cached_tok"]))
    print(f"sharing speedup: {speedup:.2f}x "
          f"(COW copies: {shared['cow']}); "
          f"token parity with sharing off: {parity}")
    out = {"rows": rows, "speedup": speedup, "token_parity": parity,
           "shared_tokens": shared["shared_tokens"],
           "ttft_ratio": unshared["ttft_mean_s"]
           / max(shared["ttft_mean_s"], 1e-9)}
    if not smoke:
        # perf gate at full size only: smoke exists to catch entry-point
        # rot, and CI runners are too noisy for a ratio assertion
        out["sharing_speedup_ok"] = speedup >= 1.3
    save("serve_prefix", out)
    return out


if __name__ == "__main__":
    run()
