"""Speculative decoding on the paged serve engine — draft k tokens per
step, verify all k+1 positions in one batched program, keep the longest
matching prefix — vs the same engine decoding one token per step.

This is the serving face of the paper's latency argument: datacenter
decode is latency-bound, not FLOP-bound (Jouppi et al. 2017), so a
batched decode program is mostly per-dispatch overhead at small batch;
speculation converts that slack into tokens by making each dispatch
carry k+1 positions.  Like prefix sharing, it is a pure *scheduling*
win — the accept test compares the draft against the target model's
own greedy argmax over bit-identical context, so the generated streams
are token-identical with speculation on or off (asserted every rep).

Trace: the shared-system-prompt saturation trace of serve_prefix
(prefix sharing ON in both arms, so the two PR 2 reuse mechanisms
compose on the measured path), run ``reps`` times over the *same*
workload.  Rep 0 measures the cold drafter (self-repetition only);
later reps measure the recurring-workload steady state, where the
cross-request n-gram index has seen these streams before — the
prompt-lookup analogue of a warm prefix cache.  Reported gates (full
size only):

* ``spec_speedup_ok``  — warm-rep median tokens/s >= 1.3x the
  ``--no-spec`` baseline (wall clock; medians because shared runners
  are noisy),
* ``spec_dispatch_ok`` — warm decode dispatches per token >= 1.3x
  fewer (deterministic counterpart of the wall-clock ratio).

    PYTHONPATH=src python -m benchmarks.serve_spec [--smoke]
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.kv_cache import pages_needed
from repro.launch.serve import synth_requests

from .common import fmt_table, save, warm_serve_arms

ARCH = "qwen3-0.6b"
SPEC_K = 6


def _trace(eng, reqs):
    # snapshot cumulative counters so warmup / earlier reps are
    # excluded from this rep's numbers
    steps0, rounds0 = eng.n_decode_steps, eng.n_spec_rounds
    drafted0, acc0 = eng.n_drafted, eng.n_draft_accepted
    t0 = time.perf_counter()
    done = eng.run(reqs, realtime=False)        # saturation throughput
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    drafted = eng.n_drafted - drafted0
    return {"tokens": {r.rid: np.asarray(r.generated, np.int32)
                       for r in done},
            "tok_per_s": n_tok / max(dt, 1e-9),
            "dispatches": eng.n_decode_steps - steps0,
            "rounds": eng.n_spec_rounds - rounds0,
            "drafted": drafted,
            "accepted": eng.n_draft_accepted - acc0,
            "accept_rate": (eng.n_draft_accepted - acc0) / max(drafted, 1)}


def run(smoke: bool = False, batch: int = 4) -> dict:
    n_req = 8
    # decode-heavy split: speculation pays per *generated* token, so gen
    # dominates the trace; the shared prefix straddles a page boundary
    # to keep COW forks on the measured path (same shape as serve_prefix)
    prefix_len, unique_len, gen = (68, 8, 16) if smoke else (68, 8, 64)
    reps = 2 if smoke else 5
    page_size, chunk = 8, 16
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    total = prefix_len + unique_len + gen
    per_seq = pages_needed(total, page_size) + 2
    # + batch: transient speculative page growth (rolled back each
    # round) must not force preemptions into the measured window
    n_pages = 2 + batch * per_seq + pages_needed(total, page_size) + batch

    def fresh(seed):
        return synth_requests(cfg, n_req, unique_len, gen, rate=500.0,
                              seed=seed, prefix_len=prefix_len)

    engines = {
        k: ServeEngine(model, params, max_batch=batch,
                       n_pages=n_pages, page_size=page_size,
                       max_pages_per_seq=pages_needed(total, page_size),
                       chunk_size=chunk, spec_k=k)
        for k in (SPEC_K, 0)}
    # compiles every program at each arm's exact pool shape (verify for
    # the spec arm, decode for the baseline; the distinct prefix seed
    # keeps the measured workload cold for trie and drafter alike)
    warm_serve_arms(engines.values(), lambda: fresh(99)[:2])

    # rep 0 = cold drafter; reps 1+ = recurring-workload steady state.
    # Arms alternate back to back so wall-clock noise hits both alike.
    spec_runs, base_runs, parity = [], [], True
    for _ in range(reps):
        s = _trace(engines[SPEC_K], fresh(1))
        b = _trace(engines[0], fresh(1))
        spec_runs.append(s)
        base_runs.append(b)
        parity &= all(np.array_equal(s["tokens"][rid], b["tokens"][rid])
                      for rid in b["tokens"])
    cold, warm_s, warm_b = spec_runs[0], spec_runs[1:], base_runs[1:]
    spec_tps = float(np.median([r["tok_per_s"] for r in warm_s]))
    base_tps = float(np.median([r["tok_per_s"] for r in warm_b]))
    speedup = spec_tps / base_tps
    warm = warm_s[-1]
    # deterministic counterpart of the wall-clock ratio: decode-program
    # dispatches the baseline needed per dispatch speculation needed
    dispatch_ratio = warm_b[-1]["dispatches"] / max(warm["dispatches"], 1)

    rows = [
        {"system": "spec off (1 tok/dispatch)",
         "tok_per_s": f"{base_tps:.1f}",
         "dispatches": warm_b[-1]["dispatches"],
         "accept_cold": "-", "accept_warm": "-"},
        {"system": f"spec on (k={SPEC_K} prompt-lookup)",
         "tok_per_s": f"{spec_tps:.1f}",
         "dispatches": warm["dispatches"],
         "accept_cold": f"{cold['accept_rate']:.2f}",
         "accept_warm": f"{warm['accept_rate']:.2f}"},
    ]
    print(f"\n== Speculative decode: {n_req} reqs, {prefix_len}-tok "
          f"shared prefix + {unique_len}-tok tail, gen {gen}, "
          f"k={SPEC_K}, median of {len(warm_s)} warm rep(s) ==")
    print(fmt_table(rows, ["system", "tok_per_s", "dispatches",
                           "accept_cold", "accept_warm"]))
    print(f"spec speedup: {speedup:.2f}x tokens/s, {dispatch_ratio:.2f}x "
          f"fewer decode dispatches; accept rate "
          f"{cold['accept_rate']:.2f} cold -> {warm['accept_rate']:.2f} "
          f"warm ({warm['accepted']}/{warm['drafted']} drafts); "
          f"token parity with spec off: {parity}")
    out = {"rows": rows, "speedup": speedup,
           "dispatch_ratio": dispatch_ratio, "token_parity": parity,
           "accept_rate_cold": cold["accept_rate"],
           "accept_rate_warm": warm["accept_rate"],
           "verify_rounds": warm["rounds"],
           "baseline_steps": warm_b[-1]["dispatches"]}
    if not smoke:
        # perf gates at full size only: smoke exists to catch
        # entry-point rot, and CI runners are too noisy for wall-clock
        # ratios (hence the deterministic dispatch gate beside it)
        out["spec_speedup_ok"] = speedup >= 1.3
        out["spec_dispatch_ok"] = dispatch_ratio >= 1.3
    save("serve_spec", out)
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
