"""Multi-replica router scaling: N engine replicas behind the
prefix-affinity router vs one engine with the same per-replica
resources, on a workload cycling through more shared-prompt groups
than one replica's page pool can keep resident.

This is the memory-system half of the datacenter-inference argument
(Jouppi et al. 2017) one level above the chip: a replica's page pool
bounds how many *hot prompt prefixes* stay resident.  The trace
interleaves K shared-prefix groups; a single replica's prefix trie can
hold only ~K/2 of them, so LRU eviction runs just ahead of reuse (the
classic cyclic-access pathology) and nearly every admission re-ingests
its prompt from scratch.  Two replicas hold two pools, and the
router's prefix affinity *partitions* the groups — each replica serves
K/2 groups that fit, so prompts ingest once and then hit the trie.
Throughput scales super-linearly in this regime because scale-out adds
the one resource the workload is starved of (prefix residency), not
just slots.

Token streams are asserted identical across arms (routing only moves
streams, never changes them).  Reported gates:

* ``router_speedup_ok``  — aggregate tokens/s of 2 replicas >= 1.5x
  the single replica (wall clock),
* ``router_dispatch_ok`` — >= 1.5x fewer program dispatches
  (prefill chunks + decode steps; the deterministic counterpart that
  cannot be faked by machine noise).

Both arms share one ``ServePrograms`` compile cache and a warmup that
touches every context bucket, so jit compiles never land in the
measured window.  A tensor-parallel composition leg (router over
``tp=2`` replicas, parity only) runs when >= 2 devices are visible —
``--xla_force_host_platform_device_count`` in CI — and is reported as
visibly skipped otherwise.

    PYTHONPATH=src python -m benchmarks.serve_router [--smoke] [--tp N]
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import Request, RequestRouter, ServeEngine, ServePrograms
from repro.serve.kv_cache import pages_needed

from .common import Skip, fmt_table, save, warm_serve_arms

ARCH = "qwen3-0.6b"
N_GROUPS = 6           # shared-prefix groups cycling through the trace
PREFIX_LEN = 128       # tokens of shared system prompt per group
UNIQUE_LEN = 8
PAGE, BATCH, CHUNK = 8, 4, 16


def _grouped_trace(cfg, per_group: int, gen: int, seed: int = 0):
    """g0, g1, ..., g5, g0, ... — LRU's worst case for one trie."""
    rng = np.random.default_rng(seed)

    def walk(length):
        base = rng.integers(0, cfg.vocab_size)
        drift = rng.integers(0, 17, size=length)
        return ((base + np.cumsum(drift)) % cfg.vocab_size).astype(np.int32)

    prefixes = [walk(PREFIX_LEN) for _ in range(N_GROUPS)]
    reqs = []
    for i in range(N_GROUPS * per_group):
        reqs.append(Request(
            rid=i,
            prompt=np.concatenate([prefixes[i % N_GROUPS],
                                   walk(UNIQUE_LEN)]),
            max_new_tokens=gen))
    return reqs


def _engine(model, params, programs, n_pages, total, **kw):
    # serialized prefill (prefill_batch default 1) in BOTH arms: this
    # benchmark isolates prefix *residency*; co-ingestion has its own
    # A/B (benchmarks/serve_prefill.py) and would shrink both arms'
    # dispatch counts alike here
    return ServeEngine(model, params, max_batch=BATCH, n_pages=n_pages,
                       page_size=PAGE, chunk_size=CHUNK,
                       max_pages_per_seq=pages_needed(total, PAGE),
                       programs=programs, **kw)


def _serve(engines, router_policy, reqs):
    if len(engines) == 1:
        front = engines[0]
    else:
        front = RequestRouter(engines, policy=router_policy)
    t0 = time.perf_counter()
    done = front.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    return {"tokens": {r.rid: np.asarray(r.generated, np.int32)
                       for r in done},
            "tok_per_s": toks / max(dt, 1e-9),
            "dispatches": sum(e.n_prefill_dispatches + e.n_decode_steps
                              for e in engines),
            "shared_tokens": sum(e.cache.n_shared_tokens
                                 for e in engines),
            "evictions": sum(e.cache.n_prefix_evictions
                             for e in engines)}


def run(smoke: bool = False, tp: int = 0) -> dict:
    per_group, gen = (3, 12) if smoke else (4, 16)
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    total = PREFIX_LEN + UNIQUE_LEN + gen
    # per-replica pool: ~half the batch's live pages plus ~1.5 group
    # prefixes (~70 pages).  Sized so one replica cycling all 6 groups
    # LRU-thrashes its trie (capacity < groups, the measured sh~0 /
    # evictions-hot regime) while a replica owning 3 affinity-routed
    # groups keeps them resident (measured: full reuse, 0 evictions)
    n_pages = (2 + (BATCH // 2) * (pages_needed(total, PAGE) + 2)
               + pages_needed(PREFIX_LEN, PAGE)
               + pages_needed(PREFIX_LEN, PAGE) // 2)
    programs = ServePrograms(model)

    # warmup covers every chunk bucket + the decode shape (cold AND
    # prefix-hit admissions) on a throwaway engine sharing the arms'
    # ServePrograms bundle at their exact page-pool shape — the
    # measured engines' own tries must start cold
    warm_serve_arms([_engine(model, params, programs, n_pages, total)],
                    lambda: _grouped_trace(cfg, 2, gen,
                                           seed=99)[:N_GROUPS + 1])

    # fresh Request objects per arm: engines fill .generated in place
    single = _serve([_engine(model, params, programs, n_pages, total)],
                    None, _grouped_trace(cfg, per_group, gen))
    routed = _serve([_engine(model, params, programs, n_pages, total)
                     for _ in range(2)], "prefix",
                    _grouped_trace(cfg, per_group, gen))
    parity = all(np.array_equal(single["tokens"][rid],
                                routed["tokens"][rid])
                 for rid in single["tokens"])
    speedup = routed["tok_per_s"] / single["tok_per_s"]
    dispatch_ratio = single["dispatches"] / max(routed["dispatches"], 1)

    # tensor-parallel composition: router over sharded replicas is
    # parity-gated only (CPU forced-host devices prove wiring, not perf)
    n_dev = len(jax.devices())
    want_tp = tp if tp >= 2 else (2 if n_dev >= 2 else 0)
    if tp and tp > n_dev:
        raise Skip(f"--tp {tp} needs {tp} devices, {n_dev} visible "
                   "(set XLA_FLAGS=--xla_force_host_platform_"
                   f"device_count={tp})")
    if want_tp:
        from repro.serve.parallel import TPServePrograms
        tp_programs = TPServePrograms(model, tp=want_tp)
        tp_reqs = [r for r in _grouped_trace(cfg, per_group, gen)
                   if r.rid < 2 * N_GROUPS]
        tp_arm = _serve([_engine(model, params, tp_programs, n_pages,
                                 total) for _ in range(2)],
                        "prefix", tp_reqs)
        tp_leg = all(np.array_equal(single["tokens"][rid],
                                    tp_arm["tokens"][rid])
                     for rid in tp_arm["tokens"])
    else:
        tp_leg = "skipped: 1 visible device (forced-host CI runs it)"

    rows = [
        {"system": "1 replica", "tok_per_s": f"{single['tok_per_s']:.1f}",
         "dispatches": single["dispatches"],
         "prefix_reuse_tok": single["shared_tokens"],
         "trie_evictions": single["evictions"]},
        {"system": "2 replicas (prefix affinity)",
         "tok_per_s": f"{routed['tok_per_s']:.1f}",
         "dispatches": routed["dispatches"],
         "prefix_reuse_tok": routed["shared_tokens"],
         "trie_evictions": routed["evictions"]},
    ]
    print(f"\n== Router scaling: {N_GROUPS} prompt groups x {per_group} "
          f"reqs, {PREFIX_LEN}-tok shared prefixes, {n_pages} pages "
          f"per replica ==")
    print(fmt_table(rows, ["system", "tok_per_s", "dispatches",
                           "prefix_reuse_tok", "trie_evictions"]))
    print(f"aggregate speedup {speedup:.2f}x tokens/s, "
          f"{dispatch_ratio:.2f}x fewer dispatches; token parity: "
          f"{parity}; tp-composition parity: {tp_leg}")
    out = {"rows": rows, "speedup": speedup,
           "dispatch_ratio": dispatch_ratio,
           "token_parity": parity,
           "tp_composition": tp_leg,
           "router_speedup_ok": speedup >= 1.5,
           "router_dispatch_ok": dispatch_ratio >= 1.5}
    save("serve_router", out)
    return out


if __name__ == "__main__":
    argv = sys.argv[1:]
    tp = int(argv[argv.index("--tp") + 1]) if "--tp" in argv else 0
    try:
        out = run(smoke="--smoke" in argv, tp=tp)
    except Skip as s:
        print(f"SKIPPED: {s.reason}")
        raise SystemExit(0)
    # every boolean in the payload is a gate — including the
    # tp-composition parity leg when it ran (string when skipped)
    gates = [v for v in out.values() if isinstance(v, bool)]
    raise SystemExit(0 if all(gates) else 1)
