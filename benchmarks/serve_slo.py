"""SLO-aware streaming front-end: interactive latency under batch
saturation, weighted tenant fairness, and a chaos leg (bursty arrivals
+ mid-stream cancels) with bitwise stream parity.

All three legs run the deterministic front-end clock (one unit per
pump), so every latency is measured in *backend steps* — the
dispatch-count framing the serving benchmarks gate on (wall clock on a
shared 2-core runner swings 3-5x run to run; scheduling decisions do
not).  Parity against the sequential ``greedy_generate`` oracle is
asserted on every leg: SLO preemption, fair queueing, and cancellation
are scheduling policy only, and must never change a token.

* ``slo_ttft_ok`` — with every slot saturated by batch-class work,
  interactive p99 TTFT (steps) <= 0.5x the slo-blind baseline (same
  trace, ``slo_aware=False``).  Priority dispatch + batch preemption
  is what buys this; exact replay is why it costs no correctness.
* ``tenant_share_ok`` — two tenants with weight 3:1 and identical
  saturating backlogs: dispatch share over the contended window within
  20% of the weight split (stride-scheduled WFQ).
* ``chaos_ok`` — bursty arrivals across tenants/classes with
  mid-stream cancels: zero dropped streams (every stream finishes or
  was cancelled), zero non-parity streams (finished == oracle,
  cancelled == oracle prefix), and cancel-then-resubmit reuses the
  cancelled request's trie pages (shared tokens strictly grow).

    PYTHONPATH=src python -m benchmarks.serve_slo [--smoke]
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import (ServeFrontend, ServeOptions, TenantPolicy,
                         greedy_generate)
from repro.serve.step import ServePrograms

from .common import fmt_table, save

ARCH = "qwen3-0.6b"
PAGE, CHUNK = 8, 16


class _DispatchRecorder:
    """Transparent ServeBackend wrapper that records dispatch order
    (the front-end's policy output) for the fairness gate."""

    def __init__(self, inner):
        self._inner = inner
        self.order = []

    def submit(self, req):
        self.order.append(req.tenant)
        self._inner.submit(req)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def _oracle(model, params, prompts, gen):
    return [[int(t) for t in np.asarray(
        greedy_generate(model, params, {"tokens": p[None]}, gen,
                        cache_len=len(p) + gen))[0]]
            for p in prompts]


def _opts(batch, **kw):
    return ServeOptions(batch=batch, page_size=PAGE, chunk_size=CHUNK,
                        **kw)


def _prompts(cfg, n, plen, seed):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, plen, dtype=np.int32)
            for _ in range(n)]


class _Sized:
    """Minimal request stand-in for ServeOptions.sized_for (it only
    reads ``prompt`` and ``max_new_tokens``), sized generously so one
    pool shape serves every leg (one jit specialization)."""

    def __init__(self, prompt, gen):
        self.prompt = prompt
        self.max_new_tokens = 4 * gen


# ------------------------------------------------------------ leg 1: SLO
def _slo_leg(model, params, cfg, programs, *, n_batch, n_inter, gen):
    """Saturate a batch-4 backend with batch-class work, then drip
    interactive arrivals; measure their TTFT in steps with and without
    SLO awareness on the identical trace."""
    prompts = _prompts(cfg, 6, 16, seed=1)
    want = _oracle(model, params, prompts, gen)
    out = {}
    for aware in (True, False):
        fe = ServeFrontend(
            _opts(4).sized_for([_Sized(prompts[0], gen)]).build(
                model, params, programs=programs),
            slo_aware=aware)
        streams = []
        for i in range(n_batch):
            streams.append((fe.submit(prompts[i % len(prompts)], gen),
                            i % len(prompts)))
        submitted = 0
        pumps = 0
        while fe.busy or submitted < n_inter:
            pumps += 1
            if pumps % 4 == 0 and submitted < n_inter:
                streams.append(
                    (fe.submit(prompts[submitted % len(prompts)], gen,
                               slo_class="interactive"),
                     submitted % len(prompts)))
                submitted += 1
            fe.pump()
        parity = all(list(s) == want[w] for s, w in streams)
        ttfts = [r.ttft for r in fe.completed
                 if r.slo_class == "interactive"]
        out[aware] = {
            "parity": parity,
            "ttft_p99": float(np.percentile(ttfts, 99)),
            "ttft_mean": float(np.mean(ttfts)),
            "preemptions": fe.stats()["n_slo_preemptions"],
        }
    return out


# ------------------------------------------------------ leg 2: fairness
def _fairness_leg(model, params, cfg, programs, *, per_tenant, gen):
    """Identical saturating backlogs from gold (weight 3) and free
    (weight 1); the dispatch share over the first contended window
    must track the weights."""
    prompts = _prompts(cfg, 4, 16, seed=2)
    want = _oracle(model, params, prompts, gen)
    rec = _DispatchRecorder(
        _opts(2).sized_for([_Sized(prompts[0], gen)]).build(
            model, params, programs=programs))
    fe = ServeFrontend(rec, tenants={"gold": TenantPolicy(weight=3.0),
                                     "free": TenantPolicy(weight=1.0)})
    streams = []
    for i in range(per_tenant):
        for tenant in ("gold", "free"):
            streams.append((fe.submit(prompts[i % len(prompts)], gen,
                                      tenant=tenant),
                            i % len(prompts)))
    fe.drain()
    parity = all(list(s) == want[w] for s, w in streams)
    # the contended window: both tenants backlogged for the first
    # 2*per_tenant - |weight mismatch| dispatches; measure the first
    # 2/3 of all dispatches to stay safely inside it
    window = rec.order[:max(4, (4 * per_tenant) // 3)]
    gold_share = window.count("gold") / len(window)
    return {"parity": parity, "gold_share": gold_share,
            "window": len(window),
            "tokens": {t: fe.stats().get(f"tenant_tokens[{t}]", 0.0)
                       for t in ("gold", "free")}}


# --------------------------------------------------------- leg 3: chaos
def _chaos_leg(model, params, cfg, programs, *, n_req, gen):
    """Bursty multi-tenant arrivals with mid-stream cancels; every
    surviving stream must be bitwise-exact, every cancelled stream an
    exact oracle prefix, and resubmitted prompts must re-share trie
    pages."""
    rng = np.random.default_rng(3)
    prompts = _prompts(cfg, n_req, 16, seed=3)
    want = _oracle(model, params, prompts, gen)
    eng = _opts(3, spec_k=3).sized_for(
        [_Sized(prompts[0], gen)]).build(model, params,
                                         programs=programs)
    fe = ServeFrontend(eng, tenants={"a": TenantPolicy(weight=2.0),
                                     "b": TenantPolicy(weight=1.0)})
    pending = list(range(n_req))
    live = {}                       # idx -> (stream, collected tokens)
    done = {}
    cancelled = {}
    cancel_budget = max(2, n_req // 4)
    while pending or fe.busy:
        # bursty arrivals: 0-3 submissions per scheduling tick
        for _ in range(int(rng.integers(0, 4))):
            if not pending:
                break
            i = pending.pop(0)
            s = fe.submit(prompts[i], gen,
                          tenant="a" if i % 3 else "b",
                          slo_class="interactive" if i % 5 == 0
                          else "batch")
            live[i] = (s, [])
        fe.pump()
        for i, (s, buf) in list(live.items()):
            while s._pending:               # drain without blocking
                buf.append(next(iter(s)))
            if s.finished and not s._pending:
                done[i] = buf
                del live[i]
        # occasionally hang up on a stream that has produced tokens
        if cancel_budget and rng.random() < 0.3:
            victims = [i for i, (s, buf) in live.items() if buf]
            if victims:
                i = victims[int(rng.integers(len(victims)))]
                s, buf = live.pop(i)
                s.cancel()
                cancelled[i] = buf
                cancel_budget -= 1
    no_drops = (len(done) + len(cancelled) == n_req
                and not fe.stats()["n_queued"]
                and not fe.stats()["n_inflight"])
    parity = all(toks == want[i] for i, toks in done.items())
    prefix_ok = all(toks == want[i][:len(toks)]
                    for i, toks in cancelled.items())
    # cancel-then-resubmit: the trie still holds the cancelled
    # prompts' pages, so the reruns share instead of recomputing
    shared0 = eng.cache.n_shared_tokens
    redo_ok = True
    for i in cancelled:
        s = fe.submit(prompts[i], gen)
        redo_ok = redo_ok and list(s) == want[i]
    trie_reuse = eng.cache.n_shared_tokens > shared0 if cancelled \
        else True
    return {"no_drops": no_drops, "parity": parity,
            "prefix_ok": prefix_ok, "redo_ok": redo_ok,
            "trie_reuse": trie_reuse, "n_cancelled": len(cancelled),
            "n_done": len(done)}


def run(smoke: bool = False) -> dict:
    n_batch, n_inter, gen = (8, 4, 8) if smoke else (12, 6, 12)
    per_tenant = 6 if smoke else 10
    n_chaos = 8 if smoke else 14
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    programs = ServePrograms(model)     # one compile cache, all legs

    slo = _slo_leg(model, params, cfg, programs,
                   n_batch=n_batch, n_inter=n_inter, gen=gen)
    fair = _fairness_leg(model, params, cfg, programs,
                         per_tenant=per_tenant, gen=gen)
    chaos = _chaos_leg(model, params, cfg, programs,
                       n_req=n_chaos, gen=gen)

    ttft_ratio = slo[True]["ttft_p99"] / max(slo[False]["ttft_p99"],
                                             1e-9)
    gold_want = 3.0 / 4.0
    share_err = abs(fair["gold_share"] - gold_want) / gold_want
    gates = {
        "slo_parity_ok": slo[True]["parity"] and slo[False]["parity"],
        "slo_ttft_ok": ttft_ratio <= 0.5,
        "tenant_share_ok": fair["parity"] and share_err <= 0.2,
        "chaos_ok": all(chaos[k] for k in
                        ("no_drops", "parity", "prefix_ok", "redo_ok",
                         "trie_reuse")),
    }
    rows = [
        {"leg": "slo-aware", "ttft_p99_steps": f"{slo[True]['ttft_p99']:.1f}",
         "detail": f"{int(slo[True]['preemptions'])} preemptions"},
        {"leg": "slo-blind", "ttft_p99_steps": f"{slo[False]['ttft_p99']:.1f}",
         "detail": f"ratio {ttft_ratio:.2f} (gate <= 0.5)"},
        {"leg": "fairness", "ttft_p99_steps": "-",
         "detail": f"gold share {fair['gold_share']:.2f} "
                   f"(want {gold_want:.2f} +/- 20%)"},
        {"leg": "chaos", "ttft_p99_steps": "-",
         "detail": f"{chaos['n_done']} done, "
                   f"{chaos['n_cancelled']} cancelled, parity "
                   f"{chaos['parity'] and chaos['prefix_ok']}"},
    ]
    print(fmt_table(rows, ["leg", "ttft_p99_steps", "detail"]))
    for g, ok in gates.items():
        print(f"{g}: {'PASS' if ok else 'FAIL'}")
    out = {
        **gates,
        "ttft_p99_slo_steps": slo[True]["ttft_p99"],
        "ttft_p99_base_steps": slo[False]["ttft_p99"],
        "ttft_ratio": ttft_ratio,
        "slo_preemptions": slo[True]["preemptions"],
        "gold_share": fair["gold_share"],
        "chaos_cancelled": float(chaos["n_cancelled"]),
    }
    save("serve_slo", {"smoke": smoke, "slo": {str(k): v for k, v in
                                               slo.items()},
                       "fairness": fair, "chaos": chaos, "gates": gates})
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
