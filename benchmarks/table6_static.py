"""Table 6 — static program analysis of the five CNN reuse schemes on
AlexNet_CONV2 (LD/CAL/COPY/ST instruction and Operand-RAM counts)."""
from __future__ import annotations

from repro.core.dataflows import ALEXNET_CONV2, PAPER_TABLE6, Reuse, \
    build_conv_program

from .common import fmt_table, save


def run() -> dict:
    rows = []
    for scheme in Reuse:
        got = build_conv_program(ALEXNET_CONV2, scheme).totals()
        want = PAPER_TABLE6[scheme]
        rows.append({
            "scheme": scheme.value,
            **{k: got[k] for k in ("ld", "cal", "copy", "st",
                                   "exeblocks", "opm_entries")},
            **{f"{k}_paper": want[k] for k in ("ld", "cal", "copy", "st",
                                               "exeblocks", "opm_entries")},
        })
    print("\n== Table 6: static analysis, AlexNet_CONV2 ==")
    print(fmt_table(rows, ["scheme", "ld", "ld_paper", "cal", "cal_paper",
                           "copy", "copy_paper", "st", "st_paper",
                           "opm_entries", "opm_entries_paper"]))
    exact = [r for r in rows if r["scheme"] in
             ("no_reuse", "filter_reuse", "ifmap_reuse")]
    all_exact = all(r[k] == r[f"{k}_paper"]
                    for r in exact
                    for k in ("ld", "cal", "copy", "st", "opm_entries"))
    save("table6_static", rows)
    return {"rows": rows, "no_filter_ifmap_exact": all_exact}


if __name__ == "__main__":
    run()
