"""Figs 18/19 — Sparse-NN optimization via Sparse PC Inc.

For the five pruned layers of Table 3 (compress rates from Deep
Compression [23]), run dense vs sparse All-Reuse programs through the
machine model and report the performance gain and energy reduction.
Paper: +26.06% performance, -33.13% energy on average.
"""
from __future__ import annotations

import numpy as np

from repro.core.dataflows import ALEXNET_CONV2, ConvSpec, Reuse
from repro.core.machine import MachineConfig, simulate
from repro.core.sparse import apply_pruning, random_sparse_vectors

from .common import conv_instances, fmt_table, save

#: Table 3 — layer, compress (keep) rate
LAYERS = [
    (ConvSpec("VGG16_CONV4", in_ch=128, out_ch=256, kh=3, kw=3,
              ih=58, iw=58), 0.36),
    (ConvSpec("VGG16_CONV9", in_ch=512, out_ch=512, kh=3, kw=3,
              ih=30, iw=30), 0.27),
    (ConvSpec("VGG16_CONV11", in_ch=512, out_ch=512, kh=3, kw=3,
              ih=16, iw=16), 0.35),
    (ALEXNET_CONV2, 0.38),
    (ConvSpec("AlexNet_CONV3", in_ch=256, out_ch=384, kh=3, kw=3,
              ih=15, iw=15), 0.35),
]


def run() -> dict:
    cfg = MachineConfig()
    rng = np.random.default_rng(0)
    rows = []
    perf_gains, energy_reds = [], []
    for spec, keep in LAYERS:
        dense = conv_instances(spec, Reuse.ALL_REUSE, 4, repeats=4)
        rd = simulate(dense, cfg)
        sparse = apply_pruning(dense, random_sparse_vectors(dense, keep,
                                                            rng))
        rs = simulate(sparse, cfg)
        gain = rd.cycles / rs.cycles - 1.0
        red = 1.0 - rs.energy_pj / rd.energy_pj
        perf_gains.append(gain)
        energy_reds.append(red)
        rows.append({
            "layer": spec.name, "keep": keep,
            "dense_cycles": int(rd.cycles), "sparse_cycles": int(rs.cycles),
            "perf_gain": f"+{gain * 100:.1f}%",
            "energy_red": f"-{red * 100:.1f}%",
        })
    avg_gain = float(np.mean(perf_gains))
    avg_red = float(np.mean(energy_reds))
    print("\n== Fig 19: Sparse-NN via Sparse PC Inc "
          "(paper avg: +26.06% perf, -33.13% energy) ==")
    print(fmt_table(rows, ["layer", "keep", "dense_cycles",
                           "sparse_cycles", "perf_gain", "energy_red"]))
    print(f"average: +{avg_gain * 100:.2f}% perf, "
          f"-{avg_red * 100:.2f}% energy")
    save("fig19_sparse", {"rows": rows, "avg_perf_gain": avg_gain,
                          "avg_energy_reduction": avg_red})
    return {"rows": rows, "avg_perf_gain": avg_gain,
            "avg_energy_reduction": avg_red,
            "positive": avg_gain > 0 and avg_red > 0}


if __name__ == "__main__":
    run()
