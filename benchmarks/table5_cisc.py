"""Table 4/5 — CISC NN-accelerator instructions on RISC-NN.

For every Cambricon/TPU instruction class the paper lists, build the
ExeBlock program, check it against the numpy oracle, and report its
static LD/CAL/COPY/ST/ExeBlock/OPM counts next to the paper's Table 5.
"""
from __future__ import annotations

import numpy as np

from repro.core import gemm_programs as gp
from repro.core.interpreter import MachineState, run_graph

from .common import fmt_table, save


def run() -> dict:
    rows = []
    for name in gp.CISC_OPS:
        g = gp.build_program(name)
        got = g.totals()
        want = gp.PAPER_TABLE5[name]
        # functional validation against the oracle
        state = MachineState(opm_entries=16 * 128 * 8)
        rng = np.random.default_rng(1)
        operands = gp.seed_operands(state, name, rng)
        run_graph(g, state)
        ref = gp.oracle(name, operands)
        out = gp.read_result(state, name)
        ok = np.allclose(out, ref, rtol=1e-4, atol=1e-4)
        rows.append({
            "op": name, "oracle_ok": ok,
            "ld": got["ld"], "ld_paper": want["ld"],
            "cal": got["cal"], "cal_paper": want["cal"],
            "copy": got["copy"], "copy_paper": want["copy"],
            "st": got["st"], "st_paper": want["st"],
            "blocks": got["exeblocks"], "blocks_paper": want["exeblocks"],
            "opm": got["opm_entries"], "opm_paper": want["opm"],
        })
    print("\n== Table 5: CISC instructions as ExeBlock programs ==")
    print(fmt_table(rows, ["op", "oracle_ok", "ld", "ld_paper", "cal",
                           "cal_paper", "copy", "copy_paper", "st",
                           "st_paper", "blocks", "blocks_paper",
                           "opm", "opm_paper"]))
    save("table5_cisc", rows)
    return {"rows": rows,
            "all_oracles_pass": all(r["oracle_ok"] for r in rows)}


if __name__ == "__main__":
    run()
