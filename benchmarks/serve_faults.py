"""Fault-tolerant serving: a faulted fleet vs a fault-free fleet on
identical traces.

The robustness claim (docs/robustness.md) is that losing a replica
costs *recompute*, never *correctness*: a crashed replica's requests
are rebuilt from the router-side recovery journal at their
confirmed-token frontier, replayed on survivors, and the elastic
controller repairs the fleet back to its replica floor.  This
benchmark runs the same trace through two arms with identical
per-replica resources:

* **clean** — an ``ElasticController`` over two replicas, no faults:
  the PR-9-identical baseline (its counters double as the
  untouched-run reference).
* **faulted** — the same fleet, but one replica carries a scripted
  **crash** mid-decode and the other a short **stall** (below the
  watchdog's patience, so it heals invisibly).  The crash loses live
  requests; the journal rebuilds them; the repair loop replaces the
  dead replica.

Every gate is a deterministic counter identity (synthetic step clock;
wall time never gates):

* ``complete_ok`` — zero dropped or duplicated streams in both arms
  (every rid finishes exactly once),
* ``parity_ok``   — every finished stream in BOTH arms is bitwise-equal
  to ``greedy_generate``: a crash moves a stream, never changes it,
* ``faults_ok``   — the faulted arm saw >= 1 failure, recovered >= 1
  request and replayed >= 1 confirmed token; the clean arm saw none,
* ``replay_ok``   — recovery replay is bounded by the journal frontier:
  replayed tokens never exceed what the recovered streams had
  confirmed (and the fleet's ``n_replay_steps`` accounts for them),
* ``repaired_ok`` — the repair loop restored the fleet to its replica
  floor (>= 1 repair, not degraded at drain).

    PYTHONPATH=src python -m benchmarks.serve_faults [--smoke]
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import (ElasticController, ElasticPolicy,
                         FaultInjector, Request, RequestRouter,
                         ServeEngine, ServePrograms, greedy_generate)
from repro.serve.kv_cache import pages_needed

from .common import (fmt_table, metrics_snapshot, save,
                     warm_serve_arms)

ARCH = "qwen3-0.6b"
PAGE, BATCH, CHUNK = 8, 4, 16
PREFIX_LEN, UNIQUE_LEN = 24, 8
SHORT_GEN, LONG_GEN = 4, 12
CRASH_AT = 6           # crash mid-decode: lost requests carry tokens
STALL_AT, STALL_FOR = 12, 3   # < stall_patience (8): heals invisibly


def _trace(cfg, n: int, seed: int = 0):
    """Shared-prefix requests with ragged arrivals over 8 steps; every
    fourth request is a long generation (in flight when the crash
    lands)."""
    rng = np.random.default_rng(seed)

    def walk(length):
        base = rng.integers(0, cfg.vocab_size)
        drift = rng.integers(0, 17, size=length)
        return ((base + np.cumsum(drift)) % cfg.vocab_size).astype(np.int32)

    prefix = walk(PREFIX_LEN)
    return [Request(rid=i,
                    prompt=np.concatenate([prefix, walk(UNIQUE_LEN)]),
                    max_new_tokens=LONG_GEN if i % 4 == 3 else SHORT_GEN,
                    arrival=float(i % 8))
            for i in range(n)]


def _engine(model, params, programs, n_pages):
    return ServeEngine(model, params, max_batch=BATCH, n_pages=n_pages,
                       page_size=PAGE, chunk_size=CHUNK,
                       max_pages_per_seq=pages_needed(
                           PREFIX_LEN + UNIQUE_LEN + LONG_GEN, PAGE),
                       spec_k=0, programs=programs)


def _fleet(mk, *, faulted: bool):
    """Two replicas + repair factory; the faulted arm wraps them in
    scripted ``FaultInjector``s (same engines, same resources)."""
    a, b = mk(), mk()
    if faulted:
        a = FaultInjector(a, crash_at=CRASH_AT)
        b = FaultInjector(b, stall_at=STALL_AT, stall_for=STALL_FOR)
    router = RequestRouter([a, b], policy="least-loaded")
    return ElasticController(router, mk, policy=ElasticPolicy(
        min_replicas=2, max_replicas=2, scale_interval=64,
        repair_backoff=1))


def _drive(front, reqs):
    for r in reqs:
        front.submit(r)
    t = 0
    while True:
        more = front.step(now=float(t))
        t += 1
        assert t < 5000, "fleet failed to drain the trace"
        if not more and t > max(r.arrival for r in reqs):
            break
    return front.stats()


def _oracle_streams(model, params, reqs):
    want = {}
    for gen in (SHORT_GEN, LONG_GEN):
        group = [r for r in reqs if r.max_new_tokens == gen]
        toks = np.stack([r.prompt for r in group])
        out = np.asarray(greedy_generate(
            model, params, {"tokens": toks}, gen,
            toks.shape[1] + gen))
        for r, row in zip(group, out):
            want[r.rid] = row
    return want


def _check(reqs, finished, want):
    rids = [r.rid for r in finished]
    complete = sorted(rids) == sorted(r.rid for r in reqs)
    parity = complete and all(
        np.array_equal(np.asarray(r.generated, np.int32), want[r.rid])
        for r in finished)
    return complete, parity


def run(smoke: bool = False) -> dict:
    n_reqs = 10 if smoke else 20
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    seq_pages = pages_needed(PREFIX_LEN + UNIQUE_LEN + LONG_GEN, PAGE)
    n_pages = 2 + BATCH * (seq_pages + 1) + pages_needed(PREFIX_LEN, PAGE)
    programs = ServePrograms(model)

    def mk():
        return _engine(model, params, programs, n_pages)

    warm_serve_arms([mk()], lambda: _trace(cfg, 4, seed=99))
    reqs = _trace(cfg, n_reqs)
    want = _oracle_streams(model, params, reqs)

    clean = _fleet(mk, faulted=False)
    st_clean = _drive(clean, _trace(cfg, n_reqs))
    clean_ok, clean_parity = _check(reqs, clean.finished, want)

    faulted = _fleet(mk, faulted=True)
    st_fault = _drive(faulted, reqs)
    fault_ok, fault_parity = _check(reqs, faulted.finished, want)

    # replay bounded by the journal frontier: a recovered stream never
    # replays more than it had confirmed when its replica died (the
    # final stream length upper-bounds the frontier), and the fleet's
    # replay counter accounts for every recovery replay step
    recovered = [r for r in faulted.finished
                 if r.rid in faulted.router.failed_rids]
    replayed = int(st_fault["n_recovery_replayed_tokens"])
    frontier_bound = sum(len(r.generated) for r in recovered)
    replay_ok = (0 < replayed <= frontier_bound
                 and st_fault["n_replay_steps"] >= replayed)

    faults_ok = (st_fault["n_failures"] >= 1
                 and st_fault["n_recovered_requests"] >= 1
                 and st_clean["n_failures"] == 0
                 and st_clean["n_recovered_requests"] == 0)
    repaired_ok = (st_fault["n_repairs"] >= 1
                   and not faulted.degraded
                   and len(faulted.replicas) == 2)

    rows = []
    for name, st in (("clean", st_clean), ("faulted", st_fault)):
        rows.append({
            "arm": name,
            "failures": int(st["n_failures"]),
            "recovered": int(st["n_recovered_requests"]),
            "replayed_toks": int(st["n_recovery_replayed_tokens"]),
            "repairs": int(st["n_repairs"]),
            "replica_steps": int(st["n_engine_steps"]),
            "dispatches": int(st["n_total_dispatches"])})
    print(f"\n== Fault-tolerant serving: {n_reqs} reqs, crash@"
          f"{CRASH_AT} + stall@{STALL_AT}x{STALL_FOR}, "
          f"{n_pages} pages/replica ==")
    print(fmt_table(rows, ["arm", "failures", "recovered",
                           "replayed_toks", "repairs", "replica_steps",
                           "dispatches"]))
    print(f"recovered {len(recovered)} streams, replayed {replayed} "
          f"confirmed tokens (bound {frontier_bound}); parity "
          f"clean={clean_parity} faulted={fault_parity}")
    out = {"rows": rows,
           "n_failures": int(st_fault["n_failures"]),
           "n_recovered_requests": int(st_fault["n_recovered_requests"]),
           "n_recovery_replayed_tokens": replayed,
           "n_repairs": int(st_fault["n_repairs"]),
           "recovery_overhead_steps": int(st_fault["n_engine_steps"])
           - int(st_clean["n_engine_steps"]),
           "complete_ok": clean_ok and fault_ok,
           "parity_ok": clean_parity and fault_parity,
           "faults_ok": faults_ok,
           "replay_ok": replay_ok,
           "repaired_ok": repaired_ok,
           "metrics_snapshot": metrics_snapshot(faulted)}
    save("serve_faults", out)
    return out


if __name__ == "__main__":
    out = run(smoke="--smoke" in sys.argv[1:])
    gates = [v for v in out.values() if isinstance(v, bool)]
    raise SystemExit(0 if all(gates) else 1)
