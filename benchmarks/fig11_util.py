"""Figs 11/12 + Table 7 — MAC-unit utilization of the five CNN
implementations, single-instance and best-of-N-instances.

The paper's headline: single-instance All-Reuse reaches ~22.9% average
utilization vs 2.1% for No-Reuse (Fig 11), and with multi-instance
ExeBlock-level parallelism All-Reuse reaches ~74.4% while the others
saturate earlier because of shared-resource contention (Fig 12/Table 7).
We reproduce the *ordering and saturation behaviour* with the
event-driven machine model; exact percentages depend on unpublished
u-arch latencies (DESIGN.md §2).
"""
from __future__ import annotations

from repro.core.dataflows import ALEXNET_CONV2, Reuse
from repro.core.machine import MachineConfig, simulate

from .common import conv_instances, fmt_table, save

#: smaller instance sweep than the paper's 8 to keep CI wall-time sane;
#: override with --full
SWEEP = (1, 2, 4, 8)


def run(sweep=SWEEP, spec=ALEXNET_CONV2, smoke: bool = False) -> dict:
    cfg = MachineConfig()
    if smoke:
        sweep = sweep[:2]
    repeats = 4 if smoke else 32
    rows = []
    best = {}
    for scheme in Reuse:
        utils = {}
        for n in sweep:
            # steady state: the task loops itself (paper §5.2)
            g = conv_instances(spec, scheme, n, repeats=repeats)
            r = simulate(g, cfg)
            utils[n] = r.mac_utilization
        rows.append({"scheme": scheme.value,
                     **{f"x{n}": f"{u:.3f}" for n, u in utils.items()},
                     "best_n": max(utils, key=utils.get),
                     "best": f"{max(utils.values()):.3f}"})
        best[scheme.value] = max(utils.values())
    print("\n== Fig 11/12 + Table 7: MAC utilization vs instances ==")
    print(fmt_table(rows, ["scheme"] + [f"x{n}" for n in sweep]
                    + ["best_n", "best"]))
    ordering_ok = (best["all_reuse"] >= max(
        v for k, v in best.items() if k != "all_reuse"))
    save("fig11_util", rows)
    return {"rows": rows, "all_reuse_best": ordering_ok,
            "best": best}


if __name__ == "__main__":
    run()
