"""Fused engine step — ONE program launch per steady-state step vs the
two-dispatch engine (chunked-prefill launch + decode launch).

The unfused engine pays a fixed two-launch tax on every steady-state
step: one batched-prefill chunk dispatch to ingest prompt work, one
decode/verify dispatch to advance the active batch.  The fused
uber-program runs both op sequences in a single launch (prefill rows
flash-attend over their chunk, decode rows gather their pages — see
``DecoderLM.fused_step_paged`` for the disjointness argument), so the
per-step cost drops 2 -> 1 wherever the trace keeps both kinds of work
in flight.

The trace here keeps it in flight by construction — a serving mix with
two request classes:

* a few long-decode sessions that admit first and then occupy every
  decode slot for the entire run (chat tails), and
* a sustained stream of single-chunk ``max_new_tokens=1`` requests
  (classification / scoring calls) whose promotion token is their whole
  stream, so they exercise prefill on every step without competing for
  decode slots.

Decode occupancy is then identical in both arms (the long sessions),
prefill supply outlasts the decode tails, and the dispatch ledger is
deterministic: the unfused arm spends ~2 launches per step, the fused
arm ~1.  Reported gates (all sizes — dispatch counts are
machine-independent; wall clocks on shared runners can't fake them):

* ``fused_dispatch_ok`` — >= 1.8x fewer TOTAL dispatches, fused vs
  unfused, on the same trace (measured via ``n_total_dispatches``,
  which counts every program launch: prefill chunks, decode/verify
  rounds, replay, fused),
* ``token_parity`` / ``oracle_parity`` — every stream bitwise-equal to
  the unfused engine and to sequential ``greedy_generate``, every rep.

tokens/s rides along as context (wall clock).  Warm medians: both arms
share one ``ServePrograms`` bundle and are warmed at their exact
pool/batch/bucket shapes via ``benchmarks.common.warm_serve_arms``.

    PYTHONPATH=src python -m benchmarks.serve_fused [--smoke]
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import Request, ServeEngine, ServePrograms, greedy_generate
from repro.serve.kv_cache import pages_needed

from .common import (fmt_table, metrics_snapshot, save,
                     warm_serve_arms)

ARCH = "qwen3-0.6b"
PAGE = 8
PROMPT_LEN = 16        # == chunk_size: one chunk per prompt
BATCH = 5              # 1 prefilling slot + 4 long-decode slots
N_LONG = 4

COUNTERS = ["n_prefill_dispatches", "n_decode_steps", "n_replay_steps",
            "n_fused_dispatches", "n_total_dispatches"]


def _mk_trace(cfg, n_short, gen_long, seed=1):
    """N_LONG chat-tail sessions + a stream of one-shot scoring calls.
    The long sessions are listed first so they admit first and hold the
    decode slots for the whole run."""
    rng = np.random.default_rng(seed)

    def prompt():
        return rng.integers(0, cfg.vocab_size,
                            size=(PROMPT_LEN,)).astype(np.int32)

    return ([Request(rid=i, prompt=prompt(), max_new_tokens=gen_long)
             for i in range(N_LONG)]
            + [Request(rid=N_LONG + i, prompt=prompt(),
                       max_new_tokens=1) for i in range(n_short)])


def _trace(eng, reqs):
    before = {k: eng.stats()[k] for k in COUNTERS}
    t0 = time.perf_counter()
    done = eng.run(reqs, realtime=False)
    dt = time.perf_counter() - t0
    after = eng.stats()
    n_tok = sum(len(r.generated) for r in done)
    return {"tokens": {r.rid: np.asarray(r.generated, np.int32)
                       for r in done},
            "tok_per_s": n_tok / max(dt, 1e-9),
            **{k: after[k] - before[k] for k in COUNTERS}}


def _oracle(model, params, reqs):
    return {r.rid: np.asarray(greedy_generate(
        model, params, {"tokens": r.prompt[None]}, r.max_new_tokens,
        cache_len=len(r.prompt) + r.max_new_tokens))[0] for r in reqs}


def run(smoke: bool = False) -> dict:
    n_short, gen_long = (28, 30) if smoke else (48, 50)
    reps = 2 if smoke else 3
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pps = pages_needed(PROMPT_LEN + gen_long, PAGE)
    n_pages = 2 + BATCH * (pps + 2)
    programs = ServePrograms(model)

    def mk(fused):
        return ServeEngine(model, params, fused=fused, max_batch=BATCH,
                           n_pages=n_pages, page_size=PAGE,
                           max_pages_per_seq=pps,
                           chunk_size=PROMPT_LEN, prefill_batch=1,
                           prefix_sharing=False, programs=programs)

    engines = {True: mk(True), False: mk(False)}
    # warm at the exact shapes the measured trace touches: one
    # full-length session walks the decode program through every
    # context bucket a long request reaches, shorts warm the chunk and
    # fused programs (token population disjoint via the seed)
    warm_serve_arms(engines.values(),
                    lambda: _mk_trace(cfg, 3, gen_long, seed=99))
    oracle = _oracle(model, params, _mk_trace(cfg, n_short, gen_long))

    fused_runs, unfused_runs = [], []
    parity = oracle_parity = True
    for _ in range(reps):
        f = _trace(engines[True], _mk_trace(cfg, n_short, gen_long))
        u = _trace(engines[False], _mk_trace(cfg, n_short, gen_long))
        fused_runs.append(f)
        unfused_runs.append(u)
        parity &= all(np.array_equal(f["tokens"][rid], u["tokens"][rid])
                      for rid in u["tokens"])
        oracle_parity &= all(np.array_equal(f["tokens"][rid], oracle[rid])
                             for rid in oracle)
    f, u = fused_runs[-1], unfused_runs[-1]
    # dispatch counts are deterministic across reps (greedy,
    # realtime=False): the ratio below equals its median
    ratio = u["n_total_dispatches"] / max(f["n_total_dispatches"], 1)
    fused_share = f["n_fused_dispatches"] / max(f["n_total_dispatches"],
                                                1)
    tps = {arm: float(np.median([r["tok_per_s"] for r in runs]))
           for arm, runs in (("fused", fused_runs),
                             ("unfused", unfused_runs))}

    rows = [
        {"system": "unfused (chunk + decode dispatch)",
         "tok_per_s": f"{tps['unfused']:.1f}",
         "total_dispatches": u["n_total_dispatches"],
         "fused_dispatches": u["n_fused_dispatches"],
         "decode_steps": u["n_decode_steps"]},
        {"system": "fused (one launch per step)",
         "tok_per_s": f"{tps['fused']:.1f}",
         "total_dispatches": f["n_total_dispatches"],
         "fused_dispatches": f["n_fused_dispatches"],
         "decode_steps": f["n_decode_steps"]},
    ]
    print(f"\n== Fused engine step: {N_LONG} sessions x {gen_long} tok "
          f"decode + {n_short} one-shot prompts ({PROMPT_LEN} tok, "
          f"1 chunk), batch {BATCH} ==")
    print(fmt_table(rows, ["system", "tok_per_s", "total_dispatches",
                           "fused_dispatches", "decode_steps"]))
    print(f"total dispatches: {ratio:.2f}x fewer "
          f"({u['n_total_dispatches']} -> {f['n_total_dispatches']}, "
          f"{fused_share:.0%} of fused-arm launches fused); "
          f"token parity: {parity}; oracle parity: {oracle_parity}")
    out = {"rows": rows,
           "dispatch_ratio": ratio,
           "fused_share": fused_share,
           "tps_fused": tps["fused"],
           "tps_unfused": tps["unfused"],
           # deterministic -> gated at every size
           "fused_dispatch_ok": ratio >= 1.8,
           "token_parity": parity,
           "oracle_parity": oracle_parity,
           "metrics_snapshot": metrics_snapshot(engines[True])}
    save("serve_fused", out)
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
