"""Batched chunked prefill — a burst of short prompts co-ingesting up
to ``prefill_batch`` requests per prompt-chunk dispatch vs the
serialized path (one request per dispatch, ``prefill_batch=1``).

This is the ingestion face of the paper's batch-or-starve argument
(RISC-NN's many-simple-units utilization story; Jouppi et al.'s MXU
version): a chunk program dispatched for ONE short prompt is mostly
per-dispatch overhead, exactly like a decode program at batch 1.
Speculation already drains up to k+1 tokens per decode dispatch, which
left serialized prompt ingestion the dominant dispatch count under
bursts of short prompts — the regime this trace reproduces (a batch's
worth of short prompts arriving at once, repeatedly).

Like prefix sharing and speculation, batching prefill is a pure
*scheduling* win: every program input row is exactly what the
serialized path would have dispatched alone, so generated streams are
bitwise identical (asserted every rep, plus against the sequential
``greedy_generate`` oracle).  Reported gates (all sizes — dispatch
counts are deterministic, the machine-independent face wall clocks on
shared runners can't fake):

* ``prefill_dispatch_ok``  — >= 2x fewer prefill dispatches at
  ``prefill_batch == batch == 8``,
* ``token_parity`` / ``oracle_parity`` — bitwise stream equality,
* ``sharing_burst_ok`` / ``spec_parity_ok`` / ``preempt_parity_ok`` —
  parity legs composing batched prefill with in-burst prefix sharing
  (the admission-order registration invariant must still fire),
  speculative decode, and preemption/replay.

tokens/s and mean TTFT ride along as context (wall clock — expect the
dispatch ratio, not these, to be stable across machines).

    PYTHONPATH=src python -m benchmarks.serve_prefill [--smoke]
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import Request, ServeEngine, ServePrograms, greedy_generate
from repro.serve.kv_cache import pages_needed
from repro.launch.serve import synth_requests

from .common import fmt_table, save, warm_serve_arms

ARCH = "qwen3-0.6b"
BATCH = 8              # decode slots AND co-ingesting prefill rows
PAGE, CHUNK = 8, 16


def _trace(eng, reqs, realtime=False):
    # snapshot cumulative counters so warmup / earlier reps are
    # excluded from this rep's numbers.  The gated reps run
    # realtime=False: the whole trace queues up-front, so admission
    # grouping — and with it the dispatch count — is deterministic
    # (a wall-clock arrival replay would make co-ingestion width a
    # race between step duration and arrival gaps).  realtime=True is
    # only for the TTFT context pass.
    disp0, chunks0 = eng.n_prefill_dispatches, eng.n_prefill_chunks
    t0 = time.perf_counter()
    done = eng.run(reqs, realtime=realtime)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    return {"tokens": {r.rid: np.asarray(r.generated, np.int32)
                       for r in done},
            "tok_per_s": n_tok / max(dt, 1e-9),
            "ttft_mean_s": (float(np.mean([r.ttft for r in done]))
                            if realtime else float("nan")),
            "dispatches": eng.n_prefill_dispatches - disp0,
            "chunks": eng.n_prefill_chunks - chunks0}


def _oracle(model, params, reqs):
    return {r.rid: np.asarray(greedy_generate(
        model, params, {"tokens": r.prompt[None]}, r.max_new_tokens,
        cache_len=len(r.prompt) + r.max_new_tokens))[0] for r in reqs}


def _streams(eng, reqs):
    return {r.rid: np.asarray(r.generated, np.int32)
            for r in eng.run(reqs, realtime=False)}


def _parity_legs(model, params, cfg, programs) -> dict:
    """Batched prefill composed with the rest of the serve stack, each
    leg bitwise-compared against its serialized twin."""
    rng = np.random.default_rng(5)
    gen = 6
    out = {}

    # in-burst prefix sharing: the prefix straddles a page boundary so
    # COW forks sit on the path, and the burst arrives together so the
    # admission-order registration invariant is what makes it share
    prefix = rng.integers(0, cfg.vocab_size, size=(20,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size,
                                            size=(7,)).astype(np.int32)])
               for _ in range(4)]

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=gen)
                for i, p in enumerate(prompts)]

    kw = dict(max_batch=4, n_pages=48, page_size=PAGE,
              max_pages_per_seq=8, chunk_size=CHUNK, programs=programs)
    want = _streams(ServeEngine(model, params, prefill_batch=1, **kw),
                    reqs())
    shared = ServeEngine(model, params, prefill_batch=4, **kw)
    got = _streams(shared, reqs())
    out["sharing_burst_ok"] = (
        all(np.array_equal(want[i], got[i]) for i in want)
        and shared.cache.n_shared_tokens >= 3 * len(prefix))

    # speculative decode downstream of a co-ingested burst
    spec = ServeEngine(model, params, prefill_batch=4, spec_k=4, **kw)
    got = _streams(spec, reqs())
    out["spec_parity_ok"] = (
        all(np.array_equal(want[i], got[i]) for i in want)
        and spec.n_spec_rounds >= 1)

    # preemption mid-flight under a tight pool, with recompute-replay
    lens = [30, 28, 18]
    pre = [rng.integers(0, cfg.vocab_size, size=(L,)).astype(np.int32)
           for L in lens]

    def pre_reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=8)
                for i, p in enumerate(pre)]

    pkw = dict(max_batch=3, n_pages=13, page_size=PAGE,
               max_pages_per_seq=8, prefix_sharing=False,
               chunk_size=CHUNK, programs=programs)
    want = _streams(ServeEngine(model, params, prefill_batch=1, **pkw),
                    pre_reqs())
    tight = ServeEngine(model, params, prefill_batch=3, **pkw)
    got = _streams(tight, pre_reqs())
    out["preempt_parity_ok"] = (
        all(np.array_equal(want[i], got[i]) for i in want)
        and tight.n_replay_steps >= 1)
    return out


def run(smoke: bool = False) -> dict:
    # short prompts (2 chunks each) arriving in batch-sized bursts:
    # the serialized path pays one dispatch per chunk per request
    n_req, gen = (16, 8) if smoke else (24, 16)
    prompt_len = 24
    reps = 2 if smoke else 3
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    total = prompt_len + gen
    per_seq = pages_needed(total, PAGE) + 2
    # slack for trie donations of finished prompts (both arms equal)
    n_pages = 2 + BATCH * per_seq + 3 * pages_needed(total, PAGE)
    programs = ServePrograms(model)

    def mk(prefill_batch):
        # sharing off in the measured arms: the prompts are distinct,
        # and without it every rep re-ingests every chunk — the pure
        # co-ingestion A/B (sharing composition has its own leg below,
        # and its own benchmark in serve_prefix.py)
        return ServeEngine(model, params, max_batch=BATCH,
                           n_pages=n_pages, page_size=PAGE,
                           max_pages_per_seq=pages_needed(total, PAGE),
                           chunk_size=CHUNK, prefill_batch=prefill_batch,
                           prefix_sharing=False, programs=programs)

    def fresh(seed):
        # one burst: measured reps ignore arrivals entirely
        # (realtime=False — everything is queued up-front); the high
        # rate keeps the TTFT context pass burst-shaped too
        return synth_requests(cfg, n_req, prompt_len, gen, rate=2000.0,
                              seed=seed)

    engines = {1: mk(1), BATCH: mk(BATCH)}
    # programs specialize on pool shape / prefill batch / bucket: warm
    # each arm at its exact shapes (two 2-chunk prompts touch every
    # bucket the trace uses)
    warm_serve_arms(engines.values(), lambda: fresh(99)[:2])
    oracle = _oracle(model, params, fresh(1))

    batched_runs, serial_runs, parity, oracle_parity = [], [], True, True
    for _ in range(reps):
        b = _trace(engines[BATCH], fresh(1))
        s = _trace(engines[1], fresh(1))
        batched_runs.append(b)
        serial_runs.append(s)
        parity &= all(np.array_equal(b["tokens"][rid], s["tokens"][rid])
                      for rid in s["tokens"])
        oracle_parity &= all(np.array_equal(b["tokens"][rid], oracle[rid])
                             for rid in oracle)
    # TTFT context pass: wall-clock arrival replay (NOT gated — the
    # co-ingestion width under replay depends on machine speed)
    ttft_b = _trace(engines[BATCH], fresh(1), realtime=True)
    ttft_s = _trace(engines[1], fresh(1), realtime=True)
    parity &= all(np.array_equal(ttft_b["tokens"][rid],
                                 ttft_s["tokens"][rid])
                  for rid in ttft_s["tokens"])
    b, s = batched_runs[-1], serial_runs[-1]
    dispatch_ratio = s["dispatches"] / max(b["dispatches"], 1)
    tps_ratio = (float(np.median([r["tok_per_s"] for r in batched_runs]))
                 / float(np.median([r["tok_per_s"] for r in serial_runs])))

    rows = [
        {"system": "serialized (1 req/dispatch)",
         "tok_per_s": f"{np.median([r['tok_per_s'] for r in serial_runs]):.1f}",
         "ttft_ms": f"{ttft_s['ttft_mean_s'] * 1e3:.0f}",
         "prefill_dispatches": s["dispatches"], "chunks": s["chunks"]},
        {"system": f"batched (up to {BATCH} reqs/dispatch)",
         "tok_per_s": f"{np.median([r['tok_per_s'] for r in batched_runs]):.1f}",
         "ttft_ms": f"{ttft_b['ttft_mean_s'] * 1e3:.0f}",
         "prefill_dispatches": b["dispatches"], "chunks": b["chunks"]},
    ]
    print(f"\n== Batched chunked prefill: {n_req} reqs x {prompt_len} "
          f"prompt tok (burst), gen {gen}, batch {BATCH}, "
          f"chunk {CHUNK} ==")
    print(fmt_table(rows, ["system", "tok_per_s", "ttft_ms",
                           "prefill_dispatches", "chunks"]))
    legs = _parity_legs(model, params, cfg, programs)
    print(f"prefill dispatches: {dispatch_ratio:.2f}x fewer "
          f"({s['dispatches']} -> {b['dispatches']} for {b['chunks']} "
          f"chunks, {b['chunks'] / max(b['dispatches'], 1):.2f} "
          f"rows/dispatch); tokens/s ratio {tps_ratio:.2f}x; "
          f"token parity: {parity}; oracle parity: {oracle_parity}; "
          f"legs: {legs}")
    out = {"rows": rows,
           "dispatch_ratio": dispatch_ratio,
           "tps_ratio": tps_ratio,
           "ttft_serial_s": ttft_s["ttft_mean_s"],
           "ttft_batched_s": ttft_b["ttft_mean_s"],
           "rows_per_dispatch": b["chunks"] / max(b["dispatches"], 1),
           # dispatch counts are deterministic -> gated at every size
           # (wall-clock ratios stay report-only; shared runners lie)
           "prefill_dispatch_ok": dispatch_ratio >= 2.0,
           "token_parity": parity,
           "oracle_parity": oracle_parity,
           **legs}
    save("serve_prefill", out)
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv[1:])
