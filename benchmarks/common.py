"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from repro.core.dataflows import Reuse, build_conv_program
from repro.core.exeblock import ExeBlock, ExecutionGraph, Task
from repro.core.isa import Instr, Op

RESULTS = Path(__file__).resolve().parents[1] / "results" / "benchmarks"


class Skip(Exception):
    """Raised by a benchmark that cannot run in this environment (a
    missing dependency, too few devices for its mesh, ...).  The
    harness (benchmarks/run.py) reports the reason in its summary
    instead of letting the benchmark either crash or silently vanish —
    a skipped gate must be visible in CI."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


def save(name: str, payload) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=2,
                                                     default=str))


def warm_serve_arms(engines, make_requests) -> None:
    """Drive a small warmup trace through every benchmark arm so jit
    compiles land outside the measured window.

    The serving programs specialize on the page-pool shape (``n_pages``
    × ``page_size``), the prefill batch, and each context bucket a
    trace touches — so warmup MUST run on engines with the arms' exact
    pool/batch shapes (usually the measured engines themselves, or a
    throwaway engine sharing their ``ServePrograms`` bundle *and*
    shapes).  A mismatched warmup doesn't fail; it silently recompiles
    mid-measurement, which is how two earlier benchmarks grew the same
    subtle bug this helper hoists away.

    ``make_requests()`` must return *fresh* ``Request`` objects on
    every call (engines fill ``.generated`` in place), with a token
    population disjoint from the measured trace wherever the arm's
    prefix trie / drafter must start cold.
    """
    for eng in engines:
        eng.run(make_requests(), realtime=False)


def metrics_snapshot(backend) -> Dict[str, float]:
    """Flattened metrics-registry snapshot of a serve backend's
    telemetry (``name{label=value,...} -> value``), or ``{}`` for a
    backend without one.  Benchmarks attach this under the
    ``metrics_snapshot`` key so summary.json carries the full labelled
    registry next to the headline scalars."""
    tel = getattr(backend, "tel", None)
    return dict(tel.registry.snapshot()) if tel is not None else {}


def fmt_table(rows: List[Dict], cols: List[str]) -> str:
    if not rows:
        return "  ".join(cols) + "\n(no rows)"
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    line = "  ".join(c.ljust(widths[c]) for c in cols)
    out = [line, "  ".join("-" * widths[c] for c in cols)]
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(widths[c])
                             for c in cols))
    return "\n".join(out)


def _rename_block(b: ExeBlock, prefix: str) -> ExeBlock:
    return ExeBlock(
        name=prefix + b.name,
        instrs=list(b.instrs),
        logical_pe=b.logical_pe,
        priority=b.priority,
        successors=[prefix + s for s in b.successors],
        sparse_execution=b.sparse_execution,
        inst_dram_address=b.inst_dram_address,
    )


def merge_instances(graphs: List[ExecutionGraph]) -> ExecutionGraph:
    """Run N program instances concurrently: merge task-k of every
    instance into one task (paper §5.2.2 multi-instance execution)."""
    n_tasks = max(len(g.tasks) for g in graphs)
    tasks = []
    for t in range(n_tasks):
        blocks: List[ExeBlock] = []
        ld_base = st_base = 0
        repeats = 1
        for i, g in enumerate(graphs):
            if t < len(g.tasks):
                src = g.tasks[t]
                ld_base, st_base = src.ld_base, src.st_base
                repeats = max(repeats, src.repeats)
                blocks += [_rename_block(b, f"I{i}:") for b in src.blocks]
        tasks.append(Task(task_id=t, blocks=blocks,
                          ld_base=ld_base, st_base=st_base,
                          repeats=repeats))
    return ExecutionGraph(name=graphs[0].name + f"(x{len(graphs)})",
                          tasks=tasks)


def conv_instances(spec, scheme: Reuse, n_instances: int,
                   **kw) -> ExecutionGraph:
    """N concurrent instances.  ``repeats`` (paper §5.2: 'only one task
    which loops itself multiple times') models steady state: instruction
    images load once and data reuse spans iterations."""
    graphs = [build_conv_program(spec, scheme, instance=i, **kw)
              for i in range(n_instances)]
    return merge_instances(graphs) if len(graphs) > 1 else graphs[0]
