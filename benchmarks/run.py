"""Benchmark harness entry point: one module per paper table/figure,
plus the serving-throughput benchmarks.

    PYTHONPATH=src python -m benchmarks.run           # everything
    PYTHONPATH=src python -m benchmarks.run table6    # one benchmark
    PYTHONPATH=src python -m benchmarks.run --smoke   # CI: reduced sizes

``--smoke`` runs every benchmark at reduced problem size (benches whose
``run`` accepts a ``smoke`` kwarg) and fails loudly if any entry point
errors — the CI guard against perf entry points silently rotting.

A benchmark whose environment requirements aren't met (devices, deps)
raises ``common.Skip(reason)``; the summary prints the reason instead
of hiding the benchmark — a gate that didn't run must be visible.
"""
from __future__ import annotations

import inspect
import sys
import time

from .common import Skip, save
from . import (fig11_util, fig13_traffic, fig15_energy, fig19_sparse,
               fig22_simd, fig23_scaling, kernel_dataflow, roofline,
               serve_elastic, serve_faults, serve_fused, serve_prefill,
               serve_prefix, serve_router, serve_slo, serve_spec,
               serve_throughput, table5_cisc, table6_static)

BENCHES = {
    "table5": table5_cisc.run,
    "table6": table6_static.run,
    "fig11": fig11_util.run,
    "fig13": fig13_traffic.run,
    "fig15": fig15_energy.run,
    "fig19": fig19_sparse.run,
    "fig22": fig22_simd.run,
    "kernel": kernel_dataflow.run,
    "roofline": roofline.run,
    "serve": serve_throughput.run,
    "serve_prefix": serve_prefix.run,
    "serve_prefill": serve_prefill.run,
    "serve_fused": serve_fused.run,
    "serve_spec": serve_spec.run,
    "serve_router": serve_router.run,
    "serve_slo": serve_slo.run,
    "serve_elastic": serve_elastic.run,
    "serve_faults": serve_faults.run,
    "fig23": fig23_scaling.run,
}


def _metrics(out: dict) -> dict:
    """Scalar metrics worth tracking across PRs (gates are reported
    separately; tables and token dumps are noise at trend granularity).
    The labelled telemetry registry rides along under its own
    ``metrics_snapshot`` key — serve benches attach it via
    ``common.metrics_snapshot`` — kept intact, not flattened into the
    scalar trend."""
    m = {k: v for k, v in (out or {}).items()
         if isinstance(v, (int, float)) and not isinstance(v, bool)}
    snap = (out or {}).get("metrics_snapshot")
    if snap:
        m["metrics_snapshot"] = snap
    return m


def main(argv):
    smoke = "--smoke" in argv
    unknown = [a for a in argv if a.startswith("--") and a != "--smoke"]
    if unknown:
        print(f"unknown flags: {unknown}; known: --smoke", file=sys.stderr)
        return 2
    names = [a for a in argv if not a.startswith("--")] or list(BENCHES)
    summary = []
    for name in names:
        t0 = time.time()
        try:
            kw = {}
            if smoke and "smoke" in inspect.signature(
                    BENCHES[name]).parameters:
                kw["smoke"] = True
            out = BENCHES[name](**kw)
            checks = {k: v for k, v in (out or {}).items()
                      if isinstance(v, bool)}
            ok = all(checks.values()) if checks else True
            summary.append((name, "ok" if ok else "CHECK-FAILED",
                            time.time() - t0, checks, _metrics(out)))
        except Skip as s:
            summary.append((name, f"SKIPPED: {s.reason}",
                            time.time() - t0, {}, {}))
        except Exception as e:                      # noqa: BLE001
            import traceback
            traceback.print_exc()
            summary.append((name, f"ERROR: {e}", time.time() - t0, {}, {}))
    print("\n==================== summary ====================")
    failed = 0
    for name, status, dt, checks, _ in summary:
        skipped = status.startswith("SKIPPED")
        flag = "" if status == "ok" or skipped else "  <<<<"
        print(f"{name:12s} {status:14s} {dt:7.1f}s {checks}{flag}")
        if status != "ok" and not skipped:
            failed += 1
    print(f"{len(summary) - failed}/{len(summary)} benchmarks clean")
    # machine-readable perf trajectory: one consolidated file per run
    # (per-benchmark JSONs remain the detailed record) so cross-PR
    # tooling reads one artifact instead of re-deriving the roll-up
    save("summary", {
        "smoke": smoke,
        "benchmarks": {
            name: {"status": status, "seconds": round(dt, 2),
                   "gates": checks, "metrics": metrics}
            for name, status, dt, checks, metrics in summary}})
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
