"""Roofline summary: collate results/dryrun JSONs into the §Roofline
table (all three terms, bottleneck, MODEL_FLOPS ratio, fit)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import fmt_table, save

DRYRUN = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(tag: str = "baseline", mesh: str = "single"):
    cells = []
    for p in sorted(DRYRUN.glob("*.json")):
        if p.name == "skips.json":
            continue
        d = json.loads(p.read_text())
        if d.get("tag", "baseline") != tag or d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def rows_for(cells):
    rows = []
    for d in cells:
        r = d["roofline"]
        la = d["loop_aware"]
        m = d["memory"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "compute_s": f"{r['compute_s']:.4g}",
            "memory_s": f"{r['memory_s']:.4g}",
            "collective_s": f"{r['collective_s']:.4g}",
            "bottleneck": r["bottleneck"],
            "useful": f"{d['model_flops']['useful_ratio']:.2f}",
            "mfrac": f"{r['model_fraction']:.3f}",
            "GB/dev": f"{m['per_device_bytes'] / 1e9:.1f}",
            "fits": m["fits"],
        })
    return rows


def run(tag: str = "baseline") -> dict:
    cells = load_cells(tag)
    rows = rows_for(cells)
    print(f"\n== Roofline table (single-pod, tag={tag}) ==")
    print(fmt_table(rows, ["arch", "shape", "compute_s", "memory_s",
                           "collective_s", "bottleneck", "useful",
                           "mfrac", "GB/dev", "fits"]))
    skips = DRYRUN / "skips.json"
    if skips.exists():
        for s in json.loads(skips.read_text()):
            print(f"   [skipped] {s['arch']} x {s['shape']}: {s['reason']}")
    save(f"roofline_{tag}", rows)
    return {"rows": rows, "n_cells": len(rows)}


if __name__ == "__main__":
    import sys
    run(sys.argv[1] if len(sys.argv) > 1 else "baseline")
