"""Elastic fleet vs peak-provisioned static fleet on a sawtooth
arrival trace.

The datacenter-inference premise (Jouppi et al. 2017): production load
is bursty, and a fleet provisioned for the peak idles through every
trough.  The trace here is the canonical sawtooth — bursts of requests
every ``PERIOD`` steps, each burst a crowd of short interactive
requests plus a couple of long generations that span the following
trough.  Two arms serve identical traces:

* **static** — a ``RequestRouter`` over ``PEAK`` replicas, sized so
  the burst never queues: the classic peak-provisioned fleet.
* **elastic** — an ``ElasticController`` starting at ONE replica with
  the same per-replica resources, scaling up to ``PEAK`` on each burst
  and draining back down through each trough.  Scale-down migrates the
  trough-spanning long requests onto the survivors: extracted at their
  confirmed-token frontier and re-admitted through the target's prefix
  trie, where the shared system prompt is already resident — prompt
  pages rebuild by **donation** (refcount attach), never a byte copy,
  and confirmed tokens replay bit-exactly.

Everything is gated on deterministic counters (the synthetic step
clock drives both arms; wall clock never appears in a gate):

* ``complete_ok``       — zero dropped, duplicated, or reordered
  requests in both arms (every rid finishes exactly once),
* ``parity_ok``         — every finished stream in BOTH arms is
  bitwise-equal to ``greedy_generate``; scaling moves streams, never
  changes them,
* ``migration_reuse_ok``— scale-downs migrated live requests, and the
  migrants re-admitted through trie donation (their re-admission
  ``shared_tokens`` counters report resident-prefix hits; there is no
  byte-copy path to miscount),
* ``elastic_steps_ok``  — the elastic fleet spends FEWER total
  replica-steps than the static fleet (``n_engine_steps`` fleet-wide:
  a replica stepping 2 lonely long requests through a trough is the
  waste elasticity removes).

Both arms share one ``ServePrograms`` compile cache and a warmup at
the exact pool shapes, so jit compiles never land in the measured
window.

    PYTHONPATH=src python -m benchmarks.serve_elastic [--smoke]
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import (ElasticController, ElasticPolicy, Request,
                         RequestRouter, ServeEngine, ServePrograms,
                         greedy_generate)
from repro.serve.kv_cache import pages_needed

from .common import (fmt_table, metrics_snapshot, save,
                     warm_serve_arms)

ARCH = "qwen3-0.6b"
PAGE, BATCH, CHUNK = 8, 4, 16
PEAK = 3               # replicas the static fleet provisions for
PERIOD = 30            # steps between bursts (divisible by SCALE_EVERY)
SCALE_EVERY = 3        # elastic control-round interval
PREFIX_LEN = 24        # shared system prompt (every replica's trie
                       # holds it after one request — migration's
                       # donation target)
UNIQUE_LEN = 8
SHORT_GEN, LONG_GEN = 4, 20   # longs span the trough after the burst


def _sawtooth(cfg, n_bursts: int, seed: int = 0):
    """Bursts of 10 (8 short + 2 long) every PERIOD steps, arrivals
    spread over the burst's first 6 steps.  The longs TRAIL each burst:
    they arrive after the short crowd forced the scale-up, so
    least-loaded dispatch lands them on the freshly-joined replicas —
    exactly the live work the trough's scale-downs must migrate back
    onto the survivor."""
    rng = np.random.default_rng(seed)

    def walk(length):
        base = rng.integers(0, cfg.vocab_size)
        drift = rng.integers(0, 17, size=length)
        return ((base + np.cumsum(drift)) % cfg.vocab_size).astype(np.int32)

    prefix = walk(PREFIX_LEN)
    reqs = []
    for b in range(n_bursts):
        for i in range(10):
            long_ = i >= 8
            reqs.append(Request(
                rid=10 * b + i,
                prompt=np.concatenate([prefix, walk(UNIQUE_LEN)]),
                max_new_tokens=LONG_GEN if long_ else SHORT_GEN,
                arrival=float(b * PERIOD
                              + (i - 4 if long_ else min(i, 3)))))
    return reqs


def _engine(model, params, programs, n_pages):
    return ServeEngine(model, params, max_batch=BATCH, n_pages=n_pages,
                       page_size=PAGE, chunk_size=CHUNK,
                       max_pages_per_seq=pages_needed(
                           PREFIX_LEN + UNIQUE_LEN + LONG_GEN, PAGE),
                       spec_k=0, programs=programs)


def _drive(front, reqs):
    """Synthetic-clock driver (step(now=t), t = 0, 1, 2, ...): both
    arms see identical arrival raggedness, deterministically."""
    for r in reqs:
        front.submit(r)
    t = 0
    while True:
        more = front.step(now=float(t))
        t += 1
        assert t < 5000, "fleet failed to drain the trace"
        if not more and t > max(r.arrival for r in reqs):
            break
    return front.stats()


def _oracle_streams(model, params, reqs):
    """Bitwise-expected streams via ``greedy_generate``, batched per
    generation length (uniform prompt lengths -> two compiles)."""
    want = {}
    for gen in (SHORT_GEN, LONG_GEN):
        group = [r for r in reqs if r.max_new_tokens == gen]
        toks = np.stack([r.prompt for r in group])
        out = np.asarray(greedy_generate(
            model, params, {"tokens": toks}, gen,
            toks.shape[1] + gen))
        for r, row in zip(group, out):
            want[r.rid] = row
    return want


def _check(reqs, finished, want):
    """complete (exactly once) + parity (bitwise) for one arm."""
    rids = [r.rid for r in finished]
    complete = sorted(rids) == sorted(r.rid for r in reqs)
    parity = complete and all(
        np.array_equal(np.asarray(r.generated, np.int32), want[r.rid])
        for r in finished)
    return complete, parity


def run(smoke: bool = False) -> dict:
    n_bursts = 2 if smoke else 3
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # per-replica pool: slots' worst case + the shared prefix, with a
    # little headroom — identical in both arms (elasticity is the only
    # variable)
    seq_pages = pages_needed(PREFIX_LEN + UNIQUE_LEN + LONG_GEN, PAGE)
    n_pages = 2 + BATCH * (seq_pages + 1) + pages_needed(PREFIX_LEN, PAGE)
    programs = ServePrograms(model)

    # warmup: every context bucket + fused/decode shapes at the arms'
    # exact pool shape, on a throwaway engine sharing their bundle
    # (token population disjoint — the measured tries start cold)
    warm_serve_arms([_engine(model, params, programs, n_pages)],
                    lambda: _sawtooth(cfg, 1, seed=99))

    reqs = _sawtooth(cfg, n_bursts)
    want = _oracle_streams(model, params, reqs)

    # static arm: peak-provisioned fixed fleet
    static_router = RequestRouter(
        [_engine(model, params, programs, n_pages) for _ in range(PEAK)],
        policy="least-loaded")
    st_static = _drive(static_router, _sawtooth(cfg, n_bursts))
    static_ok, static_parity = _check(reqs, static_router.finished, want)

    # elastic arm: same per-replica resources, fleet tracks demand
    ctl = ElasticController(
        RequestRouter([_engine(model, params, programs, n_pages)],
                      policy="least-loaded"),
        lambda: _engine(model, params, programs, n_pages),
        policy=ElasticPolicy(min_replicas=1, max_replicas=PEAK,
                             scale_interval=SCALE_EVERY,
                             scale_down_patience=1, alpha=0.8))
    st_el = _drive(ctl, reqs)
    elastic_ok, elastic_parity = _check(reqs, ctl.finished, want)

    # migration actually moved live work, and the migrants' re-admission
    # hit the target's resident prefix (trie donation, refcount-counted)
    migrated = [r for r in ctl.finished
                if r.rid in ctl.router.migrated_rids]
    donated = sum(r.shared_tokens for r in migrated)
    migration_reuse_ok = (st_el["n_migrations"] > 0
                          and len(migrated) > 0
                          and donated >= PAGE)

    steps_static = int(st_static["n_engine_steps"])
    steps_elastic = int(st_el["n_engine_steps"])
    rows = [
        {"fleet": f"static x{PEAK}", "replica_steps": steps_static,
         "peak": PEAK, "scale_ups": 0, "scale_downs": 0,
         "migrations": 0,
         "dispatches": int(st_static["n_total_dispatches"])},
        {"fleet": f"elastic 1..{PEAK}", "replica_steps": steps_elastic,
         "peak": int(st_el["n_replicas_peak"]),
         "scale_ups": int(st_el["n_scale_ups"]),
         "scale_downs": int(st_el["n_scale_downs"]),
         "migrations": int(st_el["n_migrations"]),
         "dispatches": int(st_el["n_total_dispatches"])},
    ]
    print(f"\n== Elastic fleet: {n_bursts} bursts x 10 reqs "
          f"(sawtooth, period {PERIOD}), {PREFIX_LEN}-tok shared "
          f"prefix, {n_pages} pages/replica ==")
    print(fmt_table(rows, ["fleet", "replica_steps", "peak",
                           "scale_ups", "scale_downs", "migrations",
                           "dispatches"]))
    ratio = steps_static / max(steps_elastic, 1)
    print(f"replica-steps ratio {ratio:.2f}x; "
          f"{donated} prefix tokens donated to "
          f"{len(migrated)} migrated streams; parity "
          f"static={static_parity} elastic={elastic_parity}")
    out = {"rows": rows,
           "replica_steps_static": steps_static,
           "replica_steps_elastic": steps_elastic,
           "replica_steps_ratio": ratio,
           "migrations": int(st_el["n_migrations"]),
           "migrated_shared_tokens": int(donated),
           "complete_ok": static_ok and elastic_ok,
           "parity_ok": static_parity and elastic_parity,
           "migration_reuse_ok": migration_reuse_ok,
           "elastic_steps_ok": steps_elastic < steps_static,
           "metrics_snapshot": metrics_snapshot(ctl)}
    save("serve_elastic", out)
    return out


if __name__ == "__main__":
    out = run(smoke="--smoke" in sys.argv[1:])
    gates = [v for v in out.values() if isinstance(v, bool)]
    raise SystemExit(0 if all(gates) else 1)
