"""Serving throughput under a Poisson arrival trace — continuous
batching (paged KV engine) vs a naive one-request-at-a-time greedy
loop.  Reports tokens/s and time-to-first-token.

This is the serving analogue of the paper's multi-instance utilization
story (Fig 12): one request cannot fill the machine, so throughput
comes from packing independent instances — here, sequences sharing one
jit'd decode program through the paged cache.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import build_model
from repro.serve import ServeEngine
from repro.serve.kv_cache import pages_needed
from repro.serve.step import make_decode_step, make_prefill_step
from repro.launch.serve import synth_requests

from .common import fmt_table, metrics_snapshot, save

ARCH = "qwen3-0.6b"


def _make_naive(model, params, cache_len: int):
    """Sequential baseline with the jit'd programs hoisted out of the
    timed region (greedy_generate builds fresh jit wrappers per call,
    which would bill XLA compiles as decode time)."""
    prefill = jax.jit(make_prefill_step(model, max_len=cache_len))
    step = jax.jit(make_decode_step(model))

    def trace(reqs):
        tokens = {}
        ttfts = []
        busy = 0.0
        clock = 0.0
        for r in sorted(reqs, key=lambda r: r.arrival):
            t0 = time.perf_counter()
            last, cache = prefill(params, {"tokens": r.prompt[None]})
            tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
            out = [tok]
            for _ in range(r.max_new_tokens - 1):
                tok, cache = step(params, cache, tok)
                out.append(tok)
            out = np.concatenate([np.asarray(t) for t in out], 1)[0]
            dt = time.perf_counter() - t0
            busy += dt
            clock = max(clock, r.arrival)
            # first token arrives after roughly 1/max_new of the
            # service time (prefill + first decode)
            ttfts.append(clock + dt / r.max_new_tokens - r.arrival)
            clock += dt
            tokens[r.rid] = out
        n_tok = sum(len(v) for v in tokens.values())
        return {"tokens": tokens, "tok_per_s": n_tok / max(busy, 1e-9),
                "ttft_mean_s": float(np.mean(ttfts))}
    return trace


def _engine_trace(eng, reqs):
    steps0 = eng.n_decode_steps
    t0 = time.perf_counter()
    done = eng.run(reqs, realtime=True)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    return {"tokens": {r.rid: np.asarray(r.generated, np.int32)
                       for r in done},
            "tok_per_s": n_tok / max(dt, 1e-9),
            "ttft_mean_s": float(np.mean([r.ttft for r in done])),
            "decode_steps": eng.n_decode_steps - steps0}


def run(smoke: bool = False, batch: int = 8) -> dict:
    n_req, gen = (8, 16) if smoke else (16, 24)
    prompt_len = 24 if smoke else 48
    page_size = 8
    cfg = configs.get_smoke(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    per_seq = (prompt_len + gen) // page_size + 2
    n_pages = 2 + batch * per_seq

    # high arrival rate: the queue builds immediately, so both systems
    # are measured at saturation (the batching regime of interest)
    def fresh():
        return synth_requests(cfg, n_req, prompt_len, gen,
                              rate=500.0, seed=1)

    naive_trace = _make_naive(model, params, prompt_len + gen)
    # max-throughput configuration: chunk pacing is a TTFT knob, so
    # size the chunk to cover the whole prompt (single-chunk prefill)
    eng = ServeEngine(model, params, max_batch=batch, n_pages=n_pages,
                      page_size=page_size,
                      max_pages_per_seq=pages_needed(
                          prompt_len + gen, page_size),
                      chunk_size=prompt_len)

    # warmup: both paths compile outside the timed region (the engine
    # object is reused, so its jit caches carry over)
    naive_trace(fresh()[:1])
    _engine_trace(eng, fresh()[:1])

    naive = naive_trace(fresh())
    engine = _engine_trace(eng, fresh())

    parity = all(
        np.array_equal(engine["tokens"][rid], naive["tokens"][rid])
        for rid in naive["tokens"])
    speedup = engine["tok_per_s"] / naive["tok_per_s"]
    rows = [
        {"system": "naive (1 req at a time)",
         "tok_per_s": f"{naive['tok_per_s']:.1f}",
         "ttft_ms": f"{naive['ttft_mean_s'] * 1e3:.0f}"},
        {"system": f"engine (batch={batch}, paged)",
         "tok_per_s": f"{engine['tok_per_s']:.1f}",
         "ttft_ms": f"{engine['ttft_mean_s'] * 1e3:.0f}"},
    ]
    print(f"\n== Serve throughput: {n_req} reqs "
          f"({prompt_len}+{gen} tok), Poisson arrivals ==")
    print(fmt_table(rows, ["system", "tok_per_s", "ttft_ms"]))
    print(f"continuous batching speedup: {speedup:.2f}x; "
          f"token parity with sequential oracle: {parity}")
    out = {"rows": rows, "speedup": speedup, "token_parity": parity,
           "metrics_snapshot": metrics_snapshot(eng)}
    if not smoke:
        # perf assertion only at full size: smoke problem sizes are too
        # small to amortize the paged gather, and CI runners are noisy
        out["engine_faster"] = speedup > 1.0
    save("serve_throughput", {k: v for k, v in out.items()
                              if k != "tokens"})
    return out


if __name__ == "__main__":
    run()
