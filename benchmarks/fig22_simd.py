"""Fig 22 — energy efficiency (nJ/op) of All-Reuse AlexNet_CONV2 as a
function of SIMD width.  The per-instruction control energy is amortized
over more lanes; the paper reports control at 0.8% of total by SIMD-64
and calls SIMD-8 a reasonable design point."""
from __future__ import annotations

import dataclasses

from repro.core.dataflows import ALEXNET_CONV2, Reuse
from repro.core.machine import MachineConfig, simulate

from .common import conv_instances, fmt_table, save

WIDTHS = (1, 2, 4, 8, 16, 32, 64)


def run() -> dict:
    rows = []
    g = conv_instances(ALEXNET_CONV2, Reuse.ALL_REUSE, 1)
    for w in WIDTHS:
        cfg = dataclasses.replace(MachineConfig(), simd=w)
        r = simulate(g, cfg)
        ops = r.executed_cal_instrs * w * 2
        ctrl_share = r.energy_breakdown["ctrl"] / r.energy_pj
        rows.append({"simd": w,
                     "nJ_per_op": f"{r.energy_pj / 1e3 / ops:.4f}",
                     "ctrl_share": f"{ctrl_share * 100:.2f}%"})
    print("\n== Fig 22: energy vs SIMD width (paper: ctrl -> 0.8% "
          "@ SIMD-64) ==")
    print(fmt_table(rows, ["simd", "nJ_per_op", "ctrl_share"]))
    save("fig22_simd", rows)
    nj = [float(r["nJ_per_op"]) for r in rows]
    ctrl64 = float(rows[-1]["ctrl_share"].rstrip("%"))
    return {"rows": rows, "monotone_decreasing": all(
        a >= b for a, b in zip(nj, nj[1:])), "ctrl_share_simd64": ctrl64}


if __name__ == "__main__":
    run()
