"""Figs 15/16/17 — energy per scheme; efficiency vs GPGPU and TPU.

Fig 15: normalized energy of the five CNN schemes (No-Reuse highest,
All-Reuse lowest).  Figs 16/17 use published reference points (the
paper's own methodology — Titan Xp via nvidia-smi, TPU from [28]):

* Titan Xp: 12.15 TFLOPS fp32 peak / 250 W = 0.049 TOPS/W peak; the
  paper's measured-NN efficiency extrapolations put effective fp32
  efficiency at ~0.03 TOPS/W and 2x that for fp16.
* TPU v1 (16-bit): 23 TOPS peak at ~40 W measured = 0.575 TOPS/W peak,
  derated by the utilizations TPU reports per app class
  (CNN 54.4%, MLP 11.96%, LSTM 3.53% — paper Fig 17a).

We report OUR simulated TOPS/W (per-op energy from the machine model)
against these references, reproducing the ratio *structure* of
Figs 16/17 (RISC-NN's advantage grows CNN -> MLP -> LSTM because its
utilization degrades far less).
"""
from __future__ import annotations

from repro.core import gemm_programs as gp
from repro.core.dataflows import ALEXNET_CONV2, Reuse
from repro.core.machine import MachineConfig, simulate

from .common import conv_instances, fmt_table, merge_instances, save

TPU_UTIL = {"CNN": 0.544, "MLP": 0.1196, "LSTM": 0.0353}   # paper Fig 17a
TPU_PEAK_TOPS_W = 23.0 / 40.0          # 16-bit TOPS / measured W [28]
TITAN_TOPS_W_16B = 0.06                # extrapolated 16-bit effective


def _tops_per_watt(r, cfg) -> float:
    ops = r.executed_cal_instrs * cfg.simd * 2        # MAC = 2 ops
    return ops / max(r.energy_pj, 1e-9)               # pJ/op == TOPS/W


def run() -> dict:
    cfg = MachineConfig()
    # ---- Fig 15: energy by scheme (steady state)
    rows = []
    energy = {}
    for scheme in Reuse:
        r = simulate(conv_instances(ALEXNET_CONV2, scheme, 1, repeats=8),
                     cfg)
        energy[scheme.value] = r.energy_pj
        rows.append({"scheme": scheme.value,
                     "energy_uJ": f"{r.energy_pj / 1e6:.1f}",
                     "norm_vs_all": f"{r.energy_pj: .3g}"})
    base = energy["all_reuse"]
    for r_ in rows:
        r_["norm_vs_all"] = f"{energy[r_['scheme']] / base:.2f}"
    print("\n== Fig 15: energy by CNN scheme (normalized to All-Reuse) ==")
    print(fmt_table(rows, ["scheme", "energy_uJ", "norm_vs_all"]))

    # ---- Fig 17a/b: utilization + efficiency per app class
    def repeated(g, n):
        for t in g.tasks:
            t.repeats = n
        return g

    apps = {
        "CNN": conv_instances(ALEXNET_CONV2, Reuse.ALL_REUSE, 8,
                              repeats=8),
        # MLP layer == MMM (dense 64x64 matmul blocks), steady stream
        "MLP": repeated(gp.build_program("MMM"), 8),
        # LSTM step == matrix-vector (MMV): low reuse, small batch
        "LSTM": repeated(gp.build_program("MMV"), 8),
    }
    arows = []
    ratios = {}
    for name, g in apps.items():
        r = simulate(g, cfg)
        eff = _tops_per_watt(r, cfg)
        tpu_eff = TPU_PEAK_TOPS_W * TPU_UTIL[name]
        ratios[name] = eff / tpu_eff
        arows.append({
            "app": name,
            "riscnn_util": f"{r.mac_utilization:.3f}",
            "tpu_util": TPU_UTIL[name],
            "riscnn_TOPS/W": f"{eff:.2f}",
            "tpu_TOPS/W": f"{tpu_eff:.3f}",
            "ratio": f"{ratios[name]:.1f}x",
            "vs_titan16": f"{eff / TITAN_TOPS_W_16B:.1f}x",
        })
    print("\n== Fig 17: RISC-NN vs TPU (paper: 1.29x CNN, 8.37x MLP, "
          "21.71x LSTM) ==")
    print(fmt_table(arows, ["app", "riscnn_util", "tpu_util",
                            "riscnn_TOPS/W", "tpu_TOPS/W", "ratio",
                            "vs_titan16"]))
    save("fig15_energy", {"fig15": rows, "fig17": arows})
    ordering_ok = energy["no_reuse"] == max(energy.values()) \
        and energy["all_reuse"] == min(energy.values())
    monotone = ratios["CNN"] < ratios["MLP"] < ratios["LSTM"]
    return {"fig15": rows, "fig17": arows, "fig15_ordering_ok": ordering_ok,
            "fig17_monotone_ok": monotone}


if __name__ == "__main__":
    run()
