"""Paper §5.2/§5.4 on TPU tiles — the Pallas-kernel side of the story.

* The dataflow-matmul's modeled HBM traffic across the four reuse
  policies on a transformer-shaped GEMM reproduces Table 6's ordering
  at MXU-tile granularity.
* The block-sparse kernel's static savings at the Table-3 compress
  rates mirror the Fig-19 accounting.
* Correctness of both (vs ref.py oracles) is enforced in
  tests/test_kernels.py; here we emit the numbers.
"""
from __future__ import annotations

import numpy as np

from repro.kernels import block_sparse as bs
from repro.kernels import gemm_dataflow as gd

from .common import fmt_table, save

#: llama4-scout expert GEMM: (tokens x d_model) @ (d_model x d_ff)
M, K, N = 8192, 5120, 8192


def run() -> dict:
    rows = []
    traffic = {}
    for df in gd.Dataflow:
        t = gd.modeled_traffic(M, N, K, df)
        traffic[df.value] = t["total_bytes"]
        rows.append({"dataflow": df.value,
                     "paper_scheme": {
                         "output_stationary": "All Reuse",
                         "weight_stationary": "Filter Reuse",
                         "input_stationary": "Ifmap Reuse",
                         "no_reuse": "No Reuse"}[df.value],
                     "hbm_GB": f"{t['total_bytes'] / 1e9:.2f}",
                     "vs_best": f"{t['total_bytes'] / min_traffic(M, N, K):.1f}x"})
    print("\n== Kernel dataflows: modeled HBM traffic, "
          f"GEMM {M}x{K}x{N} ==")
    print(fmt_table(rows, ["dataflow", "paper_scheme", "hbm_GB",
                           "vs_best"]))

    srows = []
    for keep in (0.36, 0.27, 0.35, 0.38):
        rng = np.random.default_rng(int(keep * 100))
        mask = rng.random((K // 128, N // 128)) < keep
        s = bs.sparse_savings(mask)
        srows.append({"keep_rate": keep,
                      "tiles_live": s["tiles_live"],
                      "flops_saved": f"{s['flops_saved_frac'] * 100:.1f}%"})
    print("\n== Block-sparse (Sparse PC Inc analogue) static savings ==")
    print(fmt_table(srows, ["keep_rate", "tiles_live", "flops_saved"]))
    save("kernel_dataflow", {"traffic": rows, "sparse": srows})
    ordering = (traffic["output_stationary"] < traffic["input_stationary"]
                <= traffic["no_reuse"]
                and traffic["output_stationary"]
                < traffic["weight_stationary"] <= traffic["no_reuse"])
    return {"traffic": rows, "sparse": srows, "ordering_ok": ordering}


def min_traffic(m, n, k):
    return min(gd.modeled_traffic(m, n, k, df)["total_bytes"]
               for df in gd.Dataflow)


if __name__ == "__main__":
    run()
