"""Figs 13/14 — off-chip memory traffic and NoC traffic per scheme
(single instance).  Paper: All-Reuse moves ~1/38, 1/13, 1/34, 1/6 of the
DRAM bytes of No/Conv/Filter/Ifmap reuse; Control-NoC traffic is <8% of
all NoC traffic; Ifmap-Reuse's cache hit rate exceeds 91.9%."""
from __future__ import annotations

from repro.core.dataflows import ALEXNET_CONV2, Reuse
from repro.core.machine import MachineConfig, simulate

from .common import conv_instances, fmt_table, save


def run(spec=ALEXNET_CONV2) -> dict:
    """Steady-state traffic (repeats=8, instructions amortized).

    Note on the cache (DESIGN.md §2): one AlexNet_CONV2 panel's working
    set fits the 1 MB memory-controller cache, so *off-chip* traffic
    converges across schemes here — the scheme-dependent quantity our
    model exposes faithfully is the **memory-request traffic** (LD/ST
    words = Memory-NoC bytes, paper Fig 14), whose ordering and ratios
    follow Table 6's LD counts.  The paper's Fig-13 off-chip ratios
    arise over full multi-channel layers where the working set exceeds
    the cache; the request-level ratios are the cache-independent
    ground truth and are what we check.
    """
    cfg = MachineConfig()
    rows = []
    noc = {}
    dram = {}
    for scheme in Reuse:
        r = simulate(conv_instances(spec, scheme, 1, repeats=8), cfg)
        dram[scheme] = r.dram_bytes
        noc[scheme] = r.mem_noc_bytes
        total_noc = r.mem_noc_bytes + r.interpe_noc_bytes + r.ctrl_noc_bytes
        rows.append({
            "scheme": scheme.value,
            "dram_B": int(r.dram_bytes),
            "mem_noc_B": int(r.mem_noc_bytes),
            "interpe_noc_B": int(r.interpe_noc_bytes),
            "ctrl_noc_B": int(r.ctrl_noc_bytes),
            "ctrl_share": f"{r.ctrl_noc_bytes / total_noc:.3f}",
            "cache_hit": f"{r.cache_hit_rate:.3f}",
        })
    ratios = {s.value: noc[s] / noc[Reuse.ALL_REUSE] for s in Reuse}
    print("\n== Fig 13/14: memory-request + NoC traffic (steady state) ==")
    print(fmt_table(rows, ["scheme", "dram_B", "mem_noc_B",
                           "interpe_noc_B", "ctrl_noc_B", "ctrl_share",
                           "cache_hit"]))
    print("mem-request ratio vs All-Reuse:",
          {k: round(v, 1) for k, v in ratios.items()},
          "(paper Fig13 off-chip: no=38x conv=13x filter=34x ifmap=6x)")
    save("fig13_traffic", {"rows": rows, "ratios_vs_all": ratios})
    ordering_ok = (noc[Reuse.ALL_REUSE] < noc[Reuse.IFMAP_REUSE]
                   == noc[Reuse.FILTER_REUSE] < noc[Reuse.NO_REUSE])
    ctrl_ok = all(float(r_["ctrl_share"]) < 0.08 for r_ in rows)
    return {"rows": rows, "ratios": ratios, "ordering_ok": ordering_ok,
            "ctrl_share_below_8pct": ctrl_ok}


if __name__ == "__main__":
    run()
