"""Model-input specifications for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) — the dry-run lowers against these.  The
synthetic pipeline (`data/pipeline.py`) materializes concrete batches
with identical structure for smoke tests / the example trainer.

Modality frontends are stubs per the brief: whisper gets precomputed
frame embeddings, qwen2-vl gets precomputed patch embeddings + M-RoPE
position ids.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["train_specs", "train_axes", "decode_token_specs"]

SDS = jax.ShapeDtypeStruct


def train_specs(cfg, batch: int, seq: int) -> Dict[str, SDS]:
    """Training / prefill batch: tokens + labels (+ frontend stubs)."""
    specs = {
        "tokens": SDS((batch, seq), jnp.int32),
        "labels": SDS((batch, seq), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        specs["frames"] = SDS((batch, cfg.n_frames, cfg.d_model),
                              jnp.bfloat16)
    if cfg.rope_kind == "mrope":
        specs["mrope_positions"] = SDS((batch, 3, seq), jnp.int32)
    if cfg.n_patches:
        specs["patch_embeds"] = SDS((batch, cfg.n_patches, cfg.d_model),
                                    jnp.bfloat16)
    return specs


def train_axes(cfg, batch: int, seq: int) -> Dict[str, Tuple]:
    """Logical axes for each entry of :func:`train_specs` (batch dim 0)."""
    axes = {
        "tokens": ("batch", None),
        "labels": ("batch", None),
    }
    if cfg.is_encoder_decoder:
        axes["frames"] = ("batch", None, None)
    if cfg.rope_kind == "mrope":
        axes["mrope_positions"] = ("batch", None, None)
    if cfg.n_patches:
        axes["patch_embeds"] = ("batch", None, None)
    return axes


def decode_token_specs(cfg, batch: int) -> Tuple[SDS, Tuple]:
    return SDS((batch, 1), jnp.int32), ("batch", None)
