"""Synthetic, stateless-resumable data pipeline.

``batch_for_step(step)`` is a pure function of (seed, step, spec): any
worker that knows the step number regenerates exactly its shard —
restart/elastic-rescale never replays or skips data, and stragglers can
be re-issued deterministically.  This is the property a real corpus
pipeline would get from deterministic index shuffling + sharded reads.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticPipeline"]


@dataclasses.dataclass(frozen=True)
class SyntheticPipeline:
    cfg: object                    # ModelConfig
    batch: int
    seq: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index]))

    def host_batch(self) -> int:
        assert self.batch % self.n_hosts == 0
        return self.batch // self.n_hosts

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        b, s = self.host_batch(), self.seq
        v = cfg.vocab_size
        # markov-ish stream so loss actually decreases in the examples
        base = rng.integers(0, v, size=(b, 1), dtype=np.int32)
        drift = rng.integers(0, 17, size=(b, s), dtype=np.int32)
        tokens = (base + np.cumsum(drift, axis=1)) % v
        batch = {
            "tokens": tokens.astype(np.int32),
            "labels": np.roll(tokens, -1, axis=1).astype(np.int32),
        }
        if cfg.is_encoder_decoder:
            batch["frames"] = rng.standard_normal(
                (b, cfg.n_frames, cfg.d_model)).astype(np.float32)
        if cfg.rope_kind == "mrope":
            pos = np.broadcast_to(np.arange(s, dtype=np.int32),
                                  (b, 3, s)).copy()
            batch["mrope_positions"] = pos
        if cfg.n_patches:
            batch["patch_embeds"] = rng.standard_normal(
                (b, cfg.n_patches, cfg.d_model)).astype(np.float32)
        return batch

    def device_batch(self, step: int, shardings=None):
        np_batch = self.batch_for_step(step)
        cast = {k: (v if v.dtype == np.int32 else v.astype(jnp.bfloat16))
                for k, v in np_batch.items()}
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in cast.items()}
        return {k: jax.device_put(jnp.asarray(v), shardings[k])
                for k, v in cast.items()}
