"""Oracle: dense matmul against the block-masked weights."""
import jax.numpy as jnp


def expand_mask(mask, bk, bn):
    """(K/bk, N/bn) bool -> (K, N) elementwise bool."""
    return jnp.repeat(jnp.repeat(mask, bk, axis=0), bn, axis=1)


def matmul_block_sparse_ref(a, b, mask, bk, bn):
    bm = expand_mask(mask, bk, bn)
    return jnp.dot(a.astype(jnp.float32),
                   jnp.where(bm, b, 0).astype(jnp.float32))
