from .kernel import matmul_block_sparse  # noqa: F401
from .ops import compile_mask, mask_from_weights, matmul, sparse_savings  # noqa: F401
from .ref import matmul_block_sparse_ref  # noqa: F401
