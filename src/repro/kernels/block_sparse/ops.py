"""Compile a block mask into the jump table + jit'd entry point.

``compile_mask`` is the moral equivalent of the paper's Instruction
Loader translating the sparse vector into per-instruction Sparse PC
Inc values (Fig 18): a static pass over the pruned weights that the
runtime then follows with zero per-MAC overhead.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import matmul_block_sparse
from .ref import matmul_block_sparse_ref  # noqa: F401

__all__ = ["compile_mask", "matmul", "mask_from_weights", "sparse_savings"]


def mask_from_weights(b: np.ndarray, bk: int, bn: int,
                      threshold: float = 0.0) -> np.ndarray:
    """Block mask: a tile is live iff it has any |w| > threshold."""
    k, n = b.shape
    assert k % bk == 0 and n % bn == 0
    blocks = np.abs(np.asarray(b)).reshape(k // bk, bk, n // bn, bn)
    return (blocks.max(axis=(1, 3)) > threshold)


def compile_mask(mask: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                            np.ndarray]:
    """Mask (nk, nn) bool -> (live_k, live_j, first) jump table, j-major
    so each output column's live tiles are a contiguous grid run."""
    mask = np.asarray(mask, bool)
    nk, nn = mask.shape
    live_k, live_j, first = [], [], []
    for j in range(nn):
        ks = np.nonzero(mask[:, j])[0]
        for t, kk in enumerate(ks):
            live_k.append(kk)
            live_j.append(j)
            first.append(1 if t == 0 else 0)
    if not live_k:                     # fully-pruned: one step, masked out
        live_k, live_j, first = [0], [0], [1]
    return (np.asarray(live_k, np.int32), np.asarray(live_j, np.int32),
            np.asarray(first, np.int32))


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def _run(a, b, live_k, live_j, first, bm, bn, bk, interpret):
    return matmul_block_sparse(a, b, live_k, live_j, first,
                               bm=bm, bn=bn, bk=bk, interpret=interpret)


def matmul(a, b, mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
           interpret: bool = False):
    """Block-sparse matmul; zeroes fully-pruned output columns."""
    live_k, live_j, first = compile_mask(mask)
    out = _run(a, b, jnp.asarray(live_k), jnp.asarray(live_j),
               jnp.asarray(first), bm, bn, bk, interpret)
    # columns with no live tile keep stale pipeline contents: mask them
    col_live = jnp.asarray(np.asarray(mask).any(axis=0))
    col_mask = jnp.repeat(col_live, bn)
    return jnp.where(col_mask[None, :], out, 0.0)


def sparse_savings(mask: np.ndarray) -> dict:
    """Static savings — the paper's Fig-19 accounting at tile level."""
    mask = np.asarray(mask, bool)
    total = mask.size
    live = int(mask.sum())
    return {
        "tiles_total": total,
        "tiles_live": live,
        "flops_saved_frac": 1.0 - live / total,
        "weight_bytes_saved_frac": 1.0 - live / total,
    }
