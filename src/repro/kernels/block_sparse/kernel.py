"""Block-sparse matmul — the paper's Sparse-PC-Inc on TPU (§5.4).

RISC-NN skips pruned weights by rewriting each instruction's
``Sparse PC Inc`` to jump over dead MACs.  The TPU-native analogue
operates at MXU-tile granularity: the compiler (ops.py) compacts the
block mask into a **jump table** of live (k, n) tile coordinates, and
the kernel's grid walks only that list — dead tiles cost neither FLOPs
nor HBM traffic, exactly like skipped CAL instructions.

Mechanics: the coordinate arrays ride in scalar-prefetch SMEM
(``PrefetchScalarGridSpec``) so the pipeline can compute the *next*
block's HBM addresses ahead of the MACs — RISC-NN's decoupled
Instruction-Loader / CAL-unit split, literally.

Within one output column j the live k-tiles are consecutive grid
steps, so the output block stays VMEM-resident and psums never round-
trip HBM (the ``first`` flag re-zeroes it when j advances).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(live_k, live_j, first, a_ref, b_ref, o_ref, acc_ref):
    s = pl.program_id(1)

    @pl.when(first[s] == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)
    # write-through every step: the last step of a j-run leaves the
    # final psum in o (previous partial writes are dead stores that the
    # pipeline keeps in VMEM while j is unchanged).
    o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_block_sparse(a: jax.Array, b: jax.Array,
                        live_k: jax.Array, live_j: jax.Array,
                        first: jax.Array,
                        *, bm: int = 128, bn: int = 128, bk: int = 128,
                        interpret: bool = False) -> jax.Array:
    """C = A @ (B under block mask).

    live_k/live_j: (n_live,) int32 tile coordinates, ordered so equal-j
    runs are contiguous; first: (n_live,) int32, 1 at each j-run start.
    Output blocks whose column has no live tile are zero.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    nm = m // bm
    n_live = live_k.shape[0]
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nm, n_live),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, s, lk, lj, f: (i, lk[s])),
            pl.BlockSpec((bk, bn), lambda i, s, lk, lj, f: (lk[s], lj[s])),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, s, lk, lj, f: (i, lj[s])),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        name="block_sparse_matmul",
    )(live_k, live_j, first, a, b)
