"""Dataflow-parameterized tiled matmul (paper §5.2 on the MXU).

The RISC-NN claim is that *programmable data movement* — not new
arithmetic — is what buys efficiency: the same MACs under five reuse
schedules differ by 38x in DRAM traffic (Table 6).  On TPU the analogue
of "which operand stays in the PE's Operand RAM" is "which operand's
VMEM block survives consecutive grid steps": Pallas's pipeline skips
the HBM->VMEM copy whenever the BlockSpec index_map returns the same
block index as the previous step.  So the **grid iteration order + the
index maps are the dataflow program**:

    OUTPUT_STATIONARY  (paper: All Reuse)    grid (m, n, k), k inner —
        the f32 accumulator lives in VMEM scratch; C written once.
    WEIGHT_STATIONARY  (paper: Filter Reuse) grid (n, k, m), m inner —
        the B (weight) block survives the whole m sweep; C revisited.
    INPUT_STATIONARY   (paper: Ifmap Reuse)  grid (m, k, n), n inner —
        the A (ifmap) block survives the n sweep; C revisited.
    NO_REUSE           (paper: No Reuse)     grid (k, m, n) — no block
        survives consecutive steps; every operand re-streamed.

All four compute identical values (tests assert allclose against
``ref.matmul_ref``); they differ only in modeled HBM traffic
(``ops.modeled_traffic``), which reproduces the paper's Table-6
*ordering* on MXU tiles.
"""
from __future__ import annotations

import enum
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


class Dataflow(enum.Enum):
    OUTPUT_STATIONARY = "output_stationary"   # paper: All Reuse
    WEIGHT_STATIONARY = "weight_stationary"   # paper: Filter Reuse
    INPUT_STATIONARY = "input_stationary"     # paper: Ifmap Reuse
    NO_REUSE = "no_reuse"                     # paper: No Reuse


def _os_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Output-stationary: accumulate in VMEM scratch, write C once."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _revisit_kernel(a_ref, b_ref, o_ref, *, k_axis: int):
    """Weight-/input-stationary/no-reuse: C revisited across k (psum
    read-modify-write through the pipeline, like the paper's psum LD/ST
    chains)."""
    k = pl.program_id(k_axis)
    part = jnp.dot(a_ref[...], b_ref[...],
                   preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _first():
        o_ref[...] = part.astype(o_ref.dtype)

    @pl.when(k != 0)
    def _rest():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + part
                      ).astype(o_ref.dtype)


def matmul_dataflow(a: jax.Array, b: jax.Array,
                    dataflow: Dataflow = Dataflow.OUTPUT_STATIONARY,
                    *, bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = False,
                    out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """C = A @ B under the selected dataflow.  Shapes must tile evenly
    (the wrapper in ops.py pads)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (a.shape, b.shape, bm, bn, bk)
    nm, nn, nk = m // bm, n // bn, k // bk
    out_dtype = out_dtype or jnp.promote_types(a.dtype, jnp.float32)
    out_shape = jax.ShapeDtypeStruct((m, n), out_dtype)

    if dataflow is Dataflow.OUTPUT_STATIONARY:
        return pl.pallas_call(
            functools.partial(_os_kernel, nk=nk),
            grid=(nm, nn, nk),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            out_shape=out_shape,
            interpret=interpret,
            name="gemm_output_stationary",
        )(a, b)

    if dataflow is Dataflow.WEIGHT_STATIONARY:
        # grid (n, k, m): B block index (kk, j) constant across inner m
        return pl.pallas_call(
            functools.partial(_revisit_kernel, k_axis=1),
            grid=(nn, nk, nm),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda j, kk, i: (i, kk)),
                pl.BlockSpec((bk, bn), lambda j, kk, i: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda j, kk, i: (i, j)),
            out_shape=out_shape,
            interpret=interpret,
            name="gemm_weight_stationary",
        )(a, b)

    if dataflow is Dataflow.INPUT_STATIONARY:
        # grid (m, k, n): A block index (i, kk) constant across inner n
        return pl.pallas_call(
            functools.partial(_revisit_kernel, k_axis=1),
            grid=(nm, nk, nn),
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, kk, j: (i, kk)),
                pl.BlockSpec((bk, bn), lambda i, kk, j: (kk, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, kk, j: (i, j)),
            out_shape=out_shape,
            interpret=interpret,
            name="gemm_input_stationary",
        )(a, b)

    # NO_REUSE: k outermost — every step changes every block index
    return pl.pallas_call(
        functools.partial(_revisit_kernel, k_axis=0),
        grid=(nk, nm, nn),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda kk, i, j: (i, kk)),
            pl.BlockSpec((bk, bn), lambda kk, i, j: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda kk, i, j: (i, j)),
        out_shape=out_shape,
        interpret=interpret,
        name="gemm_no_reuse",
    )(a, b)
