from .kernel import Dataflow, matmul_dataflow  # noqa: F401
from .ops import matmul, modeled_traffic  # noqa: F401
from .ref import matmul_ref  # noqa: F401
