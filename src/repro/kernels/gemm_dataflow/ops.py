"""jit'd wrapper + HBM-traffic model for the dataflow matmul.

``modeled_traffic`` mirrors the Pallas pipeline's copy-elision rule —
a block is re-fetched iff its index changed between consecutive grid
steps — which is how the paper's Table-6 LD/COPY/ST ordering shows up
on TPU tiles (validated in tests against the paper's scheme ordering).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from .kernel import Dataflow, matmul_dataflow

__all__ = ["matmul", "modeled_traffic", "Dataflow"]


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("dataflow", "bm", "bn", "bk",
                                             "interpret"))
def matmul(a, b, dataflow: Dataflow = Dataflow.OUTPUT_STATIONARY,
           *, bm: int = 128, bn: int = 128, bk: int = 128,
           interpret: bool = False):
    m, n = a.shape[0], b.shape[1]
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = matmul_dataflow(ap, bp, dataflow, bm=bm, bn=bn, bk=bk,
                          interpret=interpret)
    return out[:m, :n]


def modeled_traffic(m: int, n: int, k: int, dataflow: Dataflow,
                    *, bm: int = 128, bn: int = 128, bk: int = 128,
                    bytes_per_elem: int = 2) -> Dict[str, float]:
    """HBM bytes under the pipeline's copy-elision rule."""
    nm, nn, nk = -(-m // bm), -(-n // bn), -(-k // bk)
    a_blk = bm * bk * bytes_per_elem
    b_blk = bk * bn * bytes_per_elem
    o_blk = bm * bn * 4                      # f32 psums/out
    if dataflow is Dataflow.OUTPUT_STATIONARY:
        a_loads = nm * nn * nk               # A changes with (i, kk)
        b_loads = nm * nn * nk
        o_writes = nm * nn                   # written once
        o_reads = 0
    elif dataflow is Dataflow.WEIGHT_STATIONARY:
        a_loads = nn * nk * nm
        b_loads = nn * nk                    # B constant over inner m
        o_writes = nn * nk * nm
        o_reads = nn * (nk - 1) * nm
    elif dataflow is Dataflow.INPUT_STATIONARY:
        a_loads = nm * nk                    # A constant over inner n
        b_loads = nm * nk * nn
        o_writes = nm * nk * nn
        o_reads = nm * (nk - 1) * nn
    else:                                    # NO_REUSE
        a_loads = nk * nm * nn
        b_loads = nk * nm * nn
        o_writes = nk * nm * nn
        o_reads = (nk - 1) * nm * nn
    return {
        "a_bytes": a_loads * a_blk,
        "b_bytes": b_loads * b_blk,
        "out_bytes": o_writes * o_blk + o_reads * o_blk,
        "total_bytes": (a_loads * a_blk + b_loads * b_blk
                        + (o_writes + o_reads) * o_blk),
    }
