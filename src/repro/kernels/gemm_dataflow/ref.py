"""Pure-jnp oracle for the dataflow matmul."""
import jax.numpy as jnp


def matmul_ref(a, b, out_dtype=None):
    out_dtype = out_dtype or jnp.promote_types(a.dtype, jnp.float32)
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(out_dtype)
