"""jit'd wrapper with named activation tables (paper Table 4's
"unnecessary" CISC ops — complex Activate, VEXP, VLOG, VDV — become
lookup types, matching core/lut.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import lut_activation, LUT_ENTRIES
from .ref import build_table, lut_ref  # noqa: F401

__all__ = ["apply_lut", "table_for", "TABLES"]

TABLES = {
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "exp": jnp.exp,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "sqrt": lambda x: jnp.sqrt(jnp.maximum(x, 0.0)),
    "recip": lambda x: jnp.where(jnp.abs(x) < 1e-4, 0.0, 1.0 / x),
}


@functools.lru_cache(maxsize=None)
def table_for(name: str):
    return build_table(TABLES[name])


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def apply_lut(x, name: str, *, bm: int = 256, bn: int = 256,
              interpret: bool = False):
    """Elementwise activation through the 2^16-entry table."""
    table = table_for(name)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    m, n = x2.shape
    x2 = _pad_to(x2, bm, bn)
    out = lut_activation(x2, table, bm=bm, bn=bn, interpret=interpret)
    return out[:m, :n].reshape(shape)
