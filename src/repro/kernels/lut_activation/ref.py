"""Oracle: same quantize-then-gather in plain jnp (and the exact
function for accuracy bounds)."""
import jax.numpy as jnp

from .kernel import LUT_ENTRIES, LUT_HI, LUT_LO

_STEP = (LUT_HI - LUT_LO) / LUT_ENTRIES


def lut_ref(x, table):
    q = jnp.clip(jnp.round((x.astype(jnp.float32) - LUT_LO) / _STEP),
                 0, LUT_ENTRIES - 1).astype(jnp.int32)
    return jnp.take(table, q, axis=0)


def build_table(fn):
    """Tabulate fn over the 2^16-entry grid (paper §3.9)."""
    grid = LUT_LO + ( jnp.arange(LUT_ENTRIES, dtype=jnp.float32) + 0.0) \
        * _STEP
    return fn(grid).astype(jnp.float32)
