"""LUT activation — the paper's In-DRAM Table Loader (§3.9) on TPU.

RISC-NN keeps its ISA free of transcendentals: an ST instruction with a
non-zero ``In-DRAM Lookup Type`` routes the stored value through a
2^16-entry table at the memory controller.  TPUs run no logic in the
memory controller, so the adaptation moves the lookup to the **store
path of the kernel epilogue**: values are quantized to the paper's
16-bit grid and gathered from the table while still VMEM-resident —
the same accuracy contract (exact for 16-bit inputs), one level higher
in the memory hierarchy (deviation recorded in DESIGN.md).

The table block (65536 x 4B = 256 KB) is fetched once and survives all
grid steps (constant index_map) — table reuse is free, as in DRAM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: quantization grid of core/lut.py (paper: 16-bit fixed point in [-8, 8))
LUT_LO, LUT_HI, LUT_ENTRIES = -8.0, 8.0, 1 << 16
_STEP = (LUT_HI - LUT_LO) / LUT_ENTRIES


def quantize_u16(x):
    q = jnp.clip(jnp.round((x - LUT_LO) / _STEP), 0, LUT_ENTRIES - 1)
    return q.astype(jnp.int32)


def _kernel(x_ref, table_ref, o_ref):
    idx = quantize_u16(x_ref[...].astype(jnp.float32))
    o_ref[...] = jnp.take(table_ref[...], idx, axis=0)


def lut_activation(x: jax.Array, table: jax.Array, *, bm: int = 256,
                   bn: int = 256, interpret: bool = False) -> jax.Array:
    """y = table[quantize(x)] elementwise; x: (M, N), table: (65536,)."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, bm, bn)
    assert table.shape == (LUT_ENTRIES,), table.shape
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((LUT_ENTRIES,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
        name="lut_activation",
    )(x, table)
