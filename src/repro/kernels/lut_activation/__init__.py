from .kernel import lut_activation, quantize_u16, LUT_ENTRIES  # noqa: F401
from .ops import apply_lut, table_for, TABLES  # noqa: F401
from .ref import build_table, lut_ref  # noqa: F401
