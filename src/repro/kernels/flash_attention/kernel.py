"""Flash attention with decoupled LD/CAL staging (used by the LM archs).

The online-softmax decomposition is the paper's ExeBlock discipline
applied to attention: each (q-block, kv-block) pair is one ExeBlock —
LD stages K/V tiles into VMEM, CAL runs the two MACs (scores, pv) plus
the rescale chain, FLOW carries (m, l, acc) to the next block via VMEM
scratch, and ST writes the normalized tile once at the end of the kv
sweep (output-stationary, like All-Reuse).

GQA is kept factored: the kv-head index map is ``q_head // group``, so
K/V tiles are fetched once per kv head and *reused* across the group's
q heads through pipeline copy-elision.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nkv: int, bq: int, bkv: int, causal: bool, scale: float,
            q_offset: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bkv, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    if causal:
        q_i = pl.program_id(1)
        # q_offset shifts every query to absolute position q + q_offset
        # (ragged decode: sq < skv queries aligned to the END of kv,
        # matching ref.py's tril(k=skv-sq) semantics at offset skv-sq)
        q_pos = (q_offset + q_i * bq
                 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0))
        k_pos = kv_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == nkv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 256, bkv: int = 256,
                    q_offset: int = 0,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k/v: (BKV, Skv, D) with BH = BKV * group.

    Heads are flattened into the leading dim; the kv index map divides
    by the GQA group.  ``q_offset`` places query i at absolute position
    ``i + q_offset`` for the causal mask (ragged decode: ``sq < skv``
    with queries aligned to the end of kv uses ``skv - sq``).  Returns
    (BH, Sq, D).
    """
    bh, sq, d = q.shape
    bkvh, skv, _ = k.shape
    assert bh % bkvh == 0
    group = bh // bkvh
    assert sq % bq == 0 and skv % bkv == 0
    nq, nkv = sq // bq, skv // bkv
    scale = 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_kernel, nkv=nkv, bq=bq, bkv=bkv,
                          causal=causal, scale=scale,
                          q_offset=int(q_offset)),
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # accumulator
        ],
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
