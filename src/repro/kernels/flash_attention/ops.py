"""jit'd wrapper: (B, S, H, D) layout -> kernel layout and back."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref  # noqa: F401

__all__ = ["attention"]


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "q_offset", "interpret"))
def attention(q, k, v, *, causal: bool = True, bq: int = 256,
              bkv: int = 256, q_offset: int = 0,
              interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Skv, KVH, D) -> (B, Sq, H, D).

    ``q_offset`` shifts the causal mask: query i sits at absolute
    position ``i + q_offset`` (ragged ``sq < skv`` attention with
    queries aligned to the end of kv — the ``attention_ref`` offset
    semantics — uses ``skv - sq``)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], d)
    # kernel maps q head -> kv head by h // group within one batch item:
    # flatten batch-major so the division stays aligned
    out = flash_attention(qf, kf, vf, causal=causal,
                          bq=min(bq, sq), bkv=min(bkv, k.shape[1]),
                          q_offset=q_offset, interpret=interpret)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
