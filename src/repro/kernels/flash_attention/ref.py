"""Oracle: exact softmax attention in f32."""
import math

import jax.numpy as jnp


def attention_ref(q, k, v, causal=True):
    bh, sq, d = q.shape
    bkvh = k.shape[0]
    group = bh // bkvh
    kf = jnp.repeat(k, group, axis=0).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=0).astype(jnp.float32)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(d)
    if causal:
        skv = k.shape[1]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, vf).astype(q.dtype)
