"""jit'd wrapper: (B, H, Dh) decode layout -> kernel layout and back."""
from __future__ import annotations

import functools

import jax

from .kernel import paged_attention_kernel
from .ref import paged_attention_ref  # noqa: F401

__all__ = ["paged_attention"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, page_tables, lengths, *,
                    interpret: bool = False):
    """Single-token decode attention over paged KV.

    q: (B, H, Dh); k/v_pages: (P, ps, KVH, Dh); page_tables: (B, n)
    int32 page ids; lengths: (B,) attendable tokens.  Returns
    (B, H, Dh).  GQA kept factored: q heads are grouped by kv head so
    each page is staged once per kv head and reused across the group.
    """
    B, H, Dh = q.shape
    KVH = k_pages.shape[2]
    G = H // KVH
    qf = q.reshape(B, KVH, G, Dh)
    out = paged_attention_kernel(qf, k_pages, v_pages, page_tables,
                                 lengths, interpret=interpret)
    return out.reshape(B, H, Dh)
