from .kernel import paged_attention_kernel  # noqa: F401
from .ops import paged_attention  # noqa: F401
from .ref import (  # noqa: F401
    gather_pages, paged_attention_ref, paged_verify_attention_ref,
)
