"""Paged decode attention: gather non-contiguous KV pages via
scalar-prefetched page tables.

The page table IS the paper's programmable LD stage: instead of a
fixed-function contiguous DMA, each (batch, kv-head, page-slot) grid
step computes its own source address from the prefetched table
(``tbl[b, i]``) and stages exactly one resident page into VMEM.  CAL is
the usual online-softmax pair of MACs; FLOW carries (m, l, acc) across
the page sweep in VMEM scratch; ST writes the normalized output once —
output-stationary, like All-Reuse.

Pages beyond a sequence's length are skipped entirely (``pl.when``),
the paged analogue of Sparse PC Inc: work that is not addressed is
never issued.

Scalar-prefetch layout invariants (the contract with
serve/kv_cache.py — also see docs/ARCHITECTURE.md):

* ``page_tables`` and ``lengths`` ride in SMEM via
  ``PrefetchScalarGridSpec(num_scalar_prefetch=2)``: they are read at
  *grid-index-map time* to compute each step's page address, so they
  must be int32 and host-final before the call — the kernel never
  validates them.
* Every table entry must name a real page or the null page 0; the
  index map DMAs whatever page it is told.  Slots past a sequence's
  last page may contain anything (the ``i * ps < length`` guard skips
  them), but must still be in-range.
* ``lengths[b]`` counts *attendable* tokens including the one just
  written.  Tokens past ``length`` inside the final page are masked to
  -1e30 before the running max, so stale lanes contribute exact zeros
  — the same invariant the jnp reference (ref.py) and the engine's
  token-parity guarantee rely on.
* (m, l, acc) scratch lives in VMEM across the page sweep
  (output-stationary, All-Reuse in the paper's terms); the output is
  written once on the last grid step.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, ps: int, n_slots: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(i * ps < length)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, Dh)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (ps, Dh)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        k_pos = i * ps + jax.lax.broadcasted_iota(
            jnp.int32, (1, ps), 1)[0]
        s = jnp.where((k_pos < length)[None, :], s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == n_slots - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_attention_kernel(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_tables: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """q: (B, KVH, G, Dh); k/v_pages: (P, ps, KVH, Dh);
    page_tables: (B, n_slots) int32; lengths: (B,) int32.
    Returns (B, KVH, G, Dh)."""
    B, KVH, G, Dh = q.shape
    _, ps, _, _ = k_pages.shape
    n_slots = page_tables.shape[1]
    scale = 1.0 / math.sqrt(Dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, n_slots),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh),
                         lambda b, h, i, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, Dh),
                         lambda b, h, i, tbl, ln: (tbl[b, i], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, Dh),
                         lambda b, h, i, tbl, ln: (tbl[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, h, i, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),        # running max
            pltpu.VMEM((G,), jnp.float32),        # running denom
            pltpu.VMEM((G, Dh), jnp.float32),     # accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, ps=ps, n_slots=n_slots, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, Dh), q.dtype),
        interpret=interpret,
        name="paged_attention",
    )(page_tables, lengths, q, k_pages, v_pages)
