"""Oracle: decode attention over paged KV via explicit gather.

Op-for-op the same math as ``components.decode_attention`` (bf16
operands, f32 MXU accumulation, -1e30 masking) so the continuous-
batching decode path stays token-exact against the contiguous-cache
greedy oracle: gathered padding positions contribute exact zeros.
"""
import math

import jax
import jax.numpy as jnp


def gather_pages(pages, page_tables):
    """(P, ps, KVH, Dh) + (B, n) -> (B, n * ps, KVH, Dh)."""
    B, n = page_tables.shape
    g = pages[page_tables]                       # (B, n, ps, KVH, Dh)
    return g.reshape(B, n * pages.shape[1], *pages.shape[2:])


def paged_attention_ref(q, k_pages, v_pages, page_tables, lengths):
    """q: (B, H, Dh); k/v_pages: (P, ps, KVH, Dh); page_tables:
    (B, n) int32; lengths: (B,) attendable tokens per sequence
    (including the one just written).  Returns (B, H, Dh)."""
    B, H, Dh = q.shape
    KVH = k_pages.shape[2]
    G = H // KVH
    k = gather_pages(k_pages, page_tables)       # (B, S, KVH, Dh)
    v = gather_pages(v_pages, page_tables)
    qh = q.reshape(B, KVH, G, Dh)
    s = jnp.einsum("bkgd,bjkd->bkgj", qh, k,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(Dh))
    idx = jnp.arange(k.shape[1])
    valid = idx[None, :] < lengths[:, None]      # (B, S)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Dh).astype(q.dtype)


def paged_verify_attention_ref(q, k_pages, v_pages, page_tables, lengths):
    """Multi-token verification attention over paged KV (speculative
    decode).  Op-for-op ``paged_attention_ref`` with a query-time axis:
    query t of sequence b sits at absolute position ``lengths[b] + t``
    and attends to gathered positions j < lengths[b] + t + 1 — i.e.
    everything already resident plus the speculated tokens written at or
    before its own position.  Per (b, t) the score vector, the softmax
    reductions, and the value contraction run over the same gathered
    buffer length as the single-token path, so the t-th verify query is
    bit-identical to the decode step the target model would have run at
    that position (the engine's token-exactness rests on this — see
    docs/speculative.md).

    q: (B, T, H, Dh); k/v_pages: (P, ps, KVH, Dh); page_tables: (B, n)
    int32; lengths: (B,) tokens resident *before* this verify call's T
    writes.  Returns (B, T, H, Dh)."""
    B, T, H, Dh = q.shape
    KVH = k_pages.shape[2]
    G = H // KVH
    k = gather_pages(k_pages, page_tables)       # (B, S, KVH, Dh)
    v = gather_pages(v_pages, page_tables)
    qh = q.reshape(B, T, KVH, G, Dh)
    s = jnp.einsum("btkgd,bjkd->btkgj", qh, k,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / math.sqrt(Dh))
    idx = jnp.arange(k.shape[1])
    q_pos = lengths[:, None] + jnp.arange(T)[None, :]        # (B, T)
    valid = idx[None, None, :] < (q_pos + 1)[:, :, None]     # (B, T, S)
    s = jnp.where(valid[:, :, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkgj,bjkd->btkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, T, H, Dh).astype(q.dtype)
