"""In-house AdamW with global-norm clipping and optional int8
gradient compression (error feedback) for cross-pod sync.

Optimizer state shardings follow the parameters': each moment inherits
its parameter's logical axes, so FSDP-sharded params get FSDP-sharded
moments for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "opt_state_specs", "adamw_update",
           "clip_by_global_norm", "compress_int8", "decompress_int8"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # int8 stochastic-rounding gradient compression (cross-pod sync);
    # error-feedback residual is carried in the opt state.
    compress_grads: bool = False
    # bf16 param storage: keep the f32 master copy in the opt state so
    # FSDP gathers and grad reductions move half the bytes.
    keep_master: bool = False


def init_opt_state(params, cfg: OptConfig = OptConfig()):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    state = {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress_grads:
        state["ef"] = jax.tree.map(zeros, params)
    if cfg.keep_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def opt_state_specs(param_specs, cfg: OptConfig = OptConfig()):
    """ParamSpec tree for the optimizer state (moments mirror params)."""
    from ..models.base import ParamSpec

    def f32(ps):
        return ParamSpec(ps.shape, ps.axes, jnp.float32)
    tree = {
        "mu": jax.tree.map(f32, param_specs,
                           is_leaf=lambda x: isinstance(x, ParamSpec)),
        "nu": jax.tree.map(f32, param_specs,
                           is_leaf=lambda x: isinstance(x, ParamSpec)),
        "step": ParamSpec((), (), jnp.int32),
    }
    if cfg.compress_grads:
        tree["ef"] = tree["mu"]
    if cfg.keep_master:
        tree["master"] = tree["mu"]
    return tree


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def _lr_at(cfg: OptConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def compress_int8(g, key):
    """Stochastic-rounding int8 quantization; returns (q, scale)."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    noise = jax.random.uniform(key, g.shape) - 0.5
    q = jnp.clip(jnp.round(g / scale + noise), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def adamw_update(params, grads, state, cfg: OptConfig = OptConfig(),
                 compress_key: Optional[jax.Array] = None):
    """One AdamW step.  Returns (params, state, metrics)."""
    step = state["step"]
    metrics: dict[str, Any] = {}
    if cfg.compress_grads:
        # error-feedback int8: quantize (grad + residual); residual keeps
        # what quantization lost, preserving convergence (beyond-paper
        # distributed-optimization trick for cross-pod all-reduce bytes).
        keys_tree = _key_tree(grads, compress_key)
        ef = state["ef"]
        def comp(g, e, k):
            q, s = compress_int8(g.astype(jnp.float32) + e, k)
            deq = decompress_int8(q, s)
            return deq, (g.astype(jnp.float32) + e) - deq
        pairs = jax.tree.map(comp, grads, ef, keys_tree)
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda p: p[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    metrics["grad_norm"] = gnorm
    lr = _lr_at(cfg, step)
    metrics["lr"] = lr
    b1, b2 = cfg.b1, cfg.b2
    base = state.get("master", params)   # f32 master when params are bf16

    def upd(p, b, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu / (1 - b1 ** (step + 1))
        nu_hat = nu / (1 - b2 ** (step + 1))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:     # no decay on norms/bias
            delta = delta + cfg.weight_decay * b.astype(jnp.float32)
        nb = b.astype(jnp.float32) - lr * delta
        return nb.astype(p.dtype), nb, mu, nu

    quads = jax.tree.map(upd, params, base, grads, state["mu"],
                         state["nu"])
    pick = lambda i: jax.tree.map(  # noqa: E731
        lambda t: t[i], quads, is_leaf=lambda x: isinstance(x, tuple))
    new_params = pick(0)
    new_state = {"mu": pick(2), "nu": pick(3), "step": step + 1}
    if cfg.keep_master:
        new_state["master"] = pick(1)
    if cfg.compress_grads:
        new_state["ef"] = new_ef
    return new_params, new_state, metrics


def _key_tree(tree, key):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))
