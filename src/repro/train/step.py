"""train_step / loss machinery.

Gradient accumulation is a `lax.scan` over microbatches — the live
activation set is one microbatch, which is what fits the 110B config in
the 16 GB/device budget (the mesh-level analogue of the paper's staging
of operands through a small Operand RAM instead of a big RF).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import constrain
from .optimizer import OptConfig, adamw_update

__all__ = ["cross_entropy", "make_loss_fn", "make_train_step",
           "auto_microbatches"]


def cross_entropy(logits, labels):
    """Mean token NLL.  logits f32 (B,S,V); labels int32 (B,S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def chunked_cross_entropy(hidden, embed_params, labels, cfg, chunk: int):
    """Seq-chunked fused CE: per chunk, project -> logsumexp -> discard.

    The (B,S,V) logits tensor (0.6 PB of HBM traffic for the 110B
    train_4k cell) never exists; peak extra memory is (B, chunk, V) and
    `jax.checkpoint` recomputes it in the backward pass.  This is the
    paper's ST-stage discipline: results leave the fast memory already
    reduced, not as bulk intermediate traffic."""
    w = (embed_params["tok"].T if cfg.tie_embeddings
         else embed_params["head"])
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n = S // chunk
    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    def chunk_nll(hc, lc):
        logits = hc.astype(jnp.float32) @ w.astype(jnp.float32)
        if cfg.logit_softcap:
            c = cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return (logz - gold).sum()

    def body(tot, inp):
        hc, lc = inp
        return tot + jax.checkpoint(chunk_nll)(hc, lc), None

    tot, _ = lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return tot / (B * S)


def cast_params_for_compute(params, dtype=jnp.bfloat16):
    """Pre-cast >=2D f32 params to the compute dtype *before* the model
    consumes them.  With FSDP this moves the convert ahead of the
    per-layer all-gather, halving parameter-gather collective bytes
    (the dominant collective of the 110B train cell — §Perf log).
    Master weights stay f32 in the optimizer."""
    def cast(p):
        if p.dtype == jnp.float32 and p.ndim >= 2:
            return p.astype(dtype)
        return p
    return jax.tree.map(cast, params)


def make_loss_fn(model, cfg) -> Callable:
    aux_coef = cfg.moe.aux_coef if cfg.moe else 0.0
    chunked = cfg.loss_chunk > 0 and not cfg.is_encoder_decoder

    def loss_fn(params, batch):
        params = cast_params_for_compute(
            params, jnp.dtype(cfg.compute_dtype))
        if chunked:
            hidden, aux = model.apply(params, batch, train=True,
                                      want_hidden=True)
            nll = chunked_cross_entropy(hidden, params["embed"],
                                        batch["labels"], cfg,
                                        cfg.loss_chunk)
        else:
            logits, aux = model.apply(params, batch, train=True)
            nll = cross_entropy(logits, batch["labels"])
        loss = nll + aux_coef * aux["moe_aux"]
        return loss, {"nll": nll, "moe_aux": aux["moe_aux"]}
    return loss_fn


def auto_microbatches(cfg, batch: int, seq: int, dp: int,
                      budget_bytes: float = 2.5e9) -> int:
    """Choose grad-accum steps so one microbatch's residual-stream
    activations per device stay under ``budget_bytes``:

        bytes/device ~= (B_u/dp) * S * d_model * 2 (bf16) * n_layers
                        (remat saves only layer boundaries)

    Microbatch size must stay divisible by dp.
    """
    if cfg.train_microbatch:
        return cfg.train_microbatch
    n_micro = 1
    while True:
        b_u = batch // n_micro
        if b_u <= dp or b_u % dp:
            break
        per_dev = (b_u / dp) * seq * cfg.d_model * 2 * max(cfg.n_layers, 1)
        if per_dev <= budget_bytes:
            break
        n_micro *= 2
    while batch % n_micro or (batch // n_micro) % dp:
        n_micro //= 2
    return max(n_micro, 1)


def make_train_step(model, cfg, *, opt: OptConfig = OptConfig(),
                    n_micro: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  All batch leaves have the batch dim at axis 0."""
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                y = x.reshape((n_micro, b // n_micro) + x.shape[1:])
                return y
            micro = jax.tree.map(reshape, batch)

            def step(carry, mb):
                gsum, lsum = carry
                mb = jax.tree.map(
                    lambda x: constrain(x, ("batch",) + (None,) * (x.ndim - 1)),
                    mb)
                (loss, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + loss), None

            acc_dt = jnp.dtype(cfg.grad_accum_dtype)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, lsum), _ = lax.scan(step, (g0, jnp.zeros((), jnp.float32)),
                                       micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
            aux = {"nll": loss, "moe_aux": jnp.zeros((), jnp.float32)}
        ckey = jax.random.fold_in(jax.random.PRNGKey(17), opt_state["step"]) \
            if opt.compress_grads else None
        params, opt_state, om = adamw_update(params, grads, opt_state, opt,
                                             compress_key=ckey)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return train_step
