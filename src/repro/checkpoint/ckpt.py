"""Checkpointing with manifests and elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json     tree structure, shapes, dtypes, shard map
        <leaf>.npy        one file per pytree leaf (full array) or
        <leaf>.shard<k>.npy  per-shard files ("sharded" mode)
    <dir>/LATEST          committed step marker (written last -> atomic)

Restore is **elastic**: arrays are re-`device_put` against whatever mesh
/ sharding tree the restoring job provides, so a checkpoint written on
one topology restores onto another (tested 8 -> 4 devices).  The LATEST
marker is written only after every leaf is durable, so a crash
mid-checkpoint never corrupts the restore point (double-buffered
manifests).
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_SEP = "__"


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory, step: int, tree, *, keep: int = 3) -> Path:
    """Write a checkpoint; returns its path.  Atomic via LATEST marker."""
    root = Path(directory)
    ckpt = root / f"step_{step:09d}"
    tmp = root / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        # bf16 has no numpy dtype: store as uint16 view + dtype tag
        dtype = str(leaf.dtype)
        if dtype == "bfloat16":
            arr = arr.view(np.uint16)
        np.save(tmp / f"{key}.npy", arr)
        manifest["leaves"][key] = {"dtype": dtype,
                                   "shape": list(arr.shape)}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if ckpt.exists():
        shutil.rmtree(ckpt)
    tmp.rename(ckpt)
    (root / "LATEST").write_text(str(step))
    _gc(root, keep)
    return ckpt


def _gc(root: Path, keep: int) -> None:
    steps = sorted(p for p in root.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    marker = Path(directory) / "LATEST"
    if not marker.exists():
        return None
    return int(marker.read_text().strip())


def restore_checkpoint(directory, like_tree, *, step: Optional[int] = None,
                       shardings: Any = None):
    """Restore into the structure of ``like_tree`` (arrays or
    ShapeDtypeStructs).  ``shardings``: matching tree of NamedShardings
    for elastic placement (None -> default devices)."""
    root = Path(directory)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    ckpt = root / f"step_{step:09d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    flat_sh = None
    if shardings is not None:
        flat_sh = [s for _, s in
                   jax.tree_util.tree_flatten_with_path(shardings)[0]]
    leaves = []
    for i, (path, like) in enumerate(flat_like):
        key = _SEP.join(_path_str(p) for p in path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(ckpt / f"{key}.npy")
        dtype = manifest["leaves"][key]["dtype"]
        if dtype == "bfloat16":
            import jax.numpy as jnp
            arr = jax.numpy.asarray(arr).view(jnp.bfloat16)
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
