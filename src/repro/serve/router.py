"""Multi-replica request router: one front-end queue over N
independent serve-engine replicas.

This is the scale-*out* half of distributed serving (serve/parallel.py
is the scale-*up* half): replicas are whole engines — each with its
own batch slots, page pool, and prefix trie — and the router decides
*which* replica serves each request.  Replicas may themselves be
tensor-parallel (``ServeEngine(tp=...)``); the two compose.

Routing policies (``policy=``):

* ``"prefix"`` (default) — **prefix affinity**: land a request on the
  replica whose trie already holds its prompt prefix, so the KV
  compute (and pages) for a shared system prompt are paid once *per
  replica that ever sees the workload* instead of once per request.
  Affinity is scored from two sources: a read-only trie probe
  (``PrefixCache.probe`` — ground truth for what is resident *now*)
  and the router's own recent-dispatch record (what will *become*
  resident once in-flight requests donate their prompts — a burst of
  same-prefix requests must not scatter just because the first one
  hasn't finished prefilling).  Ties, and prefixes nobody holds, fall
  back to least-outstanding-tokens.
* ``"least-loaded"`` — least outstanding tokens: queued + in-flight
  work (remaining prompt ingestion plus remaining generation budget),
  the standard N-queues load balancer.
* ``"round-robin"`` — dispatch order, ignoring both load and
  affinity; the baseline the policy tests compare against.

**Backpressure.**  Each replica accepts at most ``max_inflight``
requests (default ``2 * max_batch``: a full batch plus one queued
wave).  When every replica is at its cap the router simply *holds* the
queue — requests are never dropped and never reordered (FIFO; a
held head blocks later requests, which keeps arrival order fair and
routing deterministic).

**Why the aggregate scales.**  The router's throughput story is the
TPU-paper memory argument one level up: a single replica's page pool
bounds how many distinct hot prefixes stay resident — a workload
cycling through more prompt groups than the trie can hold LRU-thrashes
and re-prefills every admission.  N replicas hold N pools, and prefix
affinity *partitions* the groups across them, so each replica's
working set fits again (benchmarks/serve_router.py measures exactly
this regime).  Token streams are unchanged by construction: every
replica is a token-exact engine and routing only chooses *where* a
stream is produced.

The router implements the same ``ServeBackend`` protocol as a single
engine (serve/backend.py): submit/step/run/stats plus the streaming
face (``drain_events``) and mid-stream removal (``extract``/
``cancel``) — a front-end cannot tell one replica from a fleet.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from .backend import StreamEvent
from .scheduler import Request, ServeEngine

__all__ = ["RequestRouter", "ROUTER_POLICIES"]

ROUTER_POLICIES = ("prefix", "least-loaded", "round-robin")


class RequestRouter:
    def __init__(self, replicas: Sequence[ServeEngine], *,
                 policy: str = "prefix",
                 max_inflight: Optional[int] = None,
                 affinity_record: int = 1024):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {ROUTER_POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        self.max_inflight = (max_inflight if max_inflight is not None
                             else 2 * max(e.max_batch for e in replicas))
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.queue: deque[Request] = deque()
        self._rr = 0                     # round-robin cursor
        # replica -> LRU-ordered page-run keys of recently dispatched
        # prompts (before their pages can appear in the trie)
        self._recent: List[Dict[Tuple[int, ...], None]] = [
            {} for _ in replicas]
        self._recent_cap = affinity_record
        # stats
        self.n_dispatched = [0] * len(replicas)
        self.n_affinity_hits = 0         # dispatches with affinity > 0

    # ---------------------------------------------------------- frontend
    def check_admissible(self, req: Request) -> None:
        """Raise ValueError if NO replica could ever admit ``req``.
        Heterogeneous fleets are fine — dispatch only considers
        replicas that can take the request."""
        err = None
        for eng in self.replicas:
            try:
                eng.check_admissible(req)
                return
            except ValueError as e:
                err = e
        raise err

    def submit(self, req: Request) -> None:
        """Queue a request (see ``check_admissible`` for rejection)."""
        self.check_admissible(req)
        self.queue.append(req)

    @property
    def n_inflight(self) -> int:
        return len(self.queue) + sum(e.n_inflight for e in self.replicas)

    @property
    def capacity(self) -> int:
        """Aggregate concurrently-servable requests: the sum of the
        replicas' batch slots (per-replica ``max_inflight`` only pads
        each replica's internal queue beyond this)."""
        return sum(e.max_batch for e in self.replicas)

    def drain_events(self) -> List[StreamEvent]:
        """Confirmed-token events since the last drain, replica-major.
        Per-stream order is exact (a request lives on one replica);
        cross-stream interleaving is already only step-granular on a
        single engine, so replica-major order changes nothing a
        streaming consumer can observe."""
        ev: List[StreamEvent] = []
        for eng in self.replicas:
            ev.extend(eng.drain_events())
        return ev

    def extract(self, rid: int) -> Optional[Request]:
        """Remove the request wherever it lives — router queue or any
        replica — freeing backend resources; confirmed tokens survive
        and re-submission resumes the stream exactly (the replay
        machinery makes resumption replica-portable)."""
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                del self.queue[i]
                return r
        for eng in self.replicas:
            req = eng.extract(rid)
            if req is not None:
                return req
        return None

    def cancel(self, rid: int) -> bool:
        """Drop a request mid-stream (extract-and-discard); True if the
        rid was live anywhere in the fleet."""
        return self.extract(rid) is not None

    # --------------------------------------------------------- affinity
    def _page_keys(self, prompt) -> List[Tuple[int, ...]]:
        ps = self.replicas[0].cache.page_size
        toks = [int(t) for t in prompt]
        return [tuple(toks[:(j + 1) * ps])
                for j in range(len(toks) // ps)]

    def _record_dispatch(self, i: int, prompt) -> None:
        rec = self._recent[i]
        for key in self._page_keys(prompt):
            rec.pop(key, None)               # re-dispatch refreshes LRU
            rec[key] = None
        while len(rec) > self._recent_cap:   # evict least recently sent
            rec.pop(next(iter(rec)))

    def _affinity(self, i: int, prompt) -> int:
        """Tokens of ``prompt`` replica ``i`` (probably) holds: the max
        of trie ground truth and the recent-dispatch record."""
        eng = self.replicas[i]
        resident = (eng.cache.prefix.probe(prompt)
                    if eng.cache.prefix is not None else 0)
        ps = eng.cache.page_size
        rec, planned = self._recent[i], 0
        for n, key in enumerate(self._page_keys(prompt)):
            if key not in rec:
                break
            planned = (n + 1) * ps
        return max(resident, planned)

    # -------------------------------------------------------- dispatch
    def _outstanding_tokens(self, i: int) -> int:
        eng = self.replicas[i]
        reqs = list(eng.waiting) + list(eng.prefilling.values()) \
            + list(eng.active.values())
        return sum(len(r.prompt) - r.prefill_pos + r.max_new_tokens
                   - len(r.generated) for r in reqs)

    def _can_admit(self, i: int, req: Request) -> bool:
        try:
            self.replicas[i].check_admissible(req)
            return True
        except ValueError:
            return False

    def _pick(self, req: Request) -> Optional[int]:
        n = len(self.replicas)
        eligible = [i for i in range(n)
                    if self.replicas[i].n_inflight < self.max_inflight
                    and self._can_admit(i, req)]
        if not eligible:
            return None                  # backpressure: hold the queue
        if self.policy == "round-robin":
            for off in range(n):
                i = (self._rr + off) % n
                if i in eligible:
                    self._rr = (i + 1) % n
                    return i
        load = {i: self._outstanding_tokens(i) for i in eligible}
        if self.policy == "prefix":
            aff = {i: self._affinity(i, req.prompt) for i in eligible}
            best = max(aff.values())
            if best > 0:
                self.n_affinity_hits += 1
                eligible = [i for i in eligible if aff[i] == best]
        return min(eligible, key=lambda i: (load[i], i))

    # ------------------------------------------------------------- step
    def step(self, now: float = float("inf")) -> bool:
        """One router iteration: place every arrived queued request a
        replica will take (FIFO), then pump one engine step on every
        replica with work.  Returns True while anything is queued or
        in flight."""
        while self.queue and self.queue[0].arrival <= now:
            i = self._pick(self.queue[0])
            if i is None:
                break
            req = self.queue.popleft()
            self.replicas[i].submit(req)
            self._record_dispatch(i, req.prompt)
            self.n_dispatched[i] += 1
        busy = False
        for eng in self.replicas:
            if eng.n_inflight:
                eng.step(now)
                busy = True
        return busy or bool(self.queue)

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        """Field-wise sum of every replica's engine counters plus the
        router's own: reads identically to ``ServeEngine.stats`` (the
        ``ServeBackend`` contract), with fleet-level extras."""
        agg: Dict[str, float] = {}
        for eng in self.replicas:
            for k, v in eng.stats().items():
                agg[k] = agg.get(k, 0) + v
        # ratio fields don't sum — recompute from the summed counters
        agg["prefill_rows_mean"] = (agg["n_prefill_chunks"]
                                    / max(agg["n_prefill_dispatches"], 1))
        agg["n_replicas"] = len(self.replicas)
        agg["n_routed"] = sum(self.n_dispatched)
        agg["n_affinity_hits"] = self.n_affinity_hits
        return agg

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request], *,
            realtime: bool = False) -> List[Request]:
        """Drive to completion; returns the requests completed by THIS
        call, in completion order (``Request.rid`` identifies streams).
        Mirrors ``ServeEngine.run``'s realtime semantics."""
        first = {id(e): len(e.finished) for e in self.replicas}
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while True:
            now = (time.perf_counter() - t0) if realtime else float("inf")
            if not self.step(now=now):
                break
            if realtime and self.queue \
                    and not any(e.n_inflight for e in self.replicas):
                time.sleep(max(0.0, self.queue[0].arrival
                               - (time.perf_counter() - t0)))
        done = []
        for e in self.replicas:
            done.extend(e.finished[first[id(e)]:])
        done.sort(key=lambda r: (r.finish_time, r.rid))
        return done
