"""Multi-replica request router: one front-end queue over N
independent serve-engine replicas — N now *elastic*.

This is the scale-*out* half of distributed serving (serve/parallel.py
is the scale-*up* half): replicas are whole engines — each with its
own batch slots, page pool, and prefix trie — and the router decides
*which* replica serves each request.  Replicas may themselves be
tensor-parallel (``ServeEngine(tp=...)``); the two compose.

Routing policies (``policy=``):

* ``"prefix"`` (default) — **prefix affinity**: land a request on the
  replica whose trie already holds its prompt prefix, so the KV
  compute (and pages) for a shared system prompt are paid once *per
  replica that ever sees the workload* instead of once per request.
  Affinity is scored from two sources: a read-only trie probe
  (``PrefixCache.probe`` — ground truth for what is resident *now*)
  and the router's own recent-dispatch record (what will *become*
  resident once in-flight requests donate their prompts — a burst of
  same-prefix requests must not scatter just because the first one
  hasn't finished prefilling).  Ties, and prefixes nobody holds, fall
  back to least-outstanding-tokens.
* ``"least-loaded"`` — least outstanding tokens: queued + in-flight
  work (remaining prompt ingestion plus remaining generation budget),
  the standard N-queues load balancer.
* ``"round-robin"`` — dispatch order, ignoring both load and
  affinity; the baseline the policy tests compare against.

**Backpressure.**  Each replica accepts at most ``max_inflight``
requests (default ``2 * max_batch``: a full batch plus one queued
wave).  When every replica is at its cap the router simply *holds* the
queue — requests are never dropped and never reordered (FIFO; a
held head blocks later requests, which keeps arrival order fair and
routing deterministic).

**Elastic membership.**  The fleet is no longer fixed at construction:
``add_replica`` joins a fresh engine mid-trace, and ``drain`` retires
one *gracefully* — the draining replica takes no new admissions, and
on the next ``step`` every request it still holds (queued, prefilling,
or decoding) is **migrated**: extracted at its confirmed-token
frontier (``ServeEngine.extract_all`` — the same preempt-to-host
machinery ``extract`` uses) and re-queued at the *head* of the router
queue, oldest first, ahead of never-admitted arrivals.  Re-admission
on the target replica goes through the normal path: the prompt is
looked up in the target's prefix trie, so a migrated request whose
shared prefix is already resident there rebuilds its prompt pages via
**trie donation** — a refcount attach — rather than any cross-replica
byte copy, and its confirmed tokens replay through the target's decode
program (exact recompute-replay), so the resumed stream is bitwise the
stream it would have produced had it never moved.  Once empty, the
replica leaves the fleet; its engine counters are folded into
``stats()`` forever (departure never un-counts work — the
``n_total_dispatches = prefill + decode + replay − fused`` identity
holds fleet-wide across any churn), its finished requests stay in the
router's completion log, and its undrained stream events are held for
the next ``drain_events``.  The demand-driven control loop that
decides *when* to scale lives one layer up (serve/elastic.py).

**Failure.**  DRAINING is cooperative; FAILED is not.  A replica
whose ``step`` raises :class:`~repro.serve.faults.ReplicaFailure`, or
that misses the stall watchdog's progress deadline
(``stall_patience`` stepped rounds holding work without a single
dispatch), is declared FAILED: nothing can be extracted from it.  Its
requests are rebuilt from the router-side ``RequestJournal``
(serve/recovery.py) at their journal-confirmed token frontier and
re-admitted at the queue head on survivors — the same recompute-replay
path migration uses, so recovered streams stay bitwise-exact — and its
counters fold through the departed-stats accumulator exactly like a
graceful retirement, so the fleet dispatch identities survive the
crash.  See docs/robustness.md.

**Why the aggregate scales.**  The router's throughput story is the
TPU-paper memory argument one level up: a single replica's page pool
bounds how many distinct hot prefixes stay resident — a workload
cycling through more prompt groups than the trie can hold LRU-thrashes
and re-prefills every admission.  N replicas hold N pools, and prefix
affinity *partitions* the groups across them, so each replica's
working set fits again (benchmarks/serve_router.py measures exactly
this regime).  Token streams are unchanged by construction: every
replica is a token-exact engine and routing only chooses *where* a
stream is produced.

The router implements the same ``ServeBackend`` protocol as a single
engine (serve/backend.py): submit/step/run/stats plus the streaming
face (``drain_events``) and mid-stream removal (``extract``/
``cancel``) — a front-end cannot tell one replica from a fleet, or a
fixed fleet from an elastic one.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .backend import StreamEvent
from .faults import ReplicaFailure
from .recovery import RequestJournal
from .scheduler import Request, ServeEngine
from .telemetry import (Telemetry, expose_counters, merge_stats,
                        next_uid)

__all__ = ["RequestRouter", "ROUTER_POLICIES"]

ROUTER_POLICIES = ("prefix", "least-loaded", "round-robin")

_ROUTER_COUNTERS = ("n_joined", "n_departed", "n_migrations",
                    "n_migrated_tokens", "n_affinity_hits",
                    "n_failures", "n_recovered_requests",
                    "n_recovery_replayed_tokens")


@expose_counters(*_ROUTER_COUNTERS)
class RequestRouter:
    def __init__(self, replicas: Sequence[ServeEngine], *,
                 policy: str = "prefix",
                 max_inflight: Optional[int] = None,
                 affinity_record: int = 1024,
                 stall_patience: int = 8,
                 telemetry: Optional[Telemetry] = None):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {ROUTER_POLICIES}")
        self.policy = policy
        self.max_inflight = (max_inflight if max_inflight is not None
                             else 2 * max(e.max_batch for e in replicas))
        if self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.queue: deque[Request] = deque()
        self._rr = 0                     # round-robin cursor
        self._recent_cap = affinity_record
        # elastic membership: every replica gets a stable id at join
        # (list indices shift as replicas leave; ids never do)
        self.replicas: List[ServeEngine] = []
        self._ids: List[int] = []
        self._next_id = 0
        self._draining: set = set()            # replica ids mid-drain
        # replica id -> LRU-ordered page-run keys of recently dispatched
        # prompts (before their pages can appear in the trie)
        self._recent: Dict[int, Dict[Tuple[int, ...], None]] = {}
        self._harvested: Dict[int, int] = {}   # id -> finished harvested
        self.n_dispatched: List[int] = []      # parallel to replicas
        # completion log: finished requests in completion order,
        # harvested every step so they survive replica departure
        self.completed: List[Request] = []
        self._pending_events: List[StreamEvent] = []
        # counters of work done by replicas that have LEFT the fleet —
        # stats() folds these in so dispatch-count identities hold
        # across arbitrary membership churn
        self._departed_stats: Dict[str, float] = {}
        self._departed_routed = 0
        # counters live in the shared MetricsRegistry — legacy names
        # (n_joined, n_migrations = requests moved by a drain,
        # n_migrated_tokens = confirmed tokens they carried,
        # n_affinity_hits = dispatches with affinity > 0, ...) are
        # read-only properties via @expose_counters.  The router
        # inherits the first replica's Telemetry by default, so a
        # hand-built fleet shares one registry without extra wiring.
        self.tel = (telemetry if telemetry is not None
                    else replicas[0].tel)
        self.uid = next_uid("r")
        self._c = {n: self.tel.registry.counter(
            n, component="router", replica=self.uid)
            for n in _ROUTER_COUNTERS}
        self._peak = self.tel.registry.gauge(
            "n_replicas_peak", component="router", replica=self.uid)
        self.migrated_rids: set = set()
        self._migrating: Dict[int, str] = {}   # rid -> src engine uid
        self._last_now = 0.0
        # crash recovery (serve/recovery.py + docs/robustness.md): the
        # journal mirrors every dispatched request's confirmed-token
        # frontier from the events the router drains each step, so a
        # replica that dies without answering extract() can have its
        # requests rebuilt router-side.  The watchdog declares a
        # replica FAILED after stall_patience consecutive steps
        # holding work without dispatching any.
        if stall_patience < 1:
            raise ValueError("stall_patience must be >= 1")
        self.stall_patience = stall_patience
        self._journal = RequestJournal()
        self.failed_rids: set = set()          # rids ever recovered
        # replica id -> (last n_total_dispatches seen, stuck rounds)
        self._progress: Dict[int, Tuple[float, int]] = {}
        for eng in replicas:
            self.add_replica(eng)

    # ------------------------------------------------------- membership
    def add_replica(self, engine: ServeEngine) -> int:
        """Join ``engine`` to the fleet (it starts taking dispatches on
        the next ``step``).  Returns the replica's stable id.  All
        replicas built from one ``ServePrograms`` bundle share a
        compile cache, so a join costs allocator state, not a trace."""
        rid = self._next_id
        self._next_id += 1
        self.replicas.append(engine)
        self._ids.append(rid)
        self._recent[rid] = {}
        self._harvested[rid] = len(engine.finished)
        self.n_dispatched.append(0)
        self._c["n_joined"].inc()
        self._peak.set(max(self.n_replicas_peak, self.n_live))
        if self.tel:
            self.tel.record("router", t=self._last_now, kind="join",
                            replica=engine.uid,
                            fleet=len(self.replicas))
        return rid

    @property
    def n_replicas_peak(self) -> int:
        return int(self._peak.value)

    def _index_of(self, replica: Union[int, ServeEngine]) -> int:
        # identity first: replicas may be wrapped backends (e.g. a
        # FaultInjector), not literal ServeEngine instances
        if not isinstance(replica, int):
            for i, e in enumerate(self.replicas):
                if e is replica:
                    return i
            raise ValueError("engine is not in this fleet")
        if not 0 <= replica < len(self.replicas):
            raise ValueError(f"no replica at index {replica}")
        return replica

    @property
    def n_live(self) -> int:
        """Replicas accepting new admissions (not draining)."""
        return len(self.replicas) - len(self._draining)

    def is_draining(self, replica: Union[int, ServeEngine]) -> bool:
        return self._ids[self._index_of(replica)] in self._draining

    def drain(self, replica: Union[int, ServeEngine]) -> None:
        """Begin graceful scale-down of one replica: it takes no new
        admissions from this call on, and the next ``step`` migrates
        every request it still holds (extract at the confirmed-token
        frontier, re-queue at the router head) before removing it from
        the fleet.  Confirmed tokens survive; re-admission elsewhere
        resumes each stream token-exactly.  Idempotent per replica;
        refuses to drain the last live replica (the fleet must always
        be able to admit)."""
        i = self._index_of(replica)
        rid = self._ids[i]
        if rid in self._draining:
            return
        if self.n_live <= 1:
            raise ValueError("cannot drain the last live replica")
        self._draining.add(rid)

    def _remove_replica(self, i: int) -> None:
        """Drop an (empty) replica from the fleet, preserving its
        history: finished requests were harvested, engine counters fold
        into the departed-stats accumulator, undrained stream events
        queue for the next ``drain_events``."""
        eng = self.replicas[i]
        assert eng.n_inflight == 0, "removing a replica with live work"
        self._harvest(i)
        self._absorb(eng)
        self._drop_replica(i, eng.stats(), kind="retire")

    def _drop_replica(self, i: int, st: Dict[str, float], *,
                      kind: str, **fields) -> None:
        """Shared fleet-exit bookkeeping (graceful retire AND crash):
        fold the replica's counters into the departed-stats
        accumulator — departure never un-counts work, so the dispatch
        identity holds fleet-wide across any churn — and excise it
        from every membership structure."""
        eng = self.replicas[i]
        self._departed_stats = merge_stats([self._departed_stats, st])
        self._departed_routed += self.n_dispatched[i]
        rid = self._ids[i]
        self._draining.discard(rid)
        self._recent.pop(rid)
        self._harvested.pop(rid)
        self._progress.pop(rid, None)
        del self.replicas[i]
        del self._ids[i]
        del self.n_dispatched[i]
        self._c["n_departed"].inc()
        if self.tel:
            self.tel.record("router", t=self._last_now, kind=kind,
                            replica=eng.uid,
                            fleet=len(self.replicas), **fields)
        if self._rr > i:
            self._rr -= 1
        self._rr = self._rr % max(len(self.replicas), 1)

    # -------------------------------------------------------- failure
    def fail(self, replica: Union[int, ServeEngine],
             reason: str = "killed") -> int:
        """Declare a replica FAILED — the kill switch (chaos tests,
        an external health checker).  Unlike ``drain`` nothing is
        asked of the replica: its requests are rebuilt from the
        recovery journal and re-admitted on survivors.  Returns the
        number of requests recovered."""
        return self._fail_replica(self._index_of(replica),
                                  reason=reason)

    def _fail_replica(self, i: int, *, reason: str) -> int:
        """Handle a dead replica: mark its wrapper dead (a late
        revival must not double-serve), fold whatever counters are
        still scrapeable, drop it from the fleet, then reconstruct
        its lost requests from the journal — truncated to the
        confirmed-token frontier the router has already streamed —
        and re-admit them at the head of the queue (oldest first,
        like a drain's migration).  Re-admission rides the normal
        recompute-replay path, so every recovered stream is bitwise
        the stream an unfailed replica would have produced."""
        eng = self.replicas[i]
        sid = self._ids[i]
        if hasattr(eng, "mark_dead"):
            eng.mark_dead()
        try:
            self._harvest(i)         # finished work is already safe
        except ReplicaFailure:
            pass
        try:
            st = eng.stats()         # counters survive the process
        except ReplicaFailure:
            st = {}
        lost = self._journal.lost(sid)
        self._c["n_failures"].inc()
        recovered: List[Request] = []
        for entry in lost:
            req, burden = RequestJournal.reconstruct(entry)
            self._c["n_recovered_requests"].inc()
            self._c["n_recovery_replayed_tokens"].inc(burden)
            self.failed_rids.add(req.rid)
            if self.tel:
                self.tel.event(req, "failed", t=self._last_now,
                               replica=eng.uid, reason=reason)
                self.tel.event(req, "recovered", t=self._last_now,
                               n_confirmed=entry.confirmed)
            recovered.append(req)
        self._drop_replica(i, st, kind="fail", reason=reason,
                           lost=len(recovered))
        # journal.lost returned oldest-first; head-insert preserves it
        self.queue.extendleft(reversed(recovered))
        return len(recovered)

    def _pump_drains(self) -> None:
        """Execute pending drains: migrate every request a draining
        replica still holds to the head of the router queue (oldest
        first, ahead of never-admitted arrivals — they have already
        waited once), then retire the empty replica."""
        if not self._draining:
            return
        migrated: List[Request] = []
        for i in [j for j in range(len(self.replicas) - 1, -1, -1)
                  if self._ids[j] in self._draining]:
            eng = self.replicas[i]
            reqs = eng.extract_all()
            self._c["n_migrations"].inc(len(reqs))
            for r in reqs:
                self._c["n_migrated_tokens"].inc(len(r.generated))
                self.migrated_rids.add(r.rid)
                self._journal.unassign(r.rid)
                if self.tel:
                    # the "migrated" span event lands at re-dispatch,
                    # when the destination is known (see step)
                    self._migrating[r.rid] = eng.uid
            migrated.extend(reqs)
            self._remove_replica(i)
        migrated.sort(key=lambda r: (r.arrival, r.rid))
        self.queue.extendleft(reversed(migrated))

    # ---------------------------------------------------------- frontend
    def check_admissible(self, req: Request) -> None:
        """Raise ValueError if NO live replica could ever admit ``req``.
        Heterogeneous fleets are fine — dispatch only considers
        replicas that can take the request."""
        err = None
        for i, eng in enumerate(self.replicas):
            if self._ids[i] in self._draining:
                continue
            try:
                eng.check_admissible(req)
                return
            except ValueError as e:
                err = e
            except ReplicaFailure:
                continue      # dead replica, removed on the next step
        raise err or ValueError("no live replica to admit the request")

    def submit(self, req: Request) -> None:
        """Queue a request (see ``check_admissible`` for rejection)."""
        self.check_admissible(req)
        self.queue.append(req)
        if self.tel:
            self.tel.request_submitted(req, t=req.arrival)

    @property
    def n_inflight(self) -> int:
        return len(self.queue) + sum(e.n_inflight for e in self.replicas)

    @property
    def capacity(self) -> int:
        """Aggregate concurrently-servable requests: the sum of the
        *live* replicas' batch slots (draining replicas are on their
        way out; per-replica ``max_inflight`` only pads each replica's
        internal queue beyond this)."""
        return sum(e.max_batch for i, e in enumerate(self.replicas)
                   if self._ids[i] not in self._draining)

    @property
    def finished(self) -> List[Request]:
        """Completion log across the whole fleet's history — finished
        requests of departed replicas included (same reading as
        ``ServeEngine.finished``)."""
        self._harvest_all()
        return self.completed

    def _absorb(self, eng) -> None:
        """Pull ``eng``'s undrained stream events into the router's
        buffer, advancing the recovery journal's confirmed-token
        frontiers on the way past.  Called for every replica every
        step, so an event the engine emitted is in router memory — and
        journal-counted — before the next step can kill the replica."""
        evs = eng.drain_events()
        if evs:
            self._journal.observe(evs)
            self._pending_events.extend(evs)

    def drain_events(self) -> List[StreamEvent]:
        """Confirmed-token events since the last drain.  The router
        absorbs each replica's events every step (the journal must see
        them — see ``_absorb``), so this mostly serves the buffer; a
        final sweep catches events emitted outside ``step``.
        Per-stream order is exact (a request lives on one replica at a
        time); cross-stream interleaving is already only step-granular
        on a single engine, so buffer order changes nothing a
        streaming consumer can observe."""
        for eng in self.replicas:
            try:
                self._absorb(eng)
            except ReplicaFailure:
                pass          # detected and recovered on the next step
        ev: List[StreamEvent] = self._pending_events
        self._pending_events = []
        return ev

    def extract(self, rid: int) -> Optional[Request]:
        """Remove the request wherever it lives — router queue or any
        replica — freeing backend resources; confirmed tokens survive
        and re-submission resumes the stream exactly (the replay
        machinery makes resumption replica-portable)."""
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                del self.queue[i]
                self._journal.discard(rid)
                return r
        for eng in self.replicas:
            try:
                req = eng.extract(rid)
            except ReplicaFailure:
                continue     # dead replica: its rids live in the queue
                             # (recovered) or are gone — keep scanning
            if req is not None:
                self._journal.discard(rid)
                return req
        return None

    def cancel(self, rid: int) -> bool:
        """Drop a request mid-stream (extract-and-discard); True if the
        rid was live anywhere in the fleet.  Idempotent — a second
        cancel (including one racing a drain's migration) finds
        nothing and returns False."""
        req = self.extract(rid)
        if req is not None:
            self._migrating.pop(rid, None)
            if self.tel:
                self.tel.event(req, "cancelled", t=self._last_now)
        return req is not None

    # --------------------------------------------------------- affinity
    def _page_keys(self, prompt) -> List[Tuple[int, ...]]:
        ps = self.replicas[0].cache.page_size
        toks = [int(t) for t in prompt]
        return [tuple(toks[:(j + 1) * ps])
                for j in range(len(toks) // ps)]

    def _record_dispatch(self, i: int, prompt) -> None:
        rec = self._recent[self._ids[i]]
        for key in self._page_keys(prompt):
            rec.pop(key, None)               # re-dispatch refreshes LRU
            rec[key] = None
        while len(rec) > self._recent_cap:   # evict least recently sent
            rec.pop(next(iter(rec)))

    def _affinity(self, i: int, prompt) -> int:
        """Tokens of ``prompt`` replica ``i`` (probably) holds: the max
        of trie ground truth and the recent-dispatch record."""
        eng = self.replicas[i]
        resident = (eng.cache.prefix.probe(prompt)
                    if eng.cache.prefix is not None else 0)
        ps = eng.cache.page_size
        rec, planned = self._recent[self._ids[i]], 0
        for n, key in enumerate(self._page_keys(prompt)):
            if key not in rec:
                break
            planned = (n + 1) * ps
        return max(resident, planned)

    # -------------------------------------------------------- dispatch
    def _outstanding_tokens(self, i: int) -> int:
        eng = self.replicas[i]
        reqs = list(eng.waiting) + list(eng.prefilling.values()) \
            + list(eng.active.values())
        return sum(len(r.prompt) - r.prefill_pos + r.max_new_tokens
                   - len(r.generated) for r in reqs)

    def _can_admit(self, i: int, req: Request) -> bool:
        try:
            self.replicas[i].check_admissible(req)
            return True
        except (ValueError, ReplicaFailure):
            return False

    def _pick(self, req: Request) -> Optional[int]:
        n = len(self.replicas)
        eligible = [i for i in range(n)
                    if self._ids[i] not in self._draining
                    and self.replicas[i].n_inflight < self.max_inflight
                    and self._can_admit(i, req)]
        if not eligible:
            return None                  # backpressure: hold the queue
        if self.policy == "round-robin":
            for off in range(n):
                i = (self._rr + off) % n
                if i in eligible:
                    self._rr = (i + 1) % n
                    return i
        load = {i: self._outstanding_tokens(i) for i in eligible}
        if self.policy == "prefix":
            aff = {i: self._affinity(i, req.prompt) for i in eligible}
            best = max(aff.values())
            if best > 0:
                self._c["n_affinity_hits"].inc()
                eligible = [i for i in eligible if aff[i] == best]
        return min(eligible, key=lambda i: (load[i], i))

    # -------------------------------------------------------- watchdog
    def _stalled(self, i: int) -> bool:
        """Progress deadline: a replica that holds work and was just
        stepped must dispatch *something* (a prefill chunk, a decode
        round, a replay step).  ``stall_patience`` consecutive stepped
        rounds with a frozen dispatch counter and live requests is a
        wedged process — declare it failed.  Healthy replicas always
        progress when stepped, so the watchdog never fires on them."""
        eng, sid = self.replicas[i], self._ids[i]
        total = eng.n_total_dispatches
        last, stuck = self._progress.get(sid, (None, 0))
        stuck = (stuck + 1 if total == last and eng.n_inflight else 0)
        self._progress[sid] = (total, stuck)
        return stuck >= self.stall_patience

    # --------------------------------------------------------- harvest
    def _harvest(self, i: int) -> None:
        eng, rid = self.replicas[i], self._ids[i]
        new = eng.finished[self._harvested[rid]:]
        if new:
            self.completed.extend(new)
            self._harvested[rid] = len(eng.finished)

    def _harvest_all(self) -> None:
        for i in range(len(self.replicas)):
            self._harvest(i)

    # ------------------------------------------------------------- step
    def step(self, now: float = float("inf")) -> bool:
        """One router iteration: execute pending drains (migrating
        their requests), place every arrived queued request a replica
        will take (FIFO), then pump one engine step on every replica
        with work.  Returns True while anything is queued or in
        flight."""
        self._last_now = (float(now) if now != float("inf")
                          else self._last_now + 1.0)
        drains = len(self._draining)
        self._pump_drains()
        n_routed = 0
        while self.queue and self.queue[0].arrival <= now:
            i = self._pick(self.queue[0])
            if i is None:
                break
            req = self.queue.popleft()
            self.replicas[i].submit(req)
            self._journal.assign(req, self._ids[i])
            if self.tel:
                src = self._migrating.pop(req.rid, None)
                if src is not None:
                    self.tel.event(req, "migrated", t=self._last_now,
                                   src=src, dst=self.replicas[i].uid,
                                   n_generated=len(req.generated))
            self._record_dispatch(i, req.prompt)
            self.n_dispatched[i] += 1
            n_routed += 1
        busy = False
        failed: List[Tuple[int, str]] = []
        for i, eng in enumerate(self.replicas):
            if eng.n_inflight:
                try:
                    eng.step(now)
                except ReplicaFailure:
                    failed.append((i, "crash"))
                    continue
                busy = True
                self._harvest(i)
                self._absorb(eng)
                if self._stalled(i):
                    failed.append((i, "stall"))
        # process failures AFTER the loop (indices shift on removal),
        # highest index first so earlier indices stay valid
        for i, why in sorted(failed, reverse=True):
            self._fail_replica(i, reason=why)
        if failed:
            busy = True              # recovered work re-queued
        if self.tel and (busy or self.queue or drains or n_routed):
            self.tel.record(
                "router", t=self._last_now, kind="route",
                fleet=len(self.replicas), live=self.n_live,
                draining=drains, routed=n_routed,
                queued=len(self.queue),
                inflight=sum(e.n_inflight for e in self.replicas))
        return busy or bool(self.queue)

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        """Field-wise sum of every replica's engine counters — living
        AND departed (a replica leaving the fleet never un-counts its
        work, so cross-counter identities like ``n_total_dispatches =
        prefill + decode + replay − fused`` hold across churn) — plus
        the router's own: reads identically to ``ServeEngine.stats``
        (the ``ServeBackend`` contract), with fleet-level extras."""
        # ratio fields don't sum — merge_stats recomputes them from
        # the summed counters, the same derivation a lone engine uses
        agg = merge_stats([self._departed_stats]
                          + [eng.stats() for eng in self.replicas])
        agg["n_replicas"] = len(self.replicas)
        agg["n_replicas_peak"] = self.n_replicas_peak
        agg["n_joined"] = self.n_joined
        agg["n_departed"] = self.n_departed
        agg["n_migrations"] = self.n_migrations
        agg["n_migrated_tokens"] = self.n_migrated_tokens
        agg["n_routed"] = sum(self.n_dispatched) + self._departed_routed
        agg["n_affinity_hits"] = self.n_affinity_hits
        agg["n_failures"] = self.n_failures
        agg["n_recovered_requests"] = self.n_recovered_requests
        agg["n_recovery_replayed_tokens"] = \
            self.n_recovery_replayed_tokens
        return agg

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request], *,
            realtime: bool = False) -> List[Request]:
        """Drive to completion; returns the requests completed by THIS
        call, in completion order (``Request.rid`` identifies streams).
        Mirrors ``ServeEngine.run``'s realtime semantics."""
        first = len(self.finished)
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while True:
            now = (time.perf_counter() - t0) if realtime else float("inf")
            if not self.step(now=now):
                break
            if realtime and self.queue \
                    and not any(e.n_inflight for e in self.replicas):
                time.sleep(max(0.0, self.queue[0].arrival
                               - (time.perf_counter() - t0)))
        done = list(self.finished[first:])
        done.sort(key=lambda r: (r.finish_time, r.rid))
        return done
