"""One construction surface for the serve stack: ``ServeOptions``.

The serve CLI grew ~15 loose flags across five PRs, and every layer
(engine, router, front-end, benchmarks) re-threaded the same kwargs.
``ServeOptions`` is the single source of truth: the CLI registers its
flags through ``add_cli`` (spellings unchanged), parses them back with
``from_args``, and ``build``/``build_frontend`` construct the whole
backend stack — engine(s), tensor-parallel program bundle, router,
drafters, streaming front-end — from one value.  Programmatic callers
construct it directly and skip argparse entirely:

    opts = ServeOptions(batch=8, spec_k=4, replicas=2)
    backend = opts.sized_for(reqs).build(model, params)

Knob semantics are documented in docs/serving.md; this module only
owns how they compose into objects.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Dict, List, Optional, Sequence

from .kv_cache import pages_needed
from .router import ROUTER_POLICIES, RequestRouter
from .scheduler import ServeEngine
from .telemetry import Telemetry

__all__ = ["ServeOptions"]


def _parse_weights(spec: str) -> Dict[str, float]:
    """``"a=3,b=1"`` -> ``{"a": 3.0, "b": 1.0}`` (empty -> {})."""
    out: Dict[str, float] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        name, _, w = part.partition("=")
        out[name] = float(w) if w else 1.0
    return out


@dataclasses.dataclass
class ServeOptions:
    # engine
    batch: int = 4
    page_size: int = 16
    n_pages: int = 0                 # 0 -> size to the trace (sized_for)
    chunk_size: int = 32
    prefill_batch: int = 0           # 0 -> batch
    prefix_sharing: bool = True
    bucket_edges: Optional[List[int]] = None
    spec_k: int = 4
    draft_config: str = ""
    fused: bool = True               # one dispatch per steady-state step
    max_pages_per_seq: Optional[int] = None
    eos_id: Optional[int] = None
    # fleet
    tp: int = 1
    replicas: int = 1
    router_policy: str = "prefix"
    # elastic fleet (max_replicas > 0 enables the controller: the
    # fleet starts at min_replicas and scales with demand; 0 keeps the
    # fixed --replicas fleet)
    min_replicas: int = 1
    max_replicas: int = 0
    scale_interval: int = 8
    # fault tolerance (docs/robustness.md): watchdog deadline for the
    # router's stall detection, repair-loop knobs for the elastic
    # controller, and an optional scripted fault-injection plan
    # ("<replica>:<crash|stall>@<step>[x<rounds>]", comma-separated)
    # that wraps the initial replicas in FaultInjectors — the chaos
    # quickstart's entry point
    stall_patience: int = 8
    repair_backoff: int = 2
    repair_budget: int = 8
    fault_spec: str = ""
    # front-end
    stream: bool = False
    tenant_weights: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # telemetry (serve/telemetry.py): trace_out != "" or
    # metrics_interval > 0 turns tracing on; a programmatic caller can
    # hand in a pre-built Telemetry instead (it wins)
    trace_out: str = ""
    metrics_interval: int = 0
    telemetry: Optional[Telemetry] = None

    # ------------------------------------------------------------- CLI
    @staticmethod
    def add_cli(ap) -> None:
        """Register the serve-stack flags (same spellings the CLI has
        always used) on an argparse parser."""
        ap.add_argument("--batch", type=int, default=4)
        ap.add_argument("--page-size", type=int, default=16)
        ap.add_argument("--n-pages", type=int, default=0,
                        help="0 -> sized to the trace")
        ap.add_argument("--chunk-size", type=int, default=32,
                        help="prompt tokens ingested per engine step")
        ap.add_argument("--prefill-batch", type=int, default=0,
                        help="requests co-ingesting one prompt chunk "
                             "each per prefill dispatch (0 -> --batch; "
                             "1 -> serialized PR 2 path; tokens are "
                             "unchanged, only dispatch count)")
        ap.add_argument("--no-prefix-sharing", action="store_true",
                        help="disable the prefix cache (recompute every "
                             "prompt from scratch)")
        ap.add_argument("--bucket-edges", type=str, default="",
                        help="comma-separated context buckets in pages "
                             "(default: doubling)")
        ap.add_argument("--spec-k", type=int, default=4,
                        help="draft tokens verified per engine step "
                             "(speculative decode; tokens are "
                             "unchanged, only faster)")
        ap.add_argument("--no-spec", action="store_true",
                        help="disable speculative decode (one token per "
                             "decode step)")
        ap.add_argument("--fused", default=True,
                        action=argparse.BooleanOptionalAction,
                        help="fuse each steady-state step's prefill "
                             "chunk + decode/verify work into ONE "
                             "program dispatch (tokens unchanged; "
                             "--no-fused is the debugging escape hatch "
                             "back to the two-dispatch engine)")
        ap.add_argument("--draft-config", type=str, default="",
                        help="arch id of a draft model for speculation "
                             "(default: model-free n-gram prompt "
                             "lookup); resolved at the same --smoke "
                             "size as --arch")
        ap.add_argument("--tp", type=int, default=1,
                        help="tensor-parallel degree: shard each "
                             "engine's attention heads, FFN and paged "
                             "KV cache over a tp-device mesh (token "
                             "streams unchanged)")
        ap.add_argument("--replicas", type=int, default=1,
                        help="engine replicas behind the request router "
                             "(each gets its own --n-pages pool)")
        ap.add_argument("--min-replicas", type=int, default=1,
                        help="elastic-fleet floor (and initial size); "
                             "only read when --max-replicas > 0")
        ap.add_argument("--max-replicas", type=int, default=0,
                        help="> 0 makes the fleet ELASTIC: a control "
                             "loop scales replicas between "
                             "--min-replicas and this with demand, "
                             "migrating live requests off draining "
                             "replicas (token streams unchanged); 0 "
                             "keeps the fixed --replicas fleet")
        ap.add_argument("--scale-interval", type=int, default=8,
                        help="engine steps between elastic control "
                             "rounds")
        ap.add_argument("--stall-patience", type=int, default=8,
                        help="router watchdog: stepped rounds a "
                             "replica may hold work without a single "
                             "dispatch before it is declared FAILED "
                             "and its requests recovered from the "
                             "journal")
        ap.add_argument("--repair-backoff", type=int, default=2,
                        help="elastic repair loop: base backoff (in "
                             "steps, doubling per consecutive factory "
                             "failure) between attempts to rebuild a "
                             "crash-lost replica")
        ap.add_argument("--repair-budget", type=int, default=8,
                        help="elastic repair loop: consecutive failed "
                             "rebuild attempts tolerated before the "
                             "fleet stays degraded")
        ap.add_argument("--chaos-faults", type=str, default="",
                        help="scripted fault injection, e.g. "
                             "'0:crash@12,1:stall@8x5' — wrap replica "
                             "<i> in a FaultInjector that crashes at "
                             "its step <n> (or stalls for <rounds>); "
                             "recovery keeps streams token-exact "
                             "(docs/robustness.md)")
        ap.add_argument("--router-policy", type=str, default="prefix",
                        choices=list(ROUTER_POLICIES),
                        help="replica selection: prefix affinity "
                             "(default), least outstanding tokens, or "
                             "round-robin")
        ap.add_argument("--stream", action="store_true",
                        help="serve through the async streaming "
                             "front-end (per-request token streams, "
                             "SLO classes, tenant fairness) instead of "
                             "the offline batch driver")
        ap.add_argument("--tenant-weights", type=str, default="",
                        help="comma-separated tenant=weight pairs for "
                             "the --stream front-end (e.g. "
                             "'interactive=3,bulk=1'); requests are "
                             "assigned round-robin across the named "
                             "tenants")
        ap.add_argument("--trace-out", type=str, default="",
                        help="write serve telemetry (request lifecycle "
                             "spans + step timeline + metrics) as JSONL "
                             "to this path; also turns tracing on "
                             "(scripts/trace_report.py reads it)")
        ap.add_argument("--metrics-interval", type=int, default=0,
                        help="> 0 embeds a full metrics-registry "
                             "snapshot into the trace every N engine "
                             "step records (implies tracing)")

    @classmethod
    def from_args(cls, args) -> "ServeOptions":
        """Build from a parsed argparse namespace (``add_cli`` flags)."""
        edges = ([int(e) for e in args.bucket_edges.split(",")]
                 if args.bucket_edges else None)
        return cls(
            batch=args.batch,
            page_size=args.page_size,
            n_pages=args.n_pages,
            chunk_size=args.chunk_size,
            prefill_batch=args.prefill_batch,
            prefix_sharing=not args.no_prefix_sharing,
            bucket_edges=edges,
            spec_k=0 if args.no_spec else args.spec_k,
            draft_config=args.draft_config,
            fused=getattr(args, "fused", True),
            tp=args.tp,
            replicas=args.replicas,
            router_policy=args.router_policy,
            min_replicas=getattr(args, "min_replicas", 1),
            max_replicas=getattr(args, "max_replicas", 0),
            scale_interval=getattr(args, "scale_interval", 8),
            stall_patience=getattr(args, "stall_patience", 8),
            repair_backoff=getattr(args, "repair_backoff", 2),
            repair_budget=getattr(args, "repair_budget", 8),
            fault_spec=getattr(args, "chaos_faults", ""),
            stream=getattr(args, "stream", False),
            tenant_weights=_parse_weights(
                getattr(args, "tenant_weights", "")),
            trace_out=getattr(args, "trace_out", ""),
            metrics_interval=getattr(args, "metrics_interval", 0),
        )

    # ------------------------------------------------------ construction
    def sized_for(self, reqs: Sequence, *,
                  shared_prefix: int = 0) -> "ServeOptions":
        """Resolve ``n_pages == 0`` / ``max_pages_per_seq == None``
        from a request trace: per-replica pool = one null page + a
        (pages + headroom) budget per batch slot + the shared prefix's
        pages once.  Explicit values pass through unchanged."""
        need = [pages_needed(len(r.prompt) + r.max_new_tokens,
                             self.page_size) for r in reqs]
        mpps = self.max_pages_per_seq or max(need)
        n_pages = self.n_pages or (
            1 + self.batch * (max(need) + 1)
            + pages_needed(max(shared_prefix, 1), self.page_size))
        return dataclasses.replace(self, n_pages=n_pages,
                                   max_pages_per_seq=mpps)

    def make_drafter_factory(self, cfg_target, *, smoke: bool = False):
        """Per-replica drafter constructor for ``draft_config`` (None
        when the default n-gram prompt-lookup drafter applies).
        Drafter state is keyed by batch slot, so replicas must not
        share one instance."""
        if not (self.spec_k and self.draft_config):
            return None
        import jax

        from repro import configs
        from repro.models import build_model

        dcfg = (configs.get_smoke if smoke
                else configs.get)(self.draft_config)
        dmodel = build_model(dcfg)
        dparams = dmodel.init(jax.random.PRNGKey(1))

        def factory():
            from .spec import DraftModelDrafter
            return DraftModelDrafter(dmodel, dparams,
                                     cfg_target=cfg_target)
        return factory

    def build(self, model, params, *, smoke: bool = False,
              programs=None):
        """Construct the backend this options value describes: one
        ``ServeEngine`` (tensor-parallel when ``tp > 1``), a
        ``RequestRouter`` over ``replicas`` engines, or — when
        ``max_replicas > 0`` — an ``ElasticController`` whose fleet
        tracks demand.  All replicas, including ones the controller
        adds later, share ONE program bundle (one compile cache
        regardless of fleet size)."""
        if self.n_pages <= 0:
            raise ValueError("n_pages unresolved: pass it explicitly or "
                             "call sized_for(reqs) first")
        if programs is None:
            if self.tp > 1:
                from .parallel import TPServePrograms
                programs = TPServePrograms(model, tp=self.tp)
            else:
                from .step import ServePrograms
                programs = ServePrograms(model)
        drafter_factory = self.make_drafter_factory(model.cfg,
                                                    smoke=smoke)
        # ONE Telemetry per stack: every engine (including ones the
        # elastic controller adds later), the router, the controller
        # and the front-end share it, so spans survive migration and
        # the registry sees the whole fleet (backend.tel reaches it)
        tel = self.telemetry
        if tel is None:
            tel = Telemetry(
                trace=bool(self.trace_out or self.metrics_interval),
                metrics_interval=self.metrics_interval)

        def mk():
            return ServeEngine(
                model, params, max_batch=self.batch,
                n_pages=self.n_pages, page_size=self.page_size,
                max_pages_per_seq=self.max_pages_per_seq,
                eos_id=self.eos_id, chunk_size=self.chunk_size,
                prefill_batch=self.prefill_batch or self.batch,
                prefix_sharing=self.prefix_sharing,
                bucket_edges=self.bucket_edges, spec_k=self.spec_k,
                drafter=(drafter_factory() if drafter_factory
                         else None),
                fused=self.fused,
                programs=programs,
                telemetry=tel)

        def wrap_faults(engines):
            # scripted chaos: wrap the targeted initial replicas in
            # FaultInjectors (replicas joined later by the elastic
            # controller are always healthy builds)
            if not self.fault_spec:
                return engines
            from .faults import FaultInjector, parse_fault_spec
            engines = list(engines)
            for idx, kw in parse_fault_spec(self.fault_spec):
                if not 0 <= idx < len(engines):
                    raise ValueError(
                        f"--chaos-faults targets replica {idx}; fleet "
                        f"starts with {len(engines)}")
                engines[idx] = FaultInjector(engines[idx], **kw)
            return engines

        if self.max_replicas > 0:
            # elastic fleet: start at the floor, let demand grow it.
            # Every replica the controller ever builds comes from the
            # same mk() closure, so joins share the compile cache.
            from .elastic import ElasticController, ElasticPolicy
            lo = max(1, self.min_replicas)
            policy = ElasticPolicy(
                min_replicas=lo,
                max_replicas=max(lo, self.max_replicas),
                scale_interval=self.scale_interval,
                repair_backoff=self.repair_backoff,
                repair_budget=self.repair_budget)
            router = RequestRouter(
                wrap_faults([mk() for _ in range(lo)]),
                policy=self.router_policy,
                stall_patience=self.stall_patience,
                telemetry=tel)
            return ElasticController(router, mk, policy=policy)
        if self.replicas > 1:
            return RequestRouter(
                wrap_faults([mk() for _ in range(self.replicas)]),
                policy=self.router_policy,
                stall_patience=self.stall_patience,
                telemetry=tel)
        return wrap_faults([mk()])[0]

    def build_frontend(self, model, params, *, smoke: bool = False,
                       programs=None, slo_aware: bool = True,
                       realtime: bool = False):
        """Streaming front-end over the built backend, with
        ``tenant_weights`` materialized as tenant policies."""
        from .frontend import ServeFrontend, TenantPolicy
        tenants = {name: TenantPolicy(weight=w)
                   for name, w in self.tenant_weights.items()} or None
        return ServeFrontend(
            self.build(model, params, smoke=smoke, programs=programs),
            tenants=tenants, slo_aware=slo_aware, realtime=realtime)
