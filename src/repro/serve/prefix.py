"""Prefix trie mapping prompt-token runs to resident KV pages.

One trie node owns exactly one page and the run of tokens whose K/V
that page holds — ``page_size`` tokens for interior (full-page) nodes,
fewer for leaf tails.  Children are keyed by the *exact* token tuple of
the child's run, so descent is an O(pages) dict walk for the common
case; when no child matches exactly, the longest common prefix against
any child still yields a *partial* hit — the caller attaches that page
read-only and copy-on-write kicks in at the first divergent write
(see kv_cache.PagedKVCache).

The trie stores page *ids* only; page contents live in the device
arrays and refcounts live in the cache.  Each node's page carries one
trie reference for as long as the node exists, which is what keeps a
finished request's prompt KV resident for future hits.  Under page
pressure the cache evicts trie leaves in LRU order
(``pop_lru_leaves``);
interior nodes only become evictable once their subtree is gone, so a
surviving chain is always a usable prefix.

Invariants the cache and scheduler rely on (exercised by
kv_cache.check_invariants and tests/test_serve_engine.py):

* **One page, one node** — a page id appears in at most one trie node
  (``insert`` records only *newly created* nodes and first-writer
  wins), so the cache can charge exactly one trie reference per
  resident page and ``pages()`` never double-counts.
* **Never the null page** — page 0 is the masked-write sink; callers
  only ever insert allocated prompt pages, and the trie never
  fabricates ids.
* **A surviving chain is a usable prefix** — eviction removes leaves
  only; an interior node's page outlives its children, so any
  root-to-node walk that ``lookup`` returns describes contiguously
  resident KV starting at token 0.
* **Lookups always leave one token to compute** — ``total_shared`` is
  capped at ``len(tokens) - 1``; generation needs the final prompt
  token's logits, so a full-prompt hit deliberately under-reports by
  one (the admission path sizes its scatter from this).
* **The trie never mutates pages** — it hands out ids read-only;
  write protection is entirely the cache's refcount/COW discipline
  (a donated page's refcount includes the trie's reference, which is
  what makes the donor's own next write fork).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

__all__ = ["PrefixCache"]


class _Node:
    __slots__ = ("key", "page", "n_tokens", "children", "parent",
                 "last_used")

    def __init__(self, key, page, n_tokens, parent):
        self.key: Tuple[int, ...] = key
        self.page: int = page
        self.n_tokens: int = n_tokens
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent: Optional["_Node"] = parent
        self.last_used: int = 0


def _common_prefix(a, b) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PrefixCache:
    def __init__(self, page_size: int):
        self.ps = page_size
        self.root = _Node((), -1, 0, None)
        self._clock = 0
        self.n_nodes = 0

    @property
    def min_partial_hit(self) -> int:
        """Smallest partial-page overlap worth serving: a partial hit
        forces a copy-on-write page copy at the attach site, so tiny
        accidental overlaps between unrelated prompts cost more than
        they save.  Single source of truth for ``_descend`` and for
        predictors of future hits (``servable_after_insert``)."""
        return max(1, self.ps // 2)

    def servable_after_insert(self, lcp: int) -> int:
        """Leading tokens a ``lookup`` could serve once a prompt whose
        token-level common prefix with the queried one is ``lcp`` has
        been inserted: full pages descend exactly, and the partial
        remainder hits only at the ``min_partial_hit`` threshold.  The
        scheduler's admission deferral (serve/scheduler.py
        ``_defers_for_sharing``) uses this to predict whether waiting
        for an in-flight prompt's registration buys anything."""
        rem = lcp % self.ps
        return lcp - rem + (rem if rem >= self.min_partial_hit else 0)

    # ---------------------------------------------------------- queries
    def _descend(self, toks) -> Tuple[List[Tuple["_Node", int]], int]:
        """Shared traversal behind ``lookup`` and ``probe``: the
        longest resident run of ``toks`` as [(node, n_tokens)],
        uncapped and side-effect-free.  Full-page children descend
        exactly; otherwise the longest common prefix against any child
        yields one final partial hit — if it covers at least half a
        page (a partial hit forces a copy-on-write page copy at the
        attach site; tiny accidental overlaps between unrelated
        prompts cost more than they save)."""
        node, out, shared = self.root, [], 0
        while shared < len(toks):
            rem = toks[shared:]
            if len(rem) >= self.ps:
                ch = node.children.get(tuple(rem[:self.ps]))
                if ch is not None and ch.n_tokens == self.ps:
                    out.append((ch, self.ps))
                    shared += self.ps
                    node = ch
                    continue
            best, best_cp = None, 0
            for ch in node.children.values():
                cp = _common_prefix(ch.key[:ch.n_tokens], rem)
                if cp > best_cp:
                    best, best_cp = ch, cp
            if best is not None and best_cp >= self.min_partial_hit:
                out.append((best, best_cp))
                shared += best_cp
            break
        return out, shared

    def probe(self, tokens) -> int:
        """Read-only residency probe: how many leading tokens of
        ``tokens`` the trie could serve right now.  Unlike ``lookup``
        it neither bumps LRU clocks nor caps at ``len(tokens) - 1`` —
        it exists for *observers* (the request router's prefix-affinity
        scoring, serve/router.py), whose curiosity must not protect
        pages from eviction or perturb engine behavior."""
        return self._descend([int(t) for t in tokens])[1]

    def lookup(self, tokens) -> Tuple[List[Tuple[int, int]], int]:
        """Longest shared prefix of ``tokens`` resident in the trie.

        Returns ([(page_id, n_usable_tokens), ...], total_shared) with
        every entry full (``ps`` tokens) except possibly the last.
        ``total_shared`` is capped at ``len(tokens) - 1`` so the caller
        always computes at least the final prompt token (its logits
        seed generation).
        """
        toks = [int(t) for t in tokens]
        if not toks:
            return [], 0
        out, shared = self._descend(toks)
        if shared >= len(toks):            # leave >= 1 token to compute
            over = shared - (len(toks) - 1)
            node_, cnt = out[-1]
            if cnt - over > 0:
                out[-1] = (node_, cnt - over)
            else:
                out.pop()
            shared = len(toks) - 1
        self._clock += 1
        for n, _ in out:
            n.last_used = self._clock
        return [(n.page, c) for n, c in out], shared

    def insert(self, tokens, pages) -> List[int]:
        """Record ``tokens``' KV residency: page ``pages[i]`` holds the
        i-th page-sized run.  Existing nodes are left untouched (first
        writer wins); returns the page ids of *newly created* nodes —
        the caller must take a trie reference on each."""
        toks = [int(t) for t in tokens]
        self._clock += 1
        node, new_pages = self.root, []
        n_full = len(toks) // self.ps
        for i in range(n_full):
            key = tuple(toks[i * self.ps:(i + 1) * self.ps])
            ch = node.children.get(key)
            if ch is None or ch.n_tokens != self.ps:
                ch = _Node(key, int(pages[i]), self.ps, node)
                node.children[key] = ch
                self.n_nodes += 1
                new_pages.append(ch.page)
            ch.last_used = self._clock
            node = ch
        tail = toks[n_full * self.ps:]
        if tail:
            key = tuple(tail)
            if key not in node.children:
                ch = _Node(key, int(pages[n_full]), len(tail), node)
                node.children[key] = ch
                self.n_nodes += 1
                new_pages.append(ch.page)
            node.children[key].last_used = self._clock
        return new_pages

    # --------------------------------------------------------- eviction
    def pop_lru_leaves(self, n: int) -> List[int]:
        """Remove up to ``n`` least-recently-used leaf nodes and return
        their page ids (caller drops the trie references).  One DFS per
        round harvests the whole current leaf set — interior nodes only
        become leaves (and evictable) once their subtree is gone, so a
        fresh walk runs only when a round exhausts the previous set."""
        out: List[int] = []
        while len(out) < n:
            leaves: List[_Node] = []

            def walk(node):
                for ch in node.children.values():
                    if ch.children:
                        walk(ch)
                    else:
                        leaves.append(ch)
            walk(self.root)
            if not leaves:
                break
            leaves.sort(key=lambda x: x.last_used)
            for leaf in leaves[:n - len(out)]:
                del leaf.parent.children[leaf.key]
                self.n_nodes -= 1
                out.append(leaf.page)
        return out

    # ------------------------------------------------------- inspection
    def resident_tokens(self) -> int:
        """Total prompt tokens the trie holds KV for (sum of node
        runs).  An observer-side warmth measure: the elastic
        controller's scale-down victim scoring (serve/elastic.py)
        prefers retiring the replica whose trie would be the smallest
        loss — like ``probe``, reading it must not perturb LRU state."""
        total = 0

        def walk(node):
            nonlocal total
            for ch in node.children.values():
                total += ch.n_tokens
                walk(ch)
        walk(self.root)
        return total

    def pages(self) -> List[int]:
        out = []

        def walk(node):
            for ch in node.children.values():
                out.append(ch.page)
                walk(ch)
        walk(self.root)
        return out

    def __len__(self) -> int:
        return self.n_nodes
