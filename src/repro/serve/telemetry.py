"""Serve telemetry: request lifecycle spans, step timeline, metrics.

Three surfaces, one ``Telemetry`` object threaded through the stack
(``ServeOptions.build`` hands the same instance to every engine, the
router, the elastic controller and the frontend):

* **Request spans** — every :class:`~repro.serve.backend.Request`
  accumulates typed :class:`SpanEvent` s on its ``trace`` list
  (``submitted -> admitted -> chunk_prefilled* -> promoted ->
  decode_round* -> finished``, with ``preempted`` / ``replayed`` /
  ``migrated`` / ``cancelled`` interleaved as chaos happens).  Times
  are the serve stack's synthetic step clock (the ``now`` passed to
  ``step``; the engine substitutes its step index when driven with
  ``now=inf``) plus optional wall time, so TTFT / TPOT / queue delay
  are derivable per request and per tenant / SLO class.

* **Step timeline** — scheduler / router / controller emit one record
  per step (dispatch kind, rows per group, page deltas, fleet size,
  drains in flight).  :meth:`Telemetry.write_jsonl` exports spans +
  timeline as JSONL; :func:`chrome_trace` converts the same lines to
  Chrome trace-event format viewable in Perfetto / chrome://tracing.

* **Metrics registry** — :class:`MetricsRegistry` holds labelled
  counters / gauges / histograms.  The serve components register their
  counters here and keep the legacy ``stats()`` keys as a
  compatibility view (read-only properties over registry counters), so
  the registry *subsumes* the ad-hoc stats dicts instead of shadowing
  them.  The dispatch-accounting identity ``total = prefill + decode +
  replay - fused`` is re-checked by :meth:`MetricsRegistry.audit` on
  every step record while tracing.

Zero-cost-when-off: ``bool(Telemetry())`` is ``False`` and every hook
is guarded by ``if self.tel:`` — with tracing off the serve stack does
no span/record work at all (registry counters always run; they replace
the ``+=`` the stats dicts already paid for).  This module is
deliberately stdlib-only so ``scripts/trace_report.py`` can load it
without importing jax.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import time
from collections import OrderedDict
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

# Event kinds a request span may contain (the JSONL schema contract;
# scripts/trace_report.py --validate enforces it).  ``failed`` marks a
# request lost to a replica crash/stall, ``recovered`` its journal
# reconstruction (re-admission then rides the normal replay path), and
# ``shed`` a typed admission rejection under degraded capacity.
EVENT_KINDS = ("submitted", "admitted", "chunk_prefilled", "promoted",
               "decode_round", "preempted", "replayed", "migrated",
               "failed", "recovered", "shed",
               "cancelled", "finished")
TERMINAL_KINDS = ("finished", "cancelled")

# Ratio stats keys -> (numerator counter, denominator counter).  These
# are re-derived from summed counters by merge_stats so per-replica and
# fleet-wide views agree (the router's departed-replica accumulation
# and launch/serve's summary both go through here).
RATIO_FIELDS: Dict[str, Tuple[str, str]] = {
    "prefill_rows_mean": ("n_prefill_chunks", "n_prefill_dispatches"),
    "accept_rate": ("n_draft_accepted", "n_drafted"),
}

# The dispatch-accounting identity (see docs/serving.md):
#   n_total = n_prefill + n_decode + n_replay - n_fused
_IDENTITY = ("n_total_dispatches", "n_prefill_dispatches",
             "n_decode_steps", "n_replay_steps", "n_fused_dispatches")

# Crash-recovery counters (see docs/robustness.md): recovery implies
# failure, and replay burden implies recovered requests — audit()
# checks the implication chain wherever these are registered.
_RECOVERY = ("n_failures", "n_recovered_requests",
             "n_recovery_replayed_tokens")

_uid_counters: Dict[str, "itertools.count[int]"] = {}


def next_uid(prefix: str) -> str:
    """Process-wide unique component id, e.g. ``e0, e1, ...`` for
    engines — used as the ``replica`` metric label and in span/step
    records so migrations are attributable across fleet churn."""
    c = _uid_counters.setdefault(prefix, itertools.count())
    return f"{prefix}{next(c)}"


def merge_stats(dicts: Iterable[Dict[str, float]]) -> Dict[str, float]:
    """Sum stats dicts, re-deriving ratio fields from the summed
    counters (a mean of means is wrong once replicas differ in size).
    The single aggregation point for engine stats, the router's
    live+departed fold, and launch/serve's end-of-run summary."""
    agg: Dict[str, float] = {}
    for d in dicts:
        for k, v in d.items():
            if k not in RATIO_FIELDS:
                agg[k] = agg.get(k, 0) + v
    for k, (num, den) in RATIO_FIELDS.items():
        agg[k] = agg.get(num, 0) / max(agg.get(den, 0), 1)
    return agg


# ------------------------------------------------------------- metrics
class Counter:
    """Monotonic counter.  ``.value`` is exact (int-in, int-out)."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Raw-sample histogram (serve runs are small enough that keeping
    samples beats choosing bucket boundaries up front)."""
    __slots__ = ("samples",)

    def __init__(self) -> None:
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples \
            else 0.0

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)


def percentile(samples: List[float], q: float) -> float:
    """Nearest-rank percentile over raw samples, stdlib-only."""
    if not samples:
        return 0.0
    s = sorted(samples)
    i = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return s[i]


_Key = Tuple[str, Tuple[Tuple[str, str], ...]]


class MetricsRegistry:
    """Labelled metric store: ``counter/gauge/histogram(name,
    **labels)`` get-or-create, ``snapshot()`` flattens to
    ``name{k=v,...} -> value`` for JSONL / summary.json, ``audit()``
    re-checks the dispatch identity per labelled component."""

    def __init__(self) -> None:
        self._metrics: "OrderedDict[_Key, Any]" = OrderedDict()

    def _get(self, cls, name: str, labels: Dict[str, Any]):
        key = (name, tuple(sorted((k, str(v))
                                  for k, v in labels.items())))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls()
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name}{dict(key[1])} already "
                            f"registered as {type(m).__name__}")
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get(Histogram, name, labels)

    def total(self, name: str) -> float:
        """Sum of a counter/gauge across all label sets."""
        return sum(m.value for (n, _), m in self._metrics.items()
                   if n == name and not isinstance(m, Histogram))

    def snapshot(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for (name, labels), m in self._metrics.items():
            lbl = ("{" + ",".join(f"{k}={v}" for k, v in labels) + "}"
                   if labels else "")
            if isinstance(m, Histogram):
                out[name + lbl + ".count"] = m.count
                out[name + lbl + ".mean"] = m.mean
                out[name + lbl + ".p50"] = m.percentile(50)
                out[name + lbl + ".p99"] = m.percentile(99)
            else:
                out[name + lbl] = m.value
        return out

    def audit(self) -> List[str]:
        """Check ``n_total = n_prefill + n_decode + n_replay -
        n_fused`` for every label set that registered the identity
        counters, plus fleet-wide over the summed totals.  Returns a
        list of violation strings (empty = healthy)."""
        groups: Dict[Tuple[Tuple[str, str], ...],
                     Dict[str, float]] = {}
        rec_groups: Dict[Tuple[Tuple[str, str], ...],
                         Dict[str, float]] = {}
        for (name, labels), m in self._metrics.items():
            if name in _IDENTITY:
                groups.setdefault(labels, {})[name] = m.value
            elif name in _RECOVERY:
                rec_groups.setdefault(labels, {})[name] = m.value
        errs = []
        for labels, vals in rec_groups.items():
            if vals.get("n_recovered_requests", 0) \
                    and not vals.get("n_failures", 0):
                errs.append(f"{dict(labels)}: n_recovered_requests="
                            f"{vals['n_recovered_requests']} with "
                            "n_failures=0")
            if vals.get("n_recovery_replayed_tokens", 0) \
                    and not vals.get("n_recovered_requests", 0):
                errs.append(f"{dict(labels)}: "
                            "n_recovery_replayed_tokens="
                            f"{vals['n_recovery_replayed_tokens']} "
                            "with n_recovered_requests=0")
        fleet = {k: 0.0 for k in _IDENTITY}
        for labels, vals in groups.items():
            for k in _IDENTITY:
                fleet[k] += vals.get(k, 0)
            if "n_total_dispatches" not in vals:
                continue
            want = (vals.get("n_prefill_dispatches", 0)
                    + vals.get("n_decode_steps", 0)
                    + vals.get("n_replay_steps", 0)
                    - vals.get("n_fused_dispatches", 0))
            if vals["n_total_dispatches"] != want:
                errs.append(f"{dict(labels)}: n_total_dispatches="
                            f"{vals['n_total_dispatches']} != {want}")
        want = (fleet["n_prefill_dispatches"] + fleet["n_decode_steps"]
                + fleet["n_replay_steps"] - fleet["n_fused_dispatches"])
        if groups and fleet["n_total_dispatches"] != want:
            errs.append(f"fleet: n_total_dispatches="
                        f"{fleet['n_total_dispatches']} != {want}")
        return errs


def expose_counters(*names: str):
    """Class decorator: install read-only legacy attributes (e.g.
    ``engine.n_decode_steps``) backed by registry counters stored in
    ``self._c`` — the stats()-compatibility view of the registry."""
    def deco(cls):
        for n in names:
            setattr(cls, n,
                    property(lambda self, _n=n: self._c[_n].value))
        return cls
    return deco


# --------------------------------------------------------------- spans
@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One typed lifecycle event on a request's trace."""
    kind: str
    t: float                      # synthetic step clock
    wall: Optional[float] = None  # perf_counter seconds, if enabled
    attrs: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": self.kind, "t": self.t}
        if self.wall is not None:
            d["wall"] = self.wall
        if self.attrs:
            d.update(self.attrs)
        return d


class Telemetry:
    """The tracing switchboard.  ``bool(tel)`` is the trace-enabled
    flag (so hooks read ``if self.tel:``); the metrics registry is
    always live.  One instance is shared by every component of a serve
    stack so spans survive migration across replicas and the registry
    sees the whole fleet."""

    def __init__(self, *, trace: bool = False, wall: bool = False,
                 metrics_interval: int = 0,
                 registry: Optional[MetricsRegistry] = None) -> None:
        self.trace = bool(trace)
        self.wall = bool(wall)
        self.metrics_interval = int(metrics_interval)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.records: List[Dict[str, Any]] = []
        self.clock_label = "steps"   # launch sets "seconds" (realtime)
        self._requests: "OrderedDict[int, Any]" = OrderedDict()
        self._since_snapshot = 0

    def __bool__(self) -> bool:
        return self.trace

    # -- spans
    def event(self, req, kind: str, t: float, **attrs: Any) -> None:
        if not self.trace:
            return
        req.trace.append(SpanEvent(
            kind, float(t),
            time.perf_counter() if self.wall else None,
            attrs or None))
        self._requests[req.rid] = req

    def request_submitted(self, req, t: float) -> None:
        """Dedup'd ``submitted`` marker: layered backends (frontend ->
        router -> engine) and migration re-submits all call this; only
        the first submission opens the span."""
        if self.trace and not req.trace:
            self.event(req, "submitted", t)

    # -- step timeline
    def record(self, component: str, t: float, **fields: Any) -> None:
        if not self.trace:
            return
        rec: Dict[str, Any] = {"type": "step", "component": component,
                               "t": float(t), **fields}
        if self.wall:
            rec["wall"] = time.perf_counter()
        self.records.append(rec)
        errs = self.registry.audit()
        if errs:
            raise RuntimeError("metrics self-audit failed: "
                               + "; ".join(errs))
        if self.metrics_interval > 0:
            self._since_snapshot += 1
            if self._since_snapshot >= self.metrics_interval:
                self._since_snapshot = 0
                self.records.append({"type": "metrics", "t": float(t),
                                     "values":
                                     self.registry.snapshot()})

    # -- export
    def jsonl_lines(self) -> Iterator[Dict[str, Any]]:
        yield {"type": "meta", "version": 1, "clock": self.clock_label,
               "wall": self.wall}
        for rid, req in self._requests.items():
            yield {"type": "span", "rid": rid,
                   "tenant": getattr(req, "tenant", "default"),
                   "slo": getattr(req, "slo_class", "batch"),
                   "prompt_tokens": int(len(req.prompt)),
                   "generated": int(len(req.generated)),
                   "events": [ev.to_dict() for ev in req.trace]}
        yield from self.records
        last_t = self.records[-1]["t"] if self.records else 0.0
        yield {"type": "metrics", "t": last_t, "final": True,
               "values": self.registry.snapshot()}

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for line in self.jsonl_lines():
                f.write(json.dumps(line) + "\n")

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(chrome_trace(self.jsonl_lines()), f)


def chrome_trace(lines: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Convert parsed telemetry JSONL lines to Chrome trace-event JSON
    (load in Perfetto / chrome://tracing).  Step records become "X"
    slices on one track per component/replica; request spans become
    async "b"/"e" pairs with instant events for each lifecycle step.
    One step-clock unit renders as 1ms (1s when the meta line says the
    clock was wall seconds)."""
    scale = 1000.0
    events: List[Dict[str, Any]] = []
    for ln in lines:
        typ = ln.get("type")
        if typ == "meta" and ln.get("clock") == "seconds":
            scale = 1e6
        elif typ == "step":
            tid = ln.get("replica", ln.get("component", "?"))
            events.append({
                "ph": "X", "pid": "timeline", "tid": str(tid),
                "name": str(ln.get("kind", ln.get("component"))),
                "ts": ln["t"] * scale, "dur": scale,
                "args": {k: v for k, v in ln.items()
                         if k not in ("type", "t")}})
        elif typ == "span":
            evs = ln.get("events", [])
            if not evs:
                continue
            rid, cat = ln["rid"], f"tenant={ln.get('tenant')}"
            name = f"req{rid}"
            events.append({"ph": "b", "cat": cat, "id": rid,
                           "pid": "requests", "tid": name,
                           "name": name, "ts": evs[0]["t"] * scale})
            for ev in evs:
                events.append({"ph": "n", "cat": cat, "id": rid,
                               "pid": "requests", "tid": name,
                               "name": ev["kind"],
                               "ts": ev["t"] * scale,
                               "args": {k: v for k, v in ev.items()
                                        if k not in ("kind",)}})
            events.append({"ph": "e", "cat": cat, "id": rid,
                           "pid": "requests", "tid": name,
                           "name": name, "ts": evs[-1]["t"] * scale})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# -------------------------------------------------------- verification
def check_spans(reqs, *, cancelled: Iterable[int] = (),
                backend=None) -> None:
    """The trace-exactness bar (used by ``drive_and_check``'s telemetry
    sweep and tests/test_serve_telemetry.py):

    * every span starts with exactly one ``submitted`` and ends with
      exactly one terminal event matching the request's fate;
    * confirmed-token events sum to ``len(generated)`` exactly;
    * admissions reconcile with preemptions + migrations +
      crash recoveries (each ``recovered`` pairs with a ``failed``);
    * ``migrated`` events carry ``src != dst`` and the next admission
      lands on ``dst``;
    * a ``shed`` span is a rejected submit: nothing before or after
      the shed marker, and the request generated nothing;
    * against ``backend`` (optional): finished events == finished
      list, replayed tokens == ``n_replay_steps``, and the registry
      audit is clean.
    """
    finish_events = replay_total = 0
    for r in reqs:
        evs = list(r.trace)
        assert evs, f"rid {r.rid}: traced request has no span events"
        kinds = [e.kind for e in evs]
        if "shed" in kinds:
            # shed at submit: the request never entered the stack
            assert kinds == ["shed"], (r.rid, kinds)
            assert len(r.generated) == 0, (r.rid, r.generated)
            continue
        assert kinds[0] == "submitted", (r.rid, kinds)
        assert kinds.count("submitted") == 1, (r.rid, kinds)
        terms = [k for k in kinds if k in TERMINAL_KINDS]
        assert len(terms) == 1, \
            f"rid {r.rid}: {len(terms)} terminal events in {kinds}"
        assert kinds[-1] in TERMINAL_KINDS, (r.rid, kinds)
        want_term = ("cancelled" if r.rid in set(cancelled)
                     else "finished")
        assert terms[0] == want_term, (r.rid, terms, want_term)
        for e in evs:
            assert e.kind in EVENT_KINDS, e
        ntok = sum((e.attrs or {}).get("n", 0) for e in evs
                   if e.kind in ("decode_round", "promoted"))
        assert ntok == len(r.generated), \
            (f"rid {r.rid}: span confirms {ntok} tokens, request "
             f"holds {len(r.generated)}")
        n_adm = kinds.count("admitted")
        n_pre = kinds.count("preempted")
        n_mig = kinds.count("migrated")
        n_fail = kinds.count("failed")
        n_rec = kinds.count("recovered")
        # every reconstruction answers exactly one loss (a request can
        # crash more than once, but never recovers without failing)
        assert n_fail == n_rec, (r.rid, n_fail, n_rec, kinds)
        if want_term == "finished":
            assert 1 <= n_adm <= 1 + n_pre + n_mig + n_rec, \
                (r.rid, n_adm, n_pre, n_mig, n_rec)
        replay_total += sum((e.attrs or {}).get("n", 0) for e in evs
                            if e.kind == "replayed")
        finish_events += kinds.count("finished")
        for j, e in enumerate(evs):
            if e.kind == "migrated":
                a = e.attrs or {}
                assert a.get("src") != a.get("dst"), (r.rid, a)
                nxt = next((x for x in evs[j:]
                            if x.kind == "admitted"), None)
                if nxt is not None:
                    assert (nxt.attrs or {}).get("replica") == \
                        a.get("dst"), (r.rid, nxt, a)
    if backend is not None:
        st = backend.stats()
        assert finish_events == len(backend.finished), \
            (finish_events, len(backend.finished))
        assert replay_total == st["n_replay_steps"], \
            (replay_total, st["n_replay_steps"])
        tel = getattr(backend, "tel", None)
        if tel is not None:
            errs = tel.registry.audit()
            assert not errs, errs
