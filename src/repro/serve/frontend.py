"""Async streaming serve front-end: multi-tenant submit/stream with
SLO classes and weighted fair scheduling over any ``ServeBackend``.

The engine (PRs 1–5) serves offline batches: every request is known up
front and ``run`` drives them to completion.  A production deployment
is the opposite shape — callers arrive at any time, want their tokens
*as they are produced*, may hang up mid-stream, and are not all equal:
an interactive user's time-to-first-token matters more than a bulk
job's throughput (the TPU paper's 99th-percentile argument).  This
module is that serving surface:

* **submit/stream** — ``submit()`` returns a ``TokenStream`` that
  yields tokens as they are *confirmed* by the backend: one per decode
  step, a burst per accepted speculation round (the streaming face of
  ``drain_events``).  Confirmed tokens are final — preemption/replay
  re-derives KV, never tokens — so streaming is exactly as token-exact
  as the batch path.  Streams are consumable synchronously (iteration
  pumps the backend on demand) or with ``async for`` against a
  ``serve()`` pump task.
* **weighted fair queueing** — each tenant has a ``TenantPolicy``
  (weight, optional token-rate limit).  Dispatch is stride-scheduled:
  a tenant's virtual time advances by ``cost / weight`` per dispatched
  request (cost = prompt + generation budget in tokens), and the
  lowest virtual time dispatches next — long-run token share is
  proportional to weight (the deterministic counterpart of Ray Serve's
  CentralizedQueues traffic split).  Rate limits are debt-style token
  buckets: a tenant whose bucket is negative waits, everyone else
  proceeds.
* **SLO classes** — ``interactive`` requests dispatch before ``batch``
  ones whenever a slot is free, and when none is free an interactive
  arrival *preempts* a batch-class request: the victim is extracted
  from the backend (pages freed via the preemption machinery), parked
  back at the head of its tenant queue, and later resumes token-exactly
  (recompute-replay) — its already-streamed tokens stay valid.
  Exactness makes this SLO knob free of correctness risk.
* **cancel** — ``stream.cancel()`` maps to ``backend.extract``: pages
  return to the allocator immediately, prompt pages the request
  donated to the prefix trie stay resident, so cancel-then-resubmit
  re-shares them.

The front-end owns ALL queueing policy: it dispatches to the backend
only while ``backend.n_inflight < backend.capacity``, so the backend's
internal queue stays empty apart from its own page-pressure
preemptions, and admission order is exactly dispatch order.  Because
``ServeEngine`` and ``RequestRouter`` implement the same
``ServeBackend`` protocol, the front-end serves one engine or a
routed fleet identically.

Clocking: ``pump(now=...)`` drives one scheduling iteration.  With no
argument the front-end self-clocks — wall time when
``realtime=True``, otherwise a deterministic step counter (+1 per
pump), which frames every latency (TTFT, fairness windows) in
*backend steps*: the machine-independent unit the benchmarks gate on
(see docs/serving.md).
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from .backend import ServeBackend, StreamEvent
from .scheduler import Request, SLO_CLASSES
from .telemetry import (Counter, Telemetry, expose_counters, next_uid)

__all__ = ["ServeFrontend", "TokenStream", "TenantPolicy",
           "ShedRejection"]


class ShedRejection(RuntimeError):
    """Typed admission rejection under degraded capacity: the backend
    reports ``degraded`` (fleet below its replica floor after crash
    losses) and the request is batch-class, so it is refused at submit
    instead of queueing unboundedly behind capacity that may not come
    back.  Interactive traffic keeps flowing.  The caller can retry
    later; nothing was enqueued."""

    def __init__(self, req: Request):
        self.rid = req.rid
        self.tenant = req.tenant
        self.slo_class = req.slo_class
        super().__init__(
            f"request {req.rid} (tenant {req.tenant!r}, "
            f"{req.slo_class}) shed: serving capacity degraded")


@dataclasses.dataclass
class TenantPolicy:
    """Per-tenant traffic policy.

    ``weight`` sets the tenant's long-run token share under contention
    (stride-scheduled WFQ).  ``rate`` (cost units — prompt + budget
    tokens — per clock unit) caps sustained admission via a debt-style
    token bucket of depth ``burst`` (default: one clock unit's worth):
    dispatch is allowed while the bucket is non-negative and charges
    the full request cost, so a tenant can overdraw once but then
    waits out its debt — bursty traffic admits immediately, sustained
    overload is throttled, and no request is ever too big to pass.
    """
    weight: float = 1.0
    rate: Optional[float] = None
    burst: Optional[float] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.rate is not None and self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")


class TokenStream:
    """Per-request confirmed-token stream.

    Iterate synchronously (``for tok in stream`` — pumps the front-end
    on demand until the next token lands) or asynchronously
    (``async for tok in stream`` — parks on an event the pump task
    sets; requires ``frontend.serve()`` running in the same loop).
    ``cancel()`` ends the stream mid-flight; tokens already yielded
    were confirmed and remain valid.
    """

    def __init__(self, frontend: "ServeFrontend", req: Request):
        self._frontend = frontend
        self.req = req
        self._pending: deque = deque()
        self.finished = False
        self.cancelled = False
        self._wakeup: Optional[asyncio.Event] = None

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def tenant(self) -> str:
        return self.req.tenant

    @property
    def slo_class(self) -> str:
        return self.req.slo_class

    def _push(self, tokens, finished: bool) -> None:
        self._pending.extend(tokens)
        self.finished = self.finished or finished
        self._wake()

    def _wake(self) -> None:
        if self._wakeup is not None:
            self._wakeup.set()

    def cancel(self) -> bool:
        return self._frontend.cancel(self.rid)

    # ------------------------------------------------------------- sync
    def __iter__(self) -> "TokenStream":
        return self

    def __next__(self) -> int:
        while True:
            if self._pending:
                return self._pending.popleft()
            if self.finished or self.cancelled:
                raise StopIteration
            # a pump may deliver this stream's last tokens AND leave the
            # front-end idle — re-check the buffer before calling idle
            # starvation
            if not self._frontend.pump() and not self._pending \
                    and not self.finished and not self.cancelled:
                raise RuntimeError(
                    f"stream {self.rid} starved: front-end idle but the "
                    "stream is neither finished nor cancelled")

    # ------------------------------------------------------------ async
    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        while True:
            if self._pending:
                return self._pending.popleft()
            if self.finished or self.cancelled:
                raise StopAsyncIteration
            if self._wakeup is None:
                self._wakeup = asyncio.Event()
            self._wakeup.clear()
            await self._wakeup.wait()


@expose_counters("n_slo_preemptions", "n_cancelled", "n_shed")
class ServeFrontend:
    def __init__(self, backend: ServeBackend, *,
                 tenants: Optional[Dict[str, TenantPolicy]] = None,
                 slo_aware: bool = True,
                 realtime: bool = False,
                 telemetry: Optional[Telemetry] = None):
        self.backend = backend
        self.slo_aware = slo_aware
        self.realtime = realtime
        self._t0 = time.perf_counter()
        self._now = 0.0
        self.policies: Dict[str, TenantPolicy] = {}
        for name, pol in (tenants or {}).items():
            self.set_policy(name, pol)
        # (tenant, slo_class) -> FIFO of queued (undispatched) requests
        self._queues: Dict[Tuple[str, str], deque] = {}
        self._vt: Dict[Tuple[str, str], float] = {}    # WFQ virtual time
        self._vclock: Dict[str, float] = {c: 0.0 for c in SLO_CLASSES}
        self._bucket: Dict[str, float] = {}            # rate-limit credit
        self._bucket_t: Dict[str, float] = {}
        self._streams: Dict[int, TokenStream] = {}     # live streams
        self._inflight: Dict[int, TokenStream] = {}    # dispatched subset
        self._charged: set = set()       # rids already billed (vt + rate)
        self._next_rid = 0
        self._closed = False
        self.completed: List[Request] = []
        # counters live in the backend's shared MetricsRegistry —
        # legacy names (frontend.n_cancelled, ...) are read-only
        # properties via @expose_counters; per-tenant token counts are
        # labelled counters with a dict-compatibility property below.
        # Explicit IS-NOT-None (a Telemetry with tracing off is falsy).
        if telemetry is None:
            telemetry = getattr(backend, "tel", None)
        self.tel = telemetry if telemetry is not None else Telemetry()
        self.uid = next_uid("f")
        self._c = {n: self.tel.registry.counter(
            n, component="frontend", replica=self.uid)
            for n in ("n_slo_preemptions", "n_cancelled", "n_shed")}
        self._tt: Dict[str, Counter] = {}

    @property
    def tenant_tokens(self) -> Dict[str, int]:
        """Confirmed tokens streamed per tenant (compatibility view of
        the registry's labelled ``tenant_tokens`` counters)."""
        return {t: c.value for t, c in self._tt.items()}

    # ------------------------------------------------------------ clock
    @property
    def clock(self) -> float:
        """Current front-end time: wall seconds (realtime) or pump
        steps (deterministic)."""
        return (time.perf_counter() - self._t0 if self.realtime
                else self._now)

    # ---------------------------------------------------------- tenants
    def set_policy(self, tenant: str, policy: TenantPolicy) -> None:
        self.policies[tenant] = policy

    def policy(self, tenant: str) -> TenantPolicy:
        return self.policies.setdefault(tenant, TenantPolicy())

    # ----------------------------------------------------------- submit
    def submit(self, prompt, max_new_tokens: int, *,
               tenant: str = "default", slo_class: str = "batch",
               rid: Optional[int] = None) -> TokenStream:
        """Queue a request; returns its ``TokenStream`` immediately.
        Raises ValueError for a request no backend could ever admit
        (fail fast — the caller's stream would otherwise starve)."""
        if rid is None:
            while self._next_rid in self._streams:
                self._next_rid += 1
            rid = self._next_rid
            self._next_rid += 1
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=int(max_new_tokens),
                      arrival=self.clock, tenant=tenant,
                      slo_class=slo_class)
        return self.submit_request(req)

    def submit_request(self, req: Request) -> TokenStream:
        """Low-level submit of a pre-built ``Request`` (rid must be
        unique among live streams)."""
        if req.slo_class not in SLO_CLASSES:
            raise ValueError(f"unknown slo_class {req.slo_class!r}; "
                             f"choose from {SLO_CLASSES}")
        if req.rid in self._streams:
            raise ValueError(f"rid {req.rid} already has a live stream")
        # graceful degradation: while the backend reports lost
        # capacity, refuse batch-class work at the door with a typed
        # rejection rather than queueing unboundedly — interactive
        # traffic keeps flowing on the survivors (docs/robustness.md)
        if req.slo_class == "batch" \
                and getattr(self.backend, "degraded", False):
            self._c["n_shed"].inc()
            if self.tel:
                self.tel.event(req, "shed", t=self.clock,
                               tenant=req.tenant)
            raise ShedRejection(req)
        self.backend.check_admissible(req)
        self.policy(req.tenant)              # materialize + validate
        stream = TokenStream(self, req)
        self._streams[req.rid] = stream
        self._enqueue(req, front=False)
        if self.tel:
            # the true submission instant — queue delay (admitted - t)
            # includes front-end WFQ/rate-limit/SLO queueing
            self.tel.request_submitted(req, t=self.clock)
        return stream

    def _class_of(self, req: Request) -> str:
        # slo-blind mode files everything as batch: the measured
        # baseline for the SLO benchmark (the request keeps its label)
        return req.slo_class if self.slo_aware else "batch"

    def _enqueue(self, req: Request, front: bool) -> None:
        key = (req.tenant, self._class_of(req))
        q = self._queues.setdefault(key, deque())
        if not q:
            # a tenant idle in this class re-joins at the current
            # virtual clock: idleness earns no credit against
            # continuously-backlogged tenants
            self._vt[key] = max(self._vt.get(key, 0.0),
                                self._vclock[key[1]])
        if front:
            q.appendleft(req)
        else:
            q.append(req)

    # ----------------------------------------------------------- cancel
    def cancel(self, rid: int) -> bool:
        """Drop a live stream mid-flight: remove the request from the
        front-end queue or extract it from the backend (pages freed via
        the preemption machinery; trie donations stay resident for
        future sharers).  True if the rid was live."""
        stream = self._streams.pop(rid, None)
        if stream is None:
            return False
        for q in self._queues.values():
            for i, r in enumerate(q):
                if r.rid == rid:
                    del q[i]
                    break
            else:
                continue
            break
        else:
            self.backend.extract(rid)
        self._inflight.pop(rid, None)
        self._charged.discard(rid)
        stream.cancelled = True
        stream._wake()
        self._c["n_cancelled"].inc()
        if self.tel:
            self.tel.event(stream.req, "cancelled", t=self.clock)
        return True

    # --------------------------------------------------------- dispatch
    @staticmethod
    def _cost(req: Request) -> float:
        return float(len(req.prompt) + req.max_new_tokens)

    def _refill(self, now: float) -> None:
        for tenant, pol in self.policies.items():
            if pol.rate is None:
                continue
            cap = pol.burst if pol.burst is not None else pol.rate
            last = self._bucket_t.get(tenant)
            if last is None:
                self._bucket[tenant] = cap
            else:
                self._bucket[tenant] = min(
                    cap, self._bucket[tenant] + pol.rate * (now - last))
            self._bucket_t[tenant] = now

    def _affordable(self, tenant: str) -> bool:
        pol = self.policies[tenant]
        return pol.rate is None or self._bucket.get(tenant, 0.0) >= 0.0

    def _pick(self, slo: str) -> Optional[Tuple[str, str]]:
        """Lowest-virtual-time backlogged, rate-affordable tenant in
        ``slo``; ties break on tenant name (deterministic)."""
        best = None
        for key, q in self._queues.items():
            if key[1] != slo or not q or not self._affordable(key[0]):
                continue
            if best is None or (self._vt[key], key[0]) < best[0]:
                best = ((self._vt[key], key[0]), key)
        return best[1] if best else None

    def _send(self, key: Tuple[str, str]) -> None:
        tenant, slo = key
        req = self._queues[key].popleft()
        if req.rid not in self._charged:
            # bill once: a request re-queued by SLO preemption was
            # already paid for, so resumption is charge-free
            self._charged.add(req.rid)
            pol = self.policies[tenant]
            self._vclock[slo] = max(self._vclock[slo], self._vt[key])
            self._vt[key] += self._cost(req) / pol.weight
            if pol.rate is not None:
                self._bucket[tenant] = (self._bucket.get(tenant, 0.0)
                                        - self._cost(req))
        self.backend.submit(req)
        self._inflight[req.rid] = self._streams[req.rid]

    def _preempt_victim(self) -> Optional[TokenStream]:
        """Cheapest-to-replay in-flight batch-class stream (fewest
        confirmed tokens; ties on rid for determinism)."""
        victims = [s for s in self._inflight.values()
                   if s.req.slo_class == "batch"]
        if not victims:
            return None
        return min(victims, key=lambda s: (len(s.req.generated), s.rid))

    def _dispatch(self, now: float) -> None:
        while self.backend.n_inflight < self.backend.capacity:
            key = self._pick("interactive") or self._pick("batch")
            if key is None:
                break
            self._send(key)
        if not self.slo_aware:
            return
        # slots exhausted: interactive arrivals evict batch-class work.
        # Each round preempts exactly one victim for one interactive
        # request, so the loop is bounded by the interactive backlog.
        while True:
            key = self._pick("interactive")
            if key is None:
                break
            victim = self._preempt_victim()
            if victim is None:
                break                # everything running is interactive
            extracted = self.backend.extract(victim.rid)
            assert extracted is victim.req, (victim.rid, extracted)
            self._inflight.pop(victim.rid)
            victim.req.n_preemptions += 1
            self._c["n_slo_preemptions"].inc()
            if self.tel:
                self.tel.event(victim.req, "preempted", t=self._now,
                               source="slo",
                               n_generated=len(victim.req.generated))
            self._enqueue(victim.req, front=True)
            self._send(key)

    # ------------------------------------------------------------- pump
    def pump(self, now: Optional[float] = None) -> bool:
        """One front-end iteration: advance the clock, refill rate
        buckets, dispatch (WFQ + SLO preemption), run one backend step,
        route confirmed-token events to their streams.  Returns True
        while anything is queued or in flight."""
        if now is None:
            now = (time.perf_counter() - self._t0 if self.realtime
                   else self._now + 1.0)
        self._now = max(self._now, float(now))
        self._refill(self._now)
        self._dispatch(self._now)
        if self.backend.n_inflight:
            self.backend.step(self._now)
            for ev in self.backend.drain_events():
                self._route(ev)
        return self.busy

    def _route(self, ev: StreamEvent) -> None:
        stream = self._streams.get(ev.rid)
        if stream is None:
            return                   # submitted around the front-end
        if ev.tokens:
            t = stream.req.tenant
            c = self._tt.get(t)
            if c is None:
                c = self._tt[t] = self.tel.registry.counter(
                    "tenant_tokens", component="frontend",
                    replica=self.uid, tenant=t)
            c.inc(len(ev.tokens))
        stream._push(ev.tokens, ev.finished)
        if ev.finished:
            self._streams.pop(ev.rid, None)
            self._inflight.pop(ev.rid, None)
            self._charged.discard(ev.rid)
            self.completed.append(stream.req)

    @property
    def busy(self) -> bool:
        return bool(self._inflight or self.backend.n_inflight
                    or any(self._queues.values()))

    def drain(self) -> None:
        """Pump until idle (sync convenience; streams buffer)."""
        while self.pump():
            pass

    # ------------------------------------------------------------ async
    async def serve(self, idle_wait: float = 0.001):
        """Pump task for asyncio consumers: run until ``close()``.
        Backend steps execute inline (they hold the loop while a
        program runs — per-step granularity is the design point), and
        idle polls sleep so submitters can run."""
        while not self._closed:
            if not self.pump():
                await asyncio.sleep(idle_wait)
            else:
                await asyncio.sleep(0)

    def close(self) -> None:
        self._closed = True

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        """Front-end counters (backend counters via
        ``backend.stats()``)."""
        return {
            "n_queued": float(sum(len(q) for q in self._queues.values())),
            "n_inflight": float(len(self._inflight)),
            "n_completed": float(len(self.completed)),
            "n_cancelled": float(self.n_cancelled),
            "n_slo_preemptions": float(self.n_slo_preemptions),
            "n_shed": float(self.n_shed),
            **{f"tenant_tokens[{t}]": float(n)
               for t, n in sorted(self.tenant_tokens.items())},
        }
