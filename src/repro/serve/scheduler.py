"""Continuous-batching request scheduler over the paged KV cache.

One jit'd paged-decode program (fixed batch/page shapes) serves an
ever-changing population of requests: the engine admits waiting
requests into free batch slots as pages allow, runs prefill for the
newcomer while in-flight requests keep decoding on the next step, and
evicts (preempts) the youngest request when the allocator runs dry —
its pages are freed and it re-queues for recompute-readmission, so the
engine never deadlocks and older requests always finish.

This is latency-bounded batching in the TPU-serving sense: decode
throughput comes from keeping the batch full, and the paged cache is
what keeps admission cheap enough to do that mid-flight.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import numpy as np

from .kv_cache import PagedKVCache
from .step import make_paged_decode_step, make_prefill_step

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    arrival: float = 0.0
    # engine-filled
    generated: List[int] = dataclasses.field(default_factory=list)
    ttft: Optional[float] = None          # first token latency (s)
    finish_time: Optional[float] = None
    n_preemptions: int = 0

    @property
    def finished(self) -> bool:
        return self.finish_time is not None


class ServeEngine:
    def __init__(self, model, params, *, max_batch: int = 8,
                 n_pages: int = 128, page_size: int = 16,
                 max_pages_per_seq: Optional[int] = None,
                 eos_id: Optional[int] = None):
        if not model.supports_paged_decode():
            raise ValueError(f"{model.cfg.name}: paged decode unsupported "
                             "(needs a scanned all-attention stack)")
        if max_pages_per_seq is None:
            # correct for any admissible request; size it from the
            # trace (kv_cache.pages_needed) when the wider page tables
            # cost too much gather bandwidth
            max_pages_per_seq = n_pages - 1
        self.model, self.params = model, params
        self.eos_id = eos_id
        self.cache = PagedKVCache(model, max_batch=max_batch,
                                  n_pages=n_pages, page_size=page_size,
                                  max_pages_per_seq=max_pages_per_seq)
        self.max_batch = max_batch
        self._decode = jax.jit(make_paged_decode_step(model))
        self._prefill = jax.jit(make_prefill_step(model))
        self.waiting: deque[Request] = deque()
        self.active: Dict[int, Request] = {}      # slot -> request
        self._admit_seq: Dict[int, int] = {}      # slot -> admission order
        self._admit_counter = 0
        self.finished: List[Request] = []
        self.n_decode_steps = 0
        self.n_prefills = 0
        self.n_replay_steps = 0

    # --------------------------------------------------------- frontend
    def submit(self, req: Request) -> None:
        """Queue a request; rejects (ValueError) one that could never
        be admitted — otherwise the engine would spin on it forever.
        The budget reserves can_admit's +1 decode-headroom page (a
        preempted request must be re-admittable at its longest)."""
        need = self.cache.pages_for(len(req.prompt) + req.max_new_tokens)
        budget = min(self.cache.max_pages_per_seq, self.cache.n_pages - 2)
        if need > budget:
            raise ValueError(
                f"request {req.rid}: {len(req.prompt)}+{req.max_new_tokens}"
                f" tokens need {need} pages of {self.cache.page_size};"
                f" per-request page budget is {budget}")
        self.waiting.append(req)

    @property
    def n_inflight(self) -> int:
        return len(self.waiting) + len(self.active)

    # --------------------------------------------------------- internals
    def _free_slot_id(self) -> Optional[int]:
        for s in range(self.max_batch):
            if s not in self.active:
                return s
        return None

    def _finish(self, slot: int, now: float) -> None:
        req = self.active.pop(slot)
        self._admit_seq.pop(slot)
        self.cache.free_slot(slot)
        req.finish_time = now
        self.finished.append(req)

    def _preempt_youngest(self, now: float) -> Optional[int]:
        """Evict the most recently admitted request: free its pages and
        push it to the front of the queue for recompute-readmission."""
        if not self.active:
            return None
        slot = max(self._admit_seq, key=self._admit_seq.get)
        req = self.active.pop(slot)
        self._admit_seq.pop(slot)
        self.cache.free_slot(slot)
        req.n_preemptions += 1
        self.waiting.appendleft(req)
        return slot

    def _admit_one(self, now: float) -> bool:
        if not self.waiting or self.waiting[0].arrival > now:
            return False
        slot = self._free_slot_id()
        if slot is None:
            return False
        req = self.waiting[0]
        if not self.cache.can_admit(len(req.prompt) + len(req.generated)):
            return False
        self.waiting.popleft()
        if not self.cache.alloc_slot(slot, len(req.prompt)):
            raise RuntimeError("allocation failed after can_admit")
        # prefill interleaves with in-flight decode at step granularity
        last, kv = self._prefill(self.params,
                                 {"tokens": req.prompt[None]})
        self.cache.write_prefill(slot, kv["layers"]["kv"])
        self.n_prefills += 1
        if req.generated:
            # recompute-readmission after preemption: replay the
            # already-generated tokens through the *same* decode
            # program, reproducing the original token stream exactly
            # (re-prefilling prompt+generated instead would cross the
            # chunked-prefill/step-decode numerics boundary and can
            # flip near-tie argmaxes)
            self._replay(slot, req.generated[:-1])
        else:
            tok = int(np.argmax(np.asarray(last[0])))
            req.generated.append(tok)
        if req.ttft is None:
            req.ttft = now - req.arrival
        self.active[slot] = req
        self._admit_seq[slot] = self._admit_counter
        self._admit_counter += 1
        if self._done(req):
            self._finish(slot, now)
        return True

    def _replay(self, slot: int, tokens) -> None:
        """Write ``tokens`` into ``slot``'s pages via single-slot decode
        steps (all other rows masked to the null page)."""
        for t in tokens:
            if not self.cache.ensure_headroom(slot):
                raise RuntimeError(
                    "replay allocation failed despite admission reserve")
            toks = np.zeros((self.max_batch, 1), np.int32)
            toks[slot, 0] = t
            tables = np.zeros_like(self.cache.page_tables)
            tables[slot] = self.cache.page_tables[slot]
            lengths = np.zeros_like(self.cache.lengths)
            lengths[slot] = self.cache.lengths[slot]
            state = {"k_pages": self.cache.k_pages,
                     "v_pages": self.cache.v_pages,
                     "page_tables": jax.numpy.asarray(tables),
                     "lengths": jax.numpy.asarray(lengths)}
            _, state = self._decode(self.params, state,
                                    jax.numpy.asarray(toks))
            self.cache.k_pages = state["k_pages"]
            self.cache.v_pages = state["v_pages"]
            self.cache.lengths[slot] += 1
            self.n_replay_steps += 1

    def _done(self, req: Request) -> bool:
        return (len(req.generated) >= req.max_new_tokens
                or (self.eos_id is not None
                    and req.generated[-1] == self.eos_id))

    # ------------------------------------------------------------- step
    def step(self, now: float = float("inf")) -> bool:
        """One engine iteration: admit what fits, then one batched
        decode step over every active slot.  Returns True while any
        work remains (queued or in flight)."""
        while self._admit_one(now):
            pass
        if not self.active:
            return bool(self.waiting)

        # page headroom for this step's token writes; evict on pressure
        for slot in sorted(self.active):
            while slot in self.active and \
                    not self.cache.ensure_headroom(slot):
                victim = self._preempt_youngest(now)
                if victim is None or not self.active:
                    raise RuntimeError(
                        "single request exceeds total page budget")

        if not self.active:          # pressure evicted everyone
            return bool(self.waiting)

        tokens = np.zeros((self.max_batch, 1), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.generated[-1]
        tables, lengths = self.cache.device_tables()
        state = {"k_pages": self.cache.k_pages,
                 "v_pages": self.cache.v_pages,
                 "page_tables": tables, "lengths": lengths}
        nxt, state = self._decode(self.params, state,
                                  jax.numpy.asarray(tokens))
        self.cache.k_pages = state["k_pages"]
        self.cache.v_pages = state["v_pages"]
        self.n_decode_steps += 1
        nxt = np.asarray(nxt)
        for slot in list(self.active):
            req = self.active[slot]
            req.generated.append(int(nxt[slot, 0]))
            self.cache.lengths[slot] += 1
            if self._done(req):
                self._finish(slot, now)
        return bool(self.active or self.waiting)

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request], *,
            realtime: bool = False) -> List[Request]:
        """Drive to completion; returns the requests completed by THIS
        call (the engine is reusable — e.g. a warmup run then a
        measured run).  ``realtime=False`` ignores arrival times (admit
        ASAP — tests / max-throughput); ``realtime=True`` replays them
        against the wall clock (benchmarks / TTFT)."""
        first = len(self.finished)
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while True:
            now = (time.perf_counter() - t0) if realtime else float("inf")
            if not self.step(now=now):
                break
            if realtime and not self.active and self.waiting:
                time.sleep(max(0.0,
                               self.waiting[0].arrival
                               - (time.perf_counter() - t0)))
        return self.finished[first:]
