"""Continuous-batching request scheduler over the paged KV cache.

One jit'd paged-decode program (fixed batch/page shapes) serves an
ever-changing population of requests.  The request lifecycle is

    submit -> WAITING -> [admit] -> PREFILLING -> DECODING -> finished
                  ^                                ^  |
                  |                     (verify    |  |
                  |                      round) VERIFYING
                  +--------- preempt (replay) --------+

* **Admission** claims a batch slot and pages; a prompt prefix already
  resident in the cache's prefix trie is attached read-only
  (copy-on-write protects it) and skipped by prefill.
* **Batched chunked prefill**: prompts ingest through a fixed-shape
  ``(prefill_batch, chunk_size)`` masked-prefill program — up to
  ``prefill_batch`` PREFILLING requests advance one chunk each *per
  dispatch* (per-row page tables / starts / valid counts; inactive
  rows routed to the null page), so a burst of short prompts pays one
  program launch instead of one per prompt.  PREFILLING is a set
  drained together, not a serialized queue; prompts longer than one
  chunk advance one chunk per engine step, interleaved with the
  batched decode step — in-flight decode never stalls for more than
  one chunk of prefill work — while short prompts admit, ingest, and
  promote eagerly so the batch ramps at full speed.  One admission
  ordering rule survives from the serialized path: a prompt that could
  share prefix pages with a prompt still mid-ingest waits for that
  prompt's trie registration (``_defers_for_sharing``) — co-ingesting
  it would silently forfeit the donation, and with it the in-burst
  sharing the serialized path guaranteed.  The program's gathered
  context length is bucketed (``bucket_edges``, in pages) so each
  bucket jit-compiles once instead of once per distinct prompt length.
* **Preemption**: when the allocator runs dry the engine first evicts
  LRU prefix-trie pages, then the youngest request — its pages are
  dropped and it re-queues for recompute-readmission (its own prompt
  usually re-shares from the trie), and its already-generated tokens
  are replayed through the same decode program, reproducing the
  original stream exactly.  The engine never deadlocks and older
  requests always finish.
* **Speculative decode** (``spec_k`` > 0): instead of one token per
  batched decode step, every DECODING slot enters a VERIFYING round —
  a drafter (serve/spec.py) guesses up to ``spec_k`` tokens, the
  target model scores all ``k+1`` positions in one batched
  ``verify_step_paged`` program, and the longest matching draft prefix
  plus the verifier's bonus token are banked.  Rows with no draft
  degrade to exactly a decode step, so the verify program *replaces*
  the decode program rather than running beside it.  Headroom for the
  whole write window is privatized before the program runs and pages
  past the confirmed frontier are rolled back after it
  (kv_cache.ensure_headroom / rollback_spec), so speculation composes
  with chunked prefill, prefix sharing/COW, and preemption without new
  aliasing states.
* **Fused steady-state step** (``fused=True``, the default): a step
  with both PREFILLING and DECODING work launches ONE uber-program
  (``models/lm.fused_step_paged``) covering the chunk ingestion *and*
  the decode/verify round, instead of two back-to-back dispatches.
  Page write/read disjointness (prefill rows touch only their own
  private pages, decode rows only headroom-privatized ones) makes the
  merge bitwise; rows promoted out of a fused dispatch join the decode
  batch on the *next* step, which shifts step boundaries but never
  token values.  Degenerate mixes — prefill-only ramp, decode-only
  tail — take the standalone programs either way, so ``fused=False``
  reproduces the two-dispatch engine dispatch-for-dispatch.

Every step keeps the token-parity guarantee: generated streams are
bit-identical to the sequential ``greedy_generate`` oracle, with or
without speculation (see docs/serving.md and docs/speculative.md for
what would break it).

The engine is one implementation of the ``ServeBackend`` protocol
(serve/backend.py); the multi-replica router is the other.  Streaming
callers consume per-step confirmed-token events (``drain_events``) and
may ``extract``/``cancel`` a request mid-stream — both ride the
preempt/free machinery above, so they compose with everything else.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from .backend import StreamEvent
from .kv_cache import PagedKVCache
from .spec import PromptLookupDrafter
from .step import ServePrograms
from .telemetry import (SpanEvent, Telemetry, expose_counters,
                        merge_stats, next_uid)

__all__ = ["Request", "ServeEngine", "SLO_CLASSES", "default_bucket_edges"]

SLO_CLASSES = ("interactive", "batch")


def default_bucket_edges(max_pages_per_seq: int) -> List[int]:
    """Doubling context buckets (in pages): 1, 2, 4, ... capped at the
    per-request page budget — one chunked-prefill compile per edge."""
    edges, e = [], 1
    while e < max_pages_per_seq:
        edges.append(e)
        e *= 2
    edges.append(max_pages_per_seq)
    return edges


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    arrival: float = 0.0
    # multi-tenant front-end metadata (serve/frontend.py); the engine
    # itself is policy-free and never reads these — defaults keep every
    # pre-front-end call site constructing unchanged
    tenant: str = "default"
    slo_class: str = "batch"              # "interactive" | "batch"
    # engine-filled
    generated: List[int] = dataclasses.field(default_factory=list)
    ttft: Optional[float] = None          # first token latency (s)
    finish_time: Optional[float] = None
    n_preemptions: int = 0
    prefill_pos: int = 0                  # prompt tokens ingested
    shared_tokens: int = 0                # prefix-cache hit size
    # lifecycle span (serve/telemetry.py) — empty unless the serving
    # stack was built with tracing on; survives migration because the
    # events ride the Request object itself
    trace: List[SpanEvent] = dataclasses.field(default_factory=list)

    @property
    def finished(self) -> bool:
        return self.finish_time is not None


_ENGINE_COUNTERS = (
    "n_engine_steps", "n_decode_steps", "n_prefill_chunks",
    "n_prefill_dispatches", "n_replay_steps", "n_fused_dispatches",
    "n_total_dispatches", "n_spec_rounds", "n_drafted",
    "n_draft_accepted")


@expose_counters(*_ENGINE_COUNTERS)
class ServeEngine:
    def __init__(self, model, params, *, max_batch: int = 8,
                 n_pages: int = 128, page_size: int = 16,
                 max_pages_per_seq: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 chunk_size: int = 32,
                 prefill_batch: int = 1,
                 prefix_sharing: bool = True,
                 bucket_edges: Optional[Sequence[int]] = None,
                 spec_k: int = 0,
                 drafter=None,
                 fused: bool = True,
                 programs: Optional[ServePrograms] = None,
                 tp: int = 1,
                 mesh=None,
                 telemetry: Optional[Telemetry] = None):
        if not model.supports_paged_decode():
            raise ValueError(f"{model.cfg.name}: paged decode unsupported "
                             "(needs a scanned all-attention stack)")
        if max_pages_per_seq is None:
            # correct for any admissible request; size it from the
            # trace (kv_cache.pages_needed) when the wider page tables
            # cost too much gather bandwidth
            max_pages_per_seq = n_pages - 1
        # the serving programs are engine-independent (one compile
        # cache shared by every replica built on the same bundle);
        # tp > 1 / mesh swaps in the shard_map'd tensor-parallel
        # bundle — the scheduler below cannot tell the difference
        if programs is None:
            if tp > 1 or mesh is not None:
                from .parallel import TPServePrograms
                programs = TPServePrograms(model, tp=tp, mesh=mesh)
            else:
                programs = ServePrograms(model)
        elif programs.model is not model:
            raise ValueError("programs were built for a different model")
        self.programs = programs
        self.tp = programs.tp
        self.model = model
        self.params = programs.prepare_params(params)
        self.eos_id = eos_id
        self.cache = PagedKVCache(model, max_batch=max_batch,
                                  n_pages=n_pages, page_size=page_size,
                                  max_pages_per_seq=max_pages_per_seq,
                                  prefix_sharing=prefix_sharing)
        self.cache.k_pages = programs.prepare_pages(self.cache.k_pages)
        self.cache.v_pages = programs.prepare_pages(self.cache.v_pages)
        self.max_batch = max_batch
        self.chunk_size = chunk_size
        # rows per chunked-prefill dispatch (the program's batch dim).
        # 1 reproduces the PR 2 serialized path dispatch-for-dispatch;
        # > 1 co-ingests a burst.  Token streams are bitwise identical
        # either way (see _dispatch_prefill).
        self.prefill_batch = max(1, min(int(prefill_batch), max_batch))
        if bucket_edges is None:
            bucket_edges = default_bucket_edges(max_pages_per_seq)
        self.bucket_edges = sorted(set(int(b) for b in bucket_edges))
        if self.bucket_edges[-1] < max_pages_per_seq:
            self.bucket_edges.append(max_pages_per_seq)
        self._decode = programs.decode
        # one jit wrapper; re-specializes per (bucket) table shape
        self._chunk = programs.chunk
        # speculative decode: drafts are advisory, the verify program
        # replaces the decode program for DECODING slots (spec_k == 0
        # keeps the plain one-token decode path)
        self.spec_k = int(spec_k)
        if self.spec_k > 0:
            self.drafter = drafter or PromptLookupDrafter()
            self._verify = programs.verify
        else:
            self.drafter = None
            self._verify = None
        # fused uber-program: steady-state steps with both PREFILLING
        # and DECODING work launch ONE program instead of two
        # (programs.fused is built lazily, so --no-fused engines never
        # trace it).  Degenerate mixes — prefill-only ramp, decode-only
        # tail — take the standalone programs either way, so fusion off
        # reproduces the unfused engine dispatch-for-dispatch.
        self.fused = bool(fused)
        self.waiting: deque[Request] = deque()
        self.prefilling: "OrderedDict[int, Request]" = OrderedDict()
        self.active: Dict[int, Request] = {}      # slot -> DECODING req
        # confirmed-token events since the last drain (streaming face;
        # see backend.StreamEvent).  run() clears them — the batch
        # driver's callers read finished Requests instead.
        self.events: deque[StreamEvent] = deque()
        self._admit_seq: Dict[int, int] = {}      # slot -> admission order
        self._admit_counter = 0
        self.finished: List[Request] = []
        # counters live in the shared MetricsRegistry (telemetry.py);
        # the legacy attribute names (engine.n_decode_steps, ...) are
        # read-only properties over them via @expose_counters, so every
        # existing consumer keeps working.  Of note:
        # * n_engine_steps — step() calls that found work;
        # * n_prefill_chunks / n_prefill_dispatches — per-row chunks
        #   ingested vs prefill program launches;
        # * dispatch accounting — n_total_dispatches counts EVERY
        #   program launch (prefill, decode/verify, replay, fused); a
        #   fused launch also increments the prefill + decode counters
        #   it subsumes, so fused-off arithmetic (total = prefill +
        #   decode + replay) loses exactly n_fused_dispatches when
        #   fusion is on — the identity MetricsRegistry.audit rechecks;
        # * speculation — accept rate = n_draft_accepted / n_drafted.
        self.tel = telemetry if telemetry is not None else Telemetry()
        self.uid = next_uid("e")
        self._c = {n: self.tel.registry.counter(
            n, component="engine", replica=self.uid)
            for n in _ENGINE_COUNTERS}
        self._now = 0.0              # last sanitized step clock
        self._last_decode_rows = 0   # rows in the last decode round

    # --------------------------------------------------------- frontend
    def check_admissible(self, req: Request) -> None:
        """Raise ValueError for a request this engine could never admit
        — otherwise it would spin on it forever.  The budget reserves
        alloc_slot's +1 decode-headroom page (a preempted request must
        be re-admittable at its longest).  Exposed separately from
        ``submit`` so a front-end (serve/router.py) can fail fast
        before choosing a replica."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt (there is "
                             "no last-token logit to seed generation)")
        need = self.cache.pages_for(len(req.prompt) + req.max_new_tokens)
        budget = min(self.cache.max_pages_per_seq, self.cache.n_pages - 2)
        if need > budget:
            raise ValueError(
                f"request {req.rid}: {len(req.prompt)}+{req.max_new_tokens}"
                f" tokens need {need} pages of {self.cache.page_size};"
                f" per-request page budget is {budget}")

    def submit(self, req: Request) -> None:
        """Queue a request (see ``check_admissible`` for rejection)."""
        self.check_admissible(req)
        self.waiting.append(req)
        if self.tel:
            self.tel.request_submitted(req, t=req.arrival)

    @property
    def n_inflight(self) -> int:
        return len(self.waiting) + len(self.prefilling) + len(self.active)

    @property
    def capacity(self) -> int:
        """Requests this backend can serve concurrently (batch slots).
        A front-end that keeps ``n_inflight < capacity`` retains all
        queueing policy itself."""
        return self.max_batch

    def drain_events(self) -> List[StreamEvent]:
        """Return (and clear) the confirmed-token events accumulated
        since the last drain, in confirmation order."""
        ev = list(self.events)
        self.events.clear()
        return ev

    def _emit(self, req: Request, tokens) -> None:
        if tokens or req.finished:
            self.events.append(StreamEvent(req.rid, tuple(tokens),
                                           req.finished))

    def extract(self, rid: int) -> Optional[Request]:
        """Remove the request wherever it lives — queued, prefilling or
        decoding — freeing its slot and pages through the same path
        preemption uses, and return it with confirmed tokens intact.
        Re-submitting the returned request later resumes its stream
        token-exactly (recompute-replay), so a front-end can preempt a
        batch-class request for an interactive one without correctness
        risk.  Returns None if the rid is not here (finished requests
        are not extractable — their stream is complete)."""
        for i, r in enumerate(self.waiting):
            if r.rid == rid:
                del self.waiting[i]
                return r
        for slot, r in list(self.prefilling.items()) \
                + list(self.active.items()):
            if r.rid == rid:
                return self._evict_slot(slot)
        return None

    def extract_all(self) -> List[Request]:
        """Remove EVERY live request — admitted slots in admission
        order, then the waiting queue — freeing all slots and pages;
        the bulk form of ``extract``, used by a draining replica to
        hand its whole population to another backend.  Each returned
        request carries its confirmed tokens; re-submission elsewhere
        resumes each stream token-exactly, and pages the prompts
        donated to this engine's trie stay resident until the engine
        itself is retired."""
        out: List[Request] = []
        for slot in sorted(self._admit_seq, key=self._admit_seq.get):
            out.append(self._evict_slot(slot))
        out.extend(self.waiting)
        self.waiting.clear()
        return out

    def cancel(self, rid: int) -> bool:
        """Drop a request mid-stream: extract-and-discard.  Pages the
        request privately held return to the free list; pages its
        prompt donated to the prefix trie stay resident (a
        cancel-then-resubmit re-shares them).  Tokens already streamed
        were confirmed and stay valid.  True if the rid was live."""
        req = self.extract(rid)
        if req is not None and self.tel:
            self.tel.event(req, "cancelled", t=self._now)
        return req is not None

    # --------------------------------------------------------- internals
    def _free_slot_id(self) -> Optional[int]:
        for s in range(self.max_batch):
            if s not in self.active and s not in self.prefilling:
                return s
        return None

    def _finish(self, slot: int, now: float) -> None:
        req = self.active.pop(slot)
        self._admit_seq.pop(slot)
        self.cache.free_slot(slot)
        if self.drafter is not None:
            self.drafter.detach(slot)
        req.finish_time = now
        self.finished.append(req)
        if self.tel:
            self.tel.event(req, "finished", t=self._now,
                           n_generated=len(req.generated))

    def _evict_slot(self, slot: int) -> Request:
        """Release ``slot`` (prefilling or decoding): drop its page
        references, detach drafter state, reset ingestion progress.
        The request's confirmed tokens survive — re-admission replays
        them, reproducing the stream exactly.  Shared by preemption,
        ``extract`` and ``cancel``."""
        req = (self.prefilling.pop(slot, None)
               or self.active.pop(slot, None))
        self._admit_seq.pop(slot)
        self.cache.free_slot(slot)
        if self.drafter is not None:
            self.drafter.detach(slot)       # draft state is disposable
        req.prefill_pos = 0
        return req

    def _preempt_youngest(self, now: float,
                          exclude: Optional[int] = None) -> Optional[int]:
        """Evict the most recently admitted request (prefilling or
        decoding): drop its page references and push it to the front of
        the queue for recompute-readmission.  ``exclude`` protects one
        slot (the one being replayed) from evicting itself."""
        candidates = [s for s in self._admit_seq if s != exclude]
        if not candidates:
            return None
        slot = max(candidates, key=self._admit_seq.get)
        req = self._evict_slot(slot)
        req.n_preemptions += 1
        self.waiting.appendleft(req)
        if self.tel:
            self.tel.event(req, "preempted", t=self._now,
                           replica=self.uid, source="pages",
                           n_generated=len(req.generated))
        return slot

    def _defers_for_sharing(self, req: Request) -> bool:
        """True when ``req`` should wait for an in-flight prefill's trie
        registration instead of co-ingesting beside it — the
        admission-order prefix-registration invariant of the serialized
        path: each prompt donates its pages before the next admission's
        trie lookup, so a burst of same-system-prompt requests shares
        all but the first.  Co-ingesting a would-be sharer forfeits
        that donation.  Deferral holds only while the donor is
        PREFILLING (promotion registers, preemption re-queues), so it
        can never deadlock; and only when registration would serve
        strictly more than the trie already can (a read-only probe —
        observation must not protect pages from eviction)."""
        trie = self.cache.prefix
        if trie is None:
            return False
        prompt = req.prompt
        cap = len(prompt) - 1
        resident = min(trie.probe(prompt), cap)
        for other in self.prefilling.values():
            o = other.prompt
            m = min(len(prompt), len(o))
            neq = np.nonzero(prompt[:m] != o[:m])[0]
            lcp = int(neq[0]) if len(neq) else m
            if min(trie.servable_after_insert(lcp), cap) > resident:
                return True
        return False

    def _admit_burst(self, now: float) -> bool:
        """Admit arrived requests (FIFO) until the PREFILLING set holds
        ``prefill_batch`` rows, slots/pages run out, or the head of the
        queue must wait for an in-flight prompt's prefix registration.
        With ``prefill_batch == 1`` this degenerates to the serialized
        path's gate: admit only when no prefill is in flight."""
        admitted = False
        while (len(self.prefilling) < self.prefill_batch
               and self.waiting and self.waiting[0].arrival <= now):
            if self.prefilling and self._defers_for_sharing(self.waiting[0]):
                break
            if not self._admit_one():
                break
            admitted = True
        return admitted

    def _admit_one(self) -> bool:
        """Admit ``waiting[0]`` (caller checked arrival) into a free
        slot; all-or-nothing on pages."""
        slot = self._free_slot_id()
        if slot is None:
            return False
        req = self.waiting[0]
        shared = self.cache.alloc_slot(
            slot, len(req.prompt), prompt=req.prompt,
            reserve_tokens=len(req.generated))
        if shared is None:
            # make room from the prefix cache before giving up: release
            # up to the request's worst-case bill at once (a page per
            # node dribble would stall admission for many steps)
            need = self.cache.pages_for(
                len(req.prompt) + len(req.generated)) + 2
            if not self.cache.release_prefix_pages(need):
                return False
            shared = self.cache.alloc_slot(
                slot, len(req.prompt), prompt=req.prompt,
                reserve_tokens=len(req.generated))
            if shared is None:
                return False
        self.waiting.popleft()
        req.prefill_pos = shared
        req.shared_tokens = shared
        self.prefilling[slot] = req
        self._admit_seq[slot] = self._admit_counter
        self._admit_counter += 1
        if self.tel:
            self.tel.event(req, "admitted", t=self._now,
                           replica=self.uid, slot=slot,
                           shared_tokens=shared)
        return True

    def _bucket_pages(self, n_needed: int) -> int:
        for e in self.bucket_edges:
            if e >= n_needed:
                return e
        return self.bucket_edges[-1]

    def _run_prefill(self, now: float) -> None:
        """Advance every PREFILLING request one chunk in ONE program
        dispatch — the drained-set replacement for the serialized
        one-request chunk loop.  ``_admit_burst`` (the set's only
        producer) caps it at ``prefill_batch`` rows, so the whole set
        always fits one dispatch; dict insertion order is admission
        order (re-admissions insert fresh)."""
        self._dispatch_prefill(list(self.prefilling.items()), now)

    def _prefill_inputs(self, group):
        """Build one batched chunked-prefill dispatch's input arrays for
        ``group`` = [(slot, req), ...]: fixed-shape (Bp, C) tokens plus
        per-row starts / valid counts / bucketed page-table rows.
        Exactness: every row is exactly what the serialized path would
        have dispatched alone — same tokens, start, valid count, and
        page-table prefix (the shared context bucket only pads the
        gathered buffer with fully-masked lanes, exact no-ops) — and
        the program is row-independent, so each request's stream is
        bitwise identical to serialized ingestion regardless of
        co-tenants.  Returns (tokens, tables, starts, valids, metas)
        with metas = [(row, slot, req, valid), ...]."""
        Bp, Csz = self.prefill_batch, self.chunk_size
        assert len(group) <= Bp, (len(group), Bp)
        tokens = np.zeros((Bp, Csz), np.int32)
        starts = np.zeros((Bp,), np.int32)
        valids = np.zeros((Bp,), np.int32)
        metas, buckets, nb = [], [], 1
        for r, (slot, req) in enumerate(group):
            start = req.prefill_pos
            valid = min(Csz, len(req.prompt) - start)
            tokens[r, :valid] = req.prompt[start:start + valid]
            starts[r] = start
            valids[r] = valid
            own = self._bucket_pages(self.cache.pages_for(start + valid))
            nb = max(nb, own)
            buckets.append(own)
            metas.append((r, slot, req, valid))
        # inactive rows (group smaller than Bp) keep all-zero tables:
        # their writes land on the null page
        tables = np.zeros((Bp, nb), np.int32)
        for (r, slot, req, valid), own in zip(metas, buckets):
            tables[r, :own] = self.cache.page_tables[slot, :own]
        return tokens, tables, starts, valids, metas

    def _dispatch_prefill(self, group, now: float) -> None:
        """Ingest one chunk for each (slot, req) in ``group`` in ONE
        batched program dispatch; promote rows whose chunk completes
        their prompt (_prefill_inputs / _finish_prefill carry the
        exactness argument)."""
        tokens, tables, starts, valids, metas = \
            self._prefill_inputs(group)
        state = {"k_pages": self.cache.k_pages,
                 "v_pages": self.cache.v_pages}
        tok, state = self._chunk(self.params, state,
                                 jax.numpy.asarray(tokens),
                                 jax.numpy.asarray(tables),
                                 jax.numpy.asarray(starts),
                                 jax.numpy.asarray(valids))
        self.cache.k_pages = state["k_pages"]
        self.cache.v_pages = state["v_pages"]
        self._c["n_prefill_dispatches"].inc()
        self._c["n_prefill_chunks"].inc(len(metas))
        self._c["n_total_dispatches"].inc()
        self._finish_prefill(metas, np.asarray(tok), now)

    def _finish_prefill(self, metas, tok, now: float) -> None:
        """Advance and promote the rows of a completed prefill dispatch
        (``tok``: the dispatch's (Bp, 1) next-token output, host-side).
        """
        # advance every row before any promotion: promotion may replay,
        # replay may preempt — and preemption resets the victim's
        # prefill_pos, which must already reflect this dispatch
        for _, slot, req, valid in metas:
            req.prefill_pos += valid
            self.cache.lengths[slot] = req.prefill_pos
            if self.tel:
                self.tel.event(req, "chunk_prefilled", t=self._now,
                               replica=self.uid, n_tokens=int(valid),
                               pos=req.prefill_pos)
        for r, slot, req, valid in metas:
            if slot not in self.prefilling \
                    or self.prefilling[slot] is not req:
                continue                 # preempted by an earlier
            if req.prefill_pos < len(req.prompt):
                continue                 # row's replay making room
            # prompt fully resident: donate it to the prefix trie, then
            # promote (replaying any pre-preemption generation).
            # Registration runs in admission order, and co-ingested
            # rows were admitted precisely because none could use
            # another's donation (_defers_for_sharing), so the
            # serialized path's registration-before-next-admission
            # sharing guarantee carries over.
            self.prefilling.pop(slot)
            self.cache.register_prefix(slot, req.prompt)
            self.active[slot] = req
            first_token = not req.generated
            if req.generated:
                # recompute-readmission after preemption: replay the
                # already-generated tokens through the *same* decode
                # program, reproducing the original token stream
                # exactly (re-prefilling prompt+generated instead would
                # cross the prompt/generation numerics boundary of the
                # oracle)
                self._replay(slot, req.generated[:-1], now)
                if self.tel:
                    self.tel.event(req, "replayed", t=self._now,
                                   replica=self.uid,
                                   n=len(req.generated) - 1)
            else:
                req.generated.append(int(tok[r, 0]))
            if self.tel:
                # a fresh first token is a new confirmation (n=1);
                # re-promotion after preemption confirms nothing new
                self.tel.event(req, "promoted", t=self._now,
                               replica=self.uid,
                               n=int(first_token))
            if req.ttft is None:
                req.ttft = now - req.arrival
                if req.ttft != float("inf"):
                    self.tel.registry.histogram(
                        "ttft", tenant=req.tenant,
                        slo=req.slo_class).observe(req.ttft)
            if self._done(req):
                self._finish(slot, now)
            # replay re-derives KV for tokens streamed before a
            # preemption; only a fresh first token is a new confirmation
            self._emit(req, req.generated[-1:] if first_token else [])

    def _replay(self, slot: int, tokens, now: float) -> None:
        """Write ``tokens`` into ``slot``'s pages via single-slot decode
        steps (all other rows masked to the null page).  The admission
        reserve is not pinned across the chunked-prefill window (other
        slots' decode growth can consume it), so replay makes room the
        same way the decode loop does — never by evicting itself."""
        for t in tokens:
            while not self.cache.ensure_headroom(slot):
                if not self._make_room(now, exclude=slot):
                    raise RuntimeError(
                        "single request exceeds total page budget")
            toks = np.zeros((self.max_batch, 1), np.int32)
            toks[slot, 0] = t
            tables = np.zeros_like(self.cache.page_tables)
            tables[slot] = self.cache.page_tables[slot]
            lengths = np.zeros_like(self.cache.lengths)
            lengths[slot] = self.cache.lengths[slot]
            state = {"k_pages": self.cache.k_pages,
                     "v_pages": self.cache.v_pages,
                     "page_tables": jax.numpy.asarray(tables),
                     "lengths": jax.numpy.asarray(lengths)}
            _, state = self._decode(self.params, state,
                                    jax.numpy.asarray(toks))
            self.cache.k_pages = state["k_pages"]
            self.cache.v_pages = state["v_pages"]
            self.cache.lengths[slot] += 1
            self._c["n_replay_steps"].inc()
            self._c["n_total_dispatches"].inc()

    def _done(self, req: Request) -> bool:
        return (len(req.generated) >= req.max_new_tokens
                or (self.eos_id is not None
                    and req.generated[-1] == self.eos_id))

    def _make_room(self, now: float,
                   exclude: Optional[int] = None) -> bool:
        """Free one page's worth of space: prefer dropping cached
        prefixes over evicting live requests."""
        if self.cache.release_prefix_pages(1):
            return True
        return self._preempt_youngest(now, exclude=exclude) is not None

    def _ensure_headroom_all(self, now: float, window) -> None:
        """Privatize/allocate every DECODING slot's write window before
        a batched program runs, making room (trie eviction, then
        youngest-preemption) on pressure; slots evicted mid-loop simply
        drop out of ``self.active``.  ``window`` maps slot -> tokens
        about to be written (missing slots default to 1)."""
        for slot in sorted(self.active):
            need = window.get(slot, 1)
            while slot in self.active and \
                    not self.cache.ensure_headroom(slot, need):
                if not self._make_room(now):
                    raise RuntimeError(
                        "single request exceeds total page budget")

    def _masked_state(self) -> dict:
        """Device state for a batched program with non-DECODING rows
        masked out: their rows carry the null page table and zero
        length, so lockstep writes land on page 0 instead of a page
        mid-ingest."""
        active_rows = np.zeros((self.max_batch,), bool)
        for slot in self.active:
            active_rows[slot] = True
        tables = np.where(active_rows[:, None], self.cache.page_tables,
                          0).astype(np.int32)
        lengths = np.where(active_rows, self.cache.lengths,
                           0).astype(np.int32)
        return {"k_pages": self.cache.k_pages,
                "v_pages": self.cache.v_pages,
                "page_tables": jax.numpy.asarray(tables),
                "lengths": jax.numpy.asarray(lengths)}

    # ----------------------------------------------------- decode round
    def _prepare_decode(self, now: float):
        """Host-side half of one decode/verify round, shared by the
        fused and unfused paths: draft (under speculation), privatize
        page headroom for every DECODING slot's write window (evicting
        on pressure), and build the round's fixed-shape (B, T) token
        array.  Returns (tokens, drafts, any_draft), or None when
        pressure evicted every DECODING slot.

        A row whose drafter returns nothing still participates — its
        round IS a decode step (one write, one bonus token) — so the
        batch never splits into spec and non-spec programs.  When *no*
        row drafted anything, the round is 1 wide (a plain decode step)
        instead of a (k+1)-wide verify of pure padding; both produce
        the identical next token, only the width differs."""
        if self.spec_k > 0:
            k = self.spec_k
            drafts: Dict[int, List[int]] = {}
            for slot, req in self.active.items():
                # cap the draft so even full acceptance cannot outrun
                # max_new_tokens — which also keeps every speculative
                # write inside the page budget submit() admitted the
                # request under
                cap = min(k, req.max_new_tokens - len(req.generated) - 1)
                d = self.drafter.propose(slot, req, cap) if cap > 0 \
                    else []
                drafts[slot] = [int(t) for t in d[:max(cap, 0)]]
            # page headroom for every position this row can confirm
            # (n_draft + 1 writes).  Padded verify positions past the
            # window land on the null page or on this slot's own
            # private pages — never on shared ones (pages past the
            # write frontier are never donated to the trie) — so they
            # need no budget.
            self._ensure_headroom_all(
                now, {s: len(d) + 1 for s, d in drafts.items()})
            if not self.active:          # pressure evicted everyone
                return None
            any_draft = any(drafts[slot] for slot in self.active)
            T = k + 1 if any_draft else 1
        else:
            # page headroom for this step's token writes (growth or COW
            # of a trie-donated page); evict on pressure
            drafts, any_draft, T = {}, False, 1
            self._ensure_headroom_all(now, {})
            if not self.active:          # pressure evicted everyone
                return None
        tokens = np.zeros((self.max_batch, T), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.generated[-1]
            d = drafts.get(slot, [])
            tokens[slot, 1:1 + len(d)] = d
        return tokens, drafts, any_draft

    def _apply_decode(self, nxt, drafts, any_draft, now: float) -> None:
        """Bank one decode/verify round's token output ``nxt``
        ((B, 1) or (B, T), host- or device-side).  The acceptance loop
        is the unified form: with no drafts it degenerates to appending
        row token 0 (a = 0, the eos truncation is a no-op on a single
        token), which is exactly the plain decode bank."""
        self._c["n_decode_steps"].inc()
        self._c["n_spec_rounds"].inc(int(any_draft))
        self._last_decode_rows = len(self.active)
        nxt = np.asarray(nxt)
        for slot in list(self.active):
            req = self.active[slot]
            d, row = drafts.get(slot, []), nxt[slot]
            # accept the longest draft prefix the target itself would
            # have generated; row[a] is then the free bonus token
            a = 0
            while a < len(d) and d[a] == int(row[a]):
                a += 1
            appended = d[:a] + [int(row[a])]
            if self.eos_id is not None and self.eos_id in appended:
                # the oracle stops at eos: anything banked after it
                # was never generated
                appended = appended[:appended.index(self.eos_id) + 1]
            req.generated.extend(appended)
            self.cache.lengths[slot] += len(appended)
            self._c["n_drafted"].inc(len(d))
            # drafts past an accepted eos were never banked
            self._c["n_draft_accepted"].inc(min(a, len(appended)))
            if self.tel:
                self.tel.event(req, "decode_round", t=self._now,
                               replica=self.uid, n=len(appended),
                               drafted=len(d),
                               accepted=min(a, len(appended)))
            if self.spec_k > 0:
                self.cache.rollback_spec(slot)
            if self._done(req):
                self._finish(slot, now)
            # confirmed in one burst: the streaming face of speculation
            self._emit(req, appended)

    def _decode_round(self, tokens, drafts, any_draft,
                      now: float) -> None:
        """Unfused decode/verify dispatch over the prepared round."""
        program = self._verify if tokens.shape[1] > 1 else self._decode
        nxt, state = program(self.params, self._masked_state(),
                             jax.numpy.asarray(tokens))
        self.cache.k_pages = state["k_pages"]
        self.cache.v_pages = state["v_pages"]
        self._c["n_total_dispatches"].inc()
        self._apply_decode(nxt, drafts, any_draft, now)

    def _fused_round(self, tokens, drafts, any_draft,
                     now: float) -> None:
        """The fused uber-program: this step's decode/verify round AND
        one chunk for every PREFILLING request in ONE dispatch
        (models/lm.fused_step_paged carries the page-disjointness
        argument that makes the merge bitwise).  The prefill inputs are
        built *after* ``_prepare_decode`` ran: its headroom pass may
        preempt a PREFILLING slot, and the dispatch must see the
        survivors.  Decode results are banked before prefill
        promotions: promotion may replay, replay may preempt — an
        unapplied decode token must never be dropped."""
        group = list(self.prefilling.items())
        p_tokens, tables, starts, valids, metas = \
            self._prefill_inputs(group)
        (d_nxt, p_nxt), state = self.programs.fused(
            self.params, self._masked_state(),
            jax.numpy.asarray(tokens),
            jax.numpy.asarray(p_tokens),
            jax.numpy.asarray(tables),
            jax.numpy.asarray(starts),
            jax.numpy.asarray(valids))
        self.cache.k_pages = state["k_pages"]
        self.cache.v_pages = state["v_pages"]
        # one launch subsumes a prefill dispatch and a decode round:
        # both sub-counters advance (their per-kind semantics — chunks
        # ingested, rounds banked — are unchanged), total only once
        self._c["n_fused_dispatches"].inc()
        self._c["n_total_dispatches"].inc()
        self._c["n_prefill_dispatches"].inc()
        self._c["n_prefill_chunks"].inc(len(metas))
        self._apply_decode(d_nxt, drafts, any_draft, now)
        self._finish_prefill(metas, np.asarray(p_nxt), now)

    # ------------------------------------------------------------- step
    def step(self, now: float = float("inf")) -> bool:
        """One engine iteration: admit what fits (up to
        ``prefill_batch`` co-ingesting prompts), advance every
        prefilling request one chunk, and run one decode/verify round
        over every decoding slot — in the steady state (both kinds of
        work pending) a single fused dispatch covers all of it
        (``fused=True``, the default).  Returns True while any work
        remains (queued or in flight).

        With tracing on, wraps ``_step`` to emit one step-timeline
        record: dispatch kind, rows per group, page/COW/eviction
        deltas, population sizes.  The sanitized clock ``_now``
        substitutes the step index when driven offline (``now=inf``)
        so span/timeline times stay finite."""
        self._now = (float(now) if now != float("inf")
                     else float(self.n_engine_steps))
        if not self.tel:
            return self._step(now)
        pre = (self._c["n_prefill_dispatches"].value,
               self._c["n_decode_steps"].value,
               self._c["n_replay_steps"].value,
               self._c["n_fused_dispatches"].value,
               self._c["n_prefill_chunks"].value,
               self.cache.n_cow, self.cache.n_prefix_evictions,
               self.cache.n_shared_tokens)
        self._last_decode_rows = 0
        more = self._step(now)
        d_pref, d_dec, d_rep, d_fus, d_chunks, d_cow, d_evict, d_shr = (
            self._c["n_prefill_dispatches"].value - pre[0],
            self._c["n_decode_steps"].value - pre[1],
            self._c["n_replay_steps"].value - pre[2],
            self._c["n_fused_dispatches"].value - pre[3],
            self._c["n_prefill_chunks"].value - pre[4],
            self.cache.n_cow - pre[5],
            self.cache.n_prefix_evictions - pre[6],
            self.cache.n_shared_tokens - pre[7])
        kind = ("fused" if d_fus else
                "+".join([k for k, v in (("prefill", d_pref),
                                         ("decode", d_dec),
                                         ("replay", d_rep)) if v])
                or "idle")
        self.tel.record(
            "engine", t=self._now, replica=self.uid, kind=kind,
            prefill_rows=d_chunks, decode_rows=self._last_decode_rows,
            replay_steps=d_rep, pages_free=self.cache.free_pages,
            cow=d_cow, prefix_evictions=d_evict, shared_tokens=d_shr,
            waiting=len(self.waiting), prefilling=len(self.prefilling),
            active=len(self.active), finished=len(self.finished))
        return more

    def _step(self, now: float) -> bool:
        # Admission + prefill.  Chunk pacing exists to stop LONG
        # prompts from stalling in-flight decode, so only mid-prompt
        # chunks yield the step: short prompts (<= chunk_size) admit,
        # ingest, and promote eagerly — the batch ramps as fast as
        # one-shot prefill — and a prompt that could share a prefix
        # with one still ingesting waits for its registration
        # (_defers_for_sharing), so bursts still share.  With no
        # decoders to protect, long prompts ingest back-to-back too.
        # Under fusion, any pending chunk work while decoders exist is
        # carried into this step's single fused dispatch instead of a
        # standalone prefill launch; degenerate mixes — prefill-only
        # ramp, decode-only tail — take the standalone programs, so
        # they reproduce the unfused engine dispatch-for-dispatch.
        if self.n_inflight:
            self._c["n_engine_steps"].inc()
        while True:
            self._admit_burst(now)
            if not self.prefilling:
                break
            if self.fused and self.active:
                break              # chunks ride the fused dispatch
            self._run_prefill(now)
            if self.prefilling and self.active:
                break                          # mid-prompt pacing point
        if not self.active:
            return bool(self.waiting or self.prefilling)

        prep = self._prepare_decode(now)
        if prep is None:             # pressure evicted everyone
            return bool(self.waiting or self.prefilling)
        tokens, drafts, any_draft = prep
        if self.fused and self.prefilling:
            self._fused_round(tokens, drafts, any_draft, now)
        else:
            self._decode_round(tokens, drafts, any_draft, now)
        return bool(self.active or self.prefilling or self.waiting)

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        """Cumulative engine counters: dispatch counts (the
        machine-independent face of every serving optimization —
        wall-clock on shared runners is noise, program launches are
        not), prefill co-ingestion occupancy, and cache reuse.
        ``prefill_rows_mean`` is the mean number of requests sharing a
        prefill dispatch (1.0 == the serialized path).  The dict is the
        compatibility view of the MetricsRegistry this engine's
        counters live in; ratio fields (``prefill_rows_mean``,
        ``accept_rate``) are derived by ``telemetry.merge_stats`` so a
        single replica and a fleet aggregate agree on the formula."""
        raw = {n: c.value for n, c in self._c.items()}
        raw.update(n_shared_tokens=self.cache.n_shared_tokens,
                   n_cow=self.cache.n_cow,
                   n_prefix_evictions=self.cache.n_prefix_evictions)
        return merge_stats([raw])

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request], *,
            realtime: bool = False) -> List[Request]:
        """Drive to completion; returns the requests completed by THIS
        call (the engine is reusable — e.g. a warmup run then a
        measured run).  ``realtime=False`` ignores arrival times (admit
        ASAP — tests / max-throughput); ``realtime=True`` replays them
        against the wall clock (benchmarks / TTFT)."""
        first = len(self.finished)
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while True:
            now = (time.perf_counter() - t0) if realtime else float("inf")
            if not self.step(now=now):
                break
            if realtime and not self.active and not self.prefilling \
                    and self.waiting:
                time.sleep(max(0.0,
                               self.waiting[0].arrival
                               - (time.perf_counter() - t0)))
        # the batch surface reports via finished Requests; stream
        # events are for step-driven front-ends (drain_events)
        self.events.clear()
        return self.finished[first:]
