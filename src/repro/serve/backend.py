"""The serving-surface contract: one ``ServeBackend`` protocol that a
single ``ServeEngine`` and a multi-replica ``RequestRouter`` both
implement, so every layer above them — the batch ``run`` driver, the
async streaming front-end (serve/frontend.py), benchmarks — drives
either one interchangeably.

The protocol is the submit/step/run/stats surface the two grew in
parallel through PRs 1–5, made identical on purpose:

* ``submit(req)`` / ``check_admissible(req)`` — queue a request; fail
  fast (ValueError) on one that could never be admitted.
* ``step(now)`` — one scheduling iteration; returns True while work
  remains.  ``now`` gates arrival replay and stamps TTFT/finish times;
  step-driven callers may feed a synthetic clock (a step counter) to
  get machine-independent latency units.
* ``drain_events()`` — the streaming face: every call returns the
  ``StreamEvent``s confirmed since the last call, in confirmation
  order.  Tokens appear exactly once, in stream order, as soon as they
  are *confirmed* — one per decode step, a burst per accepted
  speculation round, and never retracted (preemption/replay re-derives
  KV, not tokens, so a confirmed token is final).
* ``extract(rid)`` / ``cancel(rid)`` — remove a request wherever it
  lives (queued, prefilling, decoding), freeing its slot and pages via
  the same machinery preemption uses.  ``extract`` returns the live
  ``Request`` with its confirmed tokens intact — re-submitting it
  later resumes the stream token-exactly (recompute-replay), which is
  what makes front-end SLO preemption free of correctness risk.
  ``cancel`` is extract-and-discard.
* ``run(requests, realtime=)`` — the offline batch driver (drive to
  completion, return finished requests), unchanged from PR 1.
* ``stats()`` — flat numeric counter dict; the router returns the
  field-wise sum over its replicas plus its own routing counters, so
  the two read identically at trend granularity.
* ``capacity`` / ``n_inflight`` — concurrently-servable request slots
  and current occupancy; a front-end that keeps
  ``n_inflight < capacity`` owns all queueing policy itself (the
  backend's internal queue stays empty except for its own
  page-pressure preemptions).

**Failure semantics.**  A backend that has *died* raises
``repro.serve.faults.ReplicaFailure`` from every call that needs the
process — ``step``, ``submit``, ``extract``, ``cancel``,
``drain_events`` — while ``stats()`` (externally scraped counters)
stays readable.  Layers composing backends must treat ReplicaFailure
as "this replica is gone", not as a request error: the router marks
the replica FAILED and rebuilds its requests from the recovery
journal (serve/recovery.py, docs/robustness.md).  Two optional
surfaces ride the protocol: ``degraded`` (bool — lost capacity not
yet rebuilt; front-ends shed batch-class admissions while it is
True) and ``mark_dead()`` (point of no return for a wrapper that can
simulate death).  Absent attributes mean healthy/no-op.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable

__all__ = ["ServeBackend", "StreamEvent"]


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """Tokens confirmed for one request by one backend step.

    ``tokens`` is the newly confirmed suffix of the request's stream
    (possibly empty on a pure finish event); ``finished`` marks the
    stream complete — no further events will carry this ``rid``.
    Concatenating every event's tokens for a rid reproduces
    ``Request.generated`` exactly.
    """
    rid: int
    tokens: Tuple[int, ...]
    finished: bool


@runtime_checkable
class ServeBackend(Protocol):
    """Structural type of a serving backend (engine or router)."""

    @property
    def capacity(self) -> int: ...

    @property
    def n_inflight(self) -> int: ...

    def check_admissible(self, req) -> None: ...

    def submit(self, req) -> None: ...

    def step(self, now: float = float("inf")) -> bool: ...

    def drain_events(self) -> List[StreamEvent]: ...

    def extract(self, rid: int): ...

    def cancel(self, rid: int) -> bool: ...

    def run(self, requests, *, realtime: bool = False) -> List: ...

    def stats(self) -> Dict[str, float]: ...
