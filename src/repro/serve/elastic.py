"""Elastic fleet control: demand-driven replica scaling over the
request router.

RISC-NN's scaling argument — a fleet of simple units beats one
monolithic engine because units can be added and removed to track the
workload — lands here as the serving control loop: production load is
bursty (the TPU in-datacenter analysis), and a fleet provisioned for
peak idles through every trough.  ``ElasticController`` wraps a
``RequestRouter`` and resizes its replica set live:

* **Demand signal.**  Every ``scale_interval`` steps the controller
  reads the router's queue depth (arrived requests only) plus the
  fleet's in-flight count — the requests that *want* a slot right now.
  The target replica count is the smallest fleet whose batch slots
  cover that demand (``target_load`` scales how hot a replica should
  run), clamped to ``[min_replicas, max_replicas]``.
* **Scale up fast.**  A burst raises the *instant* signal and replicas
  join the same control round — a joining replica is just a fresh
  engine on the shared ``ServePrograms`` bundle (one compile cache per
  fleet), so the join costs allocator state, not a recompile, and it
  takes dispatches on the next router step.
* **Scale down with patience.**  Retirement uses the smoothed signal
  (EMA, never below the instant value) and waits
  ``scale_down_patience`` consecutive low rounds before draining ONE
  replica — hysteresis so a sawtooth trough must persist before the
  fleet shrinks, and shrinkage is gradual.  The victim is the live
  replica with the least outstanding work (ties: the coldest prefix
  trie — ``PrefixCache.resident_tokens`` — so the fleet keeps its
  warmest caches).
* **Graceful drain, live migration.**  ``RequestRouter.drain`` marks
  the victim; from that instant it takes no new admissions, and the
  next router step *migrates* every request it still holds — extracted
  at the confirmed-token frontier (``ServeEngine.extract_all``) and
  re-queued at the router head, oldest first.  Re-admission on the
  surviving replicas goes through the normal trie lookup, so a
  migrated request whose shared prefix is resident on the target
  rebuilds its prompt pages by **donation** (a refcount attach), and
  its confirmed tokens replay through the target's decode program —
  the resumed stream is bitwise the stream a static fleet would have
  produced.  No request is ever dropped or reordered by scaling.

The controller implements the same ``ServeBackend`` protocol as the
engine and the router — a front-end (serve/frontend.py) cannot tell a
fixed fleet from an elastic one.  Its ``capacity`` deliberately
reports the fleet's *potential* (``max_replicas`` × per-replica
slots), not its current size: a front-end that throttles at current
capacity would hide the very demand the controller scales on.

This module also absorbs the two seed-era elasticity utilities that
predate the serve stack: ``plan_elastic_mesh`` (the training-side
policy — pick the largest legal mesh after device-membership changes)
and ``StragglerMonitor`` (per-step wall-time EMA outlier detection,
used by the training driver).  Both are re-exported from their old
``repro.runtime`` homes.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Tuple

from .backend import StreamEvent
from .router import RequestRouter
from .scheduler import Request, ServeEngine
from .telemetry import expose_counters, next_uid

__all__ = ["ElasticController", "ElasticPolicy",
           "plan_elastic_mesh", "StragglerMonitor", "StragglerEvent"]


@dataclasses.dataclass
class ElasticPolicy:
    """Knobs of the demand-driven scaling loop (see module docstring
    for the loop itself)."""
    min_replicas: int = 1
    max_replicas: int = 4
    scale_interval: int = 8      # steps between control rounds
    target_load: float = 1.0     # demand per slot a replica should carry
    scale_down_patience: int = 2  # low rounds before draining one
    alpha: float = 0.5           # demand-EMA smoothing (scale-down only)
    # crash repair (docs/robustness.md): while the fleet sits below
    # min_replicas the controller tries to replace lost replicas via
    # replica_factory — a failed build waits out an exponentially
    # growing backoff (repair_backoff, 2x per consecutive failure, in
    # steps) and spends one unit of the bounded retry budget; a
    # successful join resets both.  Budget exhausted = stay degraded.
    repair_backoff: int = 2
    repair_budget: int = 8

    def __post_init__(self):
        if not 1 <= self.min_replicas <= self.max_replicas:
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"{self.min_replicas}..{self.max_replicas}")
        if self.scale_interval < 1:
            raise ValueError("scale_interval must be >= 1")
        if self.target_load <= 0:
            raise ValueError("target_load must be > 0")
        if self.repair_backoff < 1:
            raise ValueError("repair_backoff must be >= 1")
        if self.repair_budget < 0:
            raise ValueError("repair_budget must be >= 0")


@expose_counters("n_scale_ups", "n_scale_downs", "n_repairs",
                 "n_repair_failures")
class ElasticController:
    """A ``ServeBackend`` that owns a router and resizes its fleet.

    ``replica_factory`` builds one fresh ``ServeEngine`` per call;
    build it over a shared ``ServePrograms`` bundle so joins reuse the
    fleet's compile cache (``ServeOptions.build`` does).
    """

    def __init__(self, router: RequestRouter,
                 replica_factory: Callable[[], ServeEngine], *,
                 policy: Optional[ElasticPolicy] = None):
        self.router = router
        self.factory = replica_factory
        self.policy = policy or ElasticPolicy()
        if len(router.replicas) > self.policy.max_replicas:
            raise ValueError(
                f"router starts with {len(router.replicas)} replicas; "
                f"policy caps the fleet at {self.policy.max_replicas}")
        # fleets are homogeneous (one factory): per-replica slots are a
        # constant of the fleet, read off the first member
        self._slots = router.replicas[0].max_batch
        self._tick = 0
        self._ema: Optional[float] = None
        self._low_rounds = 0
        # repair loop state: next tick allowed to attempt a rebuild,
        # current backoff delay, remaining retry budget
        self._repair_at = 0
        self._repair_delay = self.policy.repair_backoff
        self._repair_budget = self.policy.repair_budget
        # counters in the fleet's shared registry (legacy names via
        # @expose_counters); the controller shares the router's
        # Telemetry — one registry per serving stack
        self.tel = router.tel
        self.uid = next_uid("c")
        self._c = {n: self.tel.registry.counter(
            n, component="elastic", replica=self.uid)
            for n in ("n_scale_ups", "n_scale_downs", "n_repairs",
                      "n_repair_failures")}

    # -------------------------------------------------------- delegation
    @property
    def replicas(self) -> List[ServeEngine]:
        return self.router.replicas

    @property
    def finished(self) -> List[Request]:
        return self.router.finished

    @property
    def n_inflight(self) -> int:
        return self.router.n_inflight

    @property
    def capacity(self) -> int:
        """The fleet's POTENTIAL concurrency (``max_replicas`` × batch
        slots), not its current size: front-ends throttle submission at
        ``capacity``, and demand they withhold is demand the control
        loop cannot see — the elastic fleet must be offered the load it
        is supposed to scale into."""
        return self.policy.max_replicas * self._slots

    def check_admissible(self, req: Request) -> None:
        self.router.check_admissible(req)

    def submit(self, req: Request) -> None:
        self.router.submit(req)

    def drain_events(self) -> List[StreamEvent]:
        return self.router.drain_events()

    def extract(self, rid: int) -> Optional[Request]:
        return self.router.extract(rid)

    def cancel(self, rid: int) -> bool:
        return self.router.cancel(rid)

    # ----------------------------------------------------------- control
    def demand(self, now: float = float("inf")) -> int:
        """Requests that want a slot right now: arrived-but-queued plus
        everything already on a replica."""
        queued = sum(1 for r in self.router.queue if r.arrival <= now)
        return queued + sum(e.n_inflight for e in self.router.replicas)

    def _target(self, demand: float) -> int:
        per = self._slots * self.policy.target_load
        want = math.ceil(demand / per)
        return max(self.policy.min_replicas,
                   min(self.policy.max_replicas, want))

    def _victim(self) -> Optional[int]:
        """Index of the live replica to retire: least outstanding
        tokens, then the coldest prefix trie — keep the warm caches."""
        live = [i for i in range(len(self.router.replicas))
                if not self.router.is_draining(i)]
        if len(live) <= 1:
            return None

        def score(i: int) -> Tuple[int, int, int]:
            eng = self.router.replicas[i]
            warmth = (eng.cache.prefix.resident_tokens()
                      if eng.cache.prefix is not None else 0)
            return (self.router._outstanding_tokens(i), warmth, i)
        return min(live, key=score)

    def _control(self, now: float) -> None:
        demand = self.demand(now)
        self._ema = (demand if self._ema is None else
                     self.policy.alpha * demand
                     + (1 - self.policy.alpha) * self._ema)
        live = self.router.n_live
        # scale up on the INSTANT signal: bursts must not wait out the
        # EMA.  All missing replicas join this round.
        up = self._target(demand)
        for _ in range(max(0, up - live)):
            try:
                eng = self.factory()
            except Exception as e:
                # a broken factory must not kill the serve loop; the
                # next control round (or the backoff-gated repair
                # loop, if the fleet is degraded) retries
                self._c["n_repair_failures"].inc()
                if self.tel:
                    self.tel.record("elastic", t=self.router._last_now,
                                    kind="scale_up_failed",
                                    error=type(e).__name__)
                break
            self.router.add_replica(eng)
            self._c["n_scale_ups"].inc()
        live = self.router.n_live
        # scale down on the smoothed signal (never below instant: a
        # trough that already ended is not a trough), with patience —
        # and at most one drain per control round, so shrinkage is
        # gradual and each drain's migration settles before the next.
        down = self._target(max(self._ema, demand))
        if down < live:
            self._low_rounds += 1
            if self._low_rounds >= self.policy.scale_down_patience:
                victim = self._victim()
                if victim is not None:
                    self.router.drain(victim)
                    self._c["n_scale_downs"].inc()
                self._low_rounds = 0
        else:
            self._low_rounds = 0
        if self.tel:
            self.tel.record(
                "elastic", t=self.router._last_now, kind="control",
                demand=demand, ema=round(self._ema, 3),
                target_up=up, live=self.router.n_live,
                draining=len(self.router._draining))

    # ------------------------------------------------------------ repair
    @property
    def degraded(self) -> bool:
        """True while the fleet sits below ``min_replicas`` — lost
        capacity the repair loop has not yet rebuilt.  Front-ends use
        this to shed batch-class admissions (docs/robustness.md)."""
        return self.router.n_live < self.policy.min_replicas

    def _maybe_repair(self, now: float) -> None:
        """Replace crash-lost replicas.  Runs every step (a control
        round only every ``scale_interval`` — too slow for a dead
        fleet), gated by exponential backoff and the bounded retry
        budget so a persistently failing factory cannot hot-loop."""
        if not self.degraded:
            return
        if self._repair_budget <= 0 or self._tick < self._repair_at:
            return
        try:
            eng = self.factory()
        except Exception as e:
            self._c["n_repair_failures"].inc()
            self._repair_budget -= 1
            self._repair_at = self._tick + self._repair_delay
            self._repair_delay *= 2
            if self.tel:
                self.tel.record(
                    "elastic", t=self.router._last_now,
                    kind="repair_failed", error=type(e).__name__,
                    budget=self._repair_budget,
                    next_in=self._repair_at - self._tick)
            return
        self.router.add_replica(eng)
        self._c["n_repairs"].inc()
        self._repair_delay = self.policy.repair_backoff
        self._repair_budget = self.policy.repair_budget
        if self.tel:
            self.tel.record("elastic", t=self.router._last_now,
                            kind="repair", live=self.router.n_live)

    # -------------------------------------------------------------- step
    def step(self, now: float = float("inf")) -> bool:
        """One fleet iteration: run the control loop every
        ``scale_interval``-th call, repair crash losses, then one
        router step (which executes any drain the control round just
        marked, and detects/recovers any replica failure).  Returns
        True while anything is queued or in flight."""
        if self._tick % self.policy.scale_interval == 0:
            self._control(now)
        self._maybe_repair(now)
        self._tick += 1
        return self.router.step(now)

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        """The router's fleet-wide counters (departed replicas
        included) plus the controller's scaling history."""
        agg = self.router.stats()
        agg["n_scale_ups"] = self.n_scale_ups
        agg["n_scale_downs"] = self.n_scale_downs
        agg["n_repairs"] = self.n_repairs
        agg["n_repair_failures"] = self.n_repair_failures
        agg["n_control_rounds"] = (self._tick
                                   + self.policy.scale_interval - 1) \
            // self.policy.scale_interval
        return agg

    # -------------------------------------------------------------- run
    def run(self, requests: List[Request], *,
            realtime: bool = False) -> List[Request]:
        """Drive to completion; returns the requests completed by THIS
        call in completion order (mirrors ``RequestRouter.run``, with
        the control loop in the driving seat)."""
        first = len(self.finished)
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while True:
            now = (time.perf_counter() - t0) if realtime else float("inf")
            if not self.step(now=now):
                break
            if realtime and self.router.queue \
                    and not any(e.n_inflight for e in self.replicas):
                time.sleep(max(0.0, self.router.queue[0].arrival
                               - (time.perf_counter() - t0)))
        done = list(self.finished[first:])
        done.sort(key=lambda r: (r.finish_time, r.rid))
        return done


# --------------------------------------------------------------------
# Seed-era elasticity utilities, absorbed from repro.runtime (their old
# modules re-export these; the training driver still uses both).
# --------------------------------------------------------------------

def plan_elastic_mesh(n_devices: int, *, model_parallel: int,
                      min_data: int = 1,
                      pods: int = 1) -> Optional[Tuple[Tuple[int, ...],
                                                       Tuple[str, ...]]]:
    """Largest (shape, axes) mesh using <= n_devices after a
    device-membership change — the training-side elasticity policy.

    Keeps ``model_parallel`` fixed (param shardings stay valid) and
    shrinks the data axis; drops to fewer pods before shrinking data
    below ``min_data``.  Returns None when no legal mesh exists.  The
    checkpoint layer restores onto whatever mesh this returns
    (full-array manifests are topology-free).
    """
    if model_parallel <= 0 or n_devices < model_parallel * min_data:
        return None
    for p in range(pods, 0, -1):
        per_pod = n_devices // p
        data = per_pod // model_parallel
        if data >= min_data:
            if p > 1:
                return ((p, data, model_parallel),
                        ("pod", "data", "model"))
            return ((data, model_parallel), ("data", "model"))
    return None


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ema: float
    ratio: float


class StragglerMonitor:
    """Straggler detection: per-step wall-time EMA with an outlier
    policy.  On a real pod the mitigation is re-issuing the slow host's
    shard / evicting the host; here the monitor emits the decision so
    the driver (and tests) can act on it.  A step that exceeds
    ``threshold x EMA`` (after ``warmup`` steps) marks its slowest
    participant; the outlier never poisons the EMA."""

    def __init__(self, threshold: float = 2.5, alpha: float = 0.1,
                 warmup: int = 5):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.events: List[StragglerEvent] = []

    def observe(self, step: int,
                step_time: float) -> Optional[StragglerEvent]:
        self.n += 1
        if self.ema is None:
            self.ema = step_time
            return None
        event = None
        if self.n > self.warmup and step_time > self.threshold * self.ema:
            event = StragglerEvent(step, step_time, self.ema,
                                   step_time / self.ema)
            self.events.append(event)
            # do not poison the EMA with the outlier
            return event
        self.ema = (1 - self.alpha) * self.ema + self.alpha * step_time
        return event
