"""Speculative decoding: draft proposers for the paged serve engine.

Split of responsibilities (the classic proposer/verifier decomposition,
Leviathan et al. 2023 / prompt-lookup decoding):

* A **drafter** guesses up to ``k`` continuation tokens per request per
  engine step.  Drafts are *advisory*: nothing a drafter returns can
  change the generated stream, only how fast it is produced.  A wrong
  draft costs one wasted verify position; a right one saves a whole
  decode step.
* The **verifier** is the target model itself: the scheduler packs
  ``[last_confirmed, d_1 .. d_k]`` per row into one
  ``DecoderLM.verify_step_paged`` call, which scores all ``k+1``
  positions in a single batched program and returns the target's own
  greedy prediction at each.  The engine accepts the longest draft
  prefix that matches (``d_i == argmax(logits[i-1])``) and always banks
  the verifier's next token after the accepted prefix — the "bonus"
  token — so even an all-rejected round makes the same progress a plain
  decode step would.

Invariants the engine relies on:

* **Drafters never touch the paged cache.**  All page writes, COW
  forks, and rollback happen in the verify path under
  serve/kv_cache.py's discipline; a drafter only reads host-side token
  lists (and, for the draft-model flavor, its own private contiguous
  cache).
* **Accepted == what greedy decode would have produced.**  Acceptance
  compares the draft against the verifier's argmax at the same
  position over bit-identical context (kernels/paged_attention/ref.py
  ``paged_verify_attention_ref``), so spec-on and spec-off streams are
  token-identical — docs/speculative.md gives the full argument.
* **Propose-side state is disposable.**  ``detach`` drops a slot's
  drafter state at finish/preemption; a re-admitted request simply
  re-feeds its context.  Draft state is never checkpointed, shared, or
  replayed.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List

import numpy as np

__all__ = ["PromptLookupDrafter", "DraftModelDrafter"]


class PromptLookupDrafter:
    """N-gram prompt-lookup drafting (no model at all): find the most
    recent occurrence of the context's trailing n-gram — in this
    request's own prompt + generation, *or in any other request the
    engine has served* — and propose the tokens that followed it.

    This is the zero-cost drafter: repetitive continuations — quoted
    spans, code identifiers, the degenerate repeat plateaus of greedy
    decoding — are exactly the regime where the next tokens already
    appeared verbatim somewhere the drafter has seen.  The index is
    *cross-request within a workload*: requests sharing a system
    prompt generate overlapping continuations (the same property the
    prefix cache exploits for KV), so the first request through a
    motif becomes the draft source for every later one.  Each index is
    scoped by the request's leading prompt tokens (``scope_tokens``) —
    unrelated workloads must not share n-gram statistics, since a
    short n-gram that recurs across workloads almost never continues
    the same way, and one polluted entry shadows a good one until the
    motif recurs (measured: accept rate decays 0.49 -> 0.15 over five
    unscoped workload generations).  Longer n-grams are tried first
    (``max_ngram`` down to ``min_ngram``) so a specific match beats an
    accidental short one.

    Bookkeeping is O(max_ngram) dict writes per *confirmed* token and
    O(max_ngram) lookups per proposal — no arrays, no device work.  An
    n-gram is only indexed once its continuation token is confirmed
    (the index lags the frontier by one position), so a lookup never
    lands on the still-growing tail it is trying to extend, and a
    trailing plateau ``[x, x]`` correctly finds its own earlier
    ``(x, x) -> x`` occurrence.  Index entries hold references to the
    per-request context lists, so a continuation keeps extending as
    its source request generates.  ``max_entries`` (summed over
    scopes) bounds memory with a **per-scope LRU**: when the budget
    overflows, whole least-recently-*used* scopes are dropped —
    scope granularity because statistics within a workload age
    together, and LRU because the hot workload of the moment is
    exactly the one whose index is earning accepts (the old wholesale
    reset re-cooled every workload each time one overgrew).
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 scope_tokens: int = 16, max_entries: int = 1 << 20):
        assert 1 <= min_ngram <= max_ngram
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.scope_tokens = scope_tokens
        self.max_entries = max_entries
        self._n_entries = 0
        # scope -> ngram -> (ctx_list, pos); ordered oldest-used first
        self._scopes: "OrderedDict[tuple, Dict[tuple, tuple]]" = \
            OrderedDict()
        self._slots: Dict[int, dict] = {}
        self.n_scope_evictions = 0

    def propose(self, slot: int, req, k: int) -> List[int]:
        st = self._slots.get(slot)
        if st is None or st["req"] is not req:
            scope = tuple(int(t) for t in req.prompt[:self.scope_tokens])
            st = {"req": req, "ctx": [int(t) for t in req.prompt],
                  "ngen": 0, "cursor": 0, "scope": scope}
            self._slots[slot] = st
        ctx = st["ctx"]
        for t in req.generated[st["ngen"]:]:
            ctx.append(int(t))
        st["ngen"] = len(req.generated)
        index = self._scopes.get(st["scope"])
        if index is None:
            index = self._scopes[st["scope"]] = {}
        else:
            self._scopes.move_to_end(st["scope"])   # LRU touch
        # index every n-gram whose continuation is now confirmed
        for j in range(st["cursor"], len(ctx) - 1):
            for n in range(self.min_ngram, self.max_ngram + 1):
                if j + 1 >= n:
                    key = tuple(ctx[j + 1 - n:j + 1])
                    self._n_entries += key not in index
                    index[key] = (ctx, j + 1)
        st["cursor"] = max(st["cursor"], len(ctx) - 1)
        # over budget: drop whole least-recently-used scopes (never the
        # one in use — it was just touched to the back of the order)
        while self._n_entries > self.max_entries and len(self._scopes) > 1:
            _, evicted = self._scopes.popitem(last=False)
            self._n_entries -= len(evicted)
            self.n_scope_evictions += 1
        if self._n_entries > self.max_entries:
            # one degenerate scope alone exceeds the budget: reset it
            self._n_entries -= len(index)
            index.clear()
            self.n_scope_evictions += 1
        if k <= 0:
            return []
        for n in range(self.max_ngram, self.min_ngram - 1, -1):
            if len(ctx) < n:
                continue
            hit = index.get(tuple(ctx[len(ctx) - n:]))
            if hit is not None:
                src, pos = hit
                cont = src[pos:pos + k]
                if cont:
                    return list(cont)
        return []

    def detach(self, slot: int) -> None:
        # the slot's cursor dies with it; its indexed n-grams live on
        # as draft sources for future requests
        self._slots.pop(slot, None)


class DraftModelDrafter:
    """Draft with a smaller ``DecoderLM`` (``--draft-config``): each
    DECODING slot keeps a private single-row contiguous cache for the
    draft model, fed through the plain lockstep ``decode_step`` program
    (one jit compile total — the context is streamed token by token, so
    no per-prompt-length prefill programs pile up).

    Rollback is a position reset: after a verify round rejects the tail
    of a draft, the slot's draft cache simply rewinds ``pos`` to the
    last *confirmed* context token it had consumed — entries past
    ``pos`` are masked by decode attention and get overwritten in place
    when the true continuation is fed.  The draft cache never needs
    page bookkeeping, COW, or replay: it is advisory state, rebuilt
    from the token list after any preemption.
    """

    def __init__(self, model, params, *, cfg_target=None,
                 headroom: int = 8):
        import jax
        from .step import make_decode_step
        if cfg_target is not None and \
                model.cfg.vocab_size != cfg_target.vocab_size:
            raise ValueError(
                f"draft vocab {model.cfg.vocab_size} != target vocab "
                f"{cfg_target.vocab_size}: draft tokens would be "
                "meaningless to the verifier")
        self.model, self.params = model, params
        self._decode = jax.jit(make_decode_step(model))
        self.headroom = headroom
        self._slots: Dict[int, dict] = {}   # slot -> {cache, n_fed, cap}

    def _state_for(self, slot: int, req) -> dict:
        st = self._slots.get(slot)
        if st is None:
            cap = len(req.prompt) + req.max_new_tokens + self.headroom
            st = {"cache": self.model.init_cache(1, cap),
                  "n_fed": 0, "cap": cap}
            self._slots[slot] = st
        return st

    def propose(self, slot: int, req, k: int) -> List[int]:
        import jax.numpy as jnp
        if k <= 0:
            return []
        st = self._state_for(slot, req)
        ctx = [int(t) for t in req.prompt] + list(req.generated)
        # rewind past any rejected draft tokens from the last round:
        # the cache's pos falls back to the confirmed-context frontier
        # and the pending true tokens overwrite the stale entries
        cache = dict(st["cache"])
        cache["pos"] = jnp.asarray(st["n_fed"], jnp.int32)
        tok = None
        for t in ctx[st["n_fed"]:]:
            tok, cache = self._decode(
                self.params, cache, jnp.asarray([[t]], jnp.int32))
        st["n_fed"] = len(ctx)
        if tok is None:                      # nothing new to consume
            return []
        drafts: List[int] = []
        budget = st["cap"] - len(ctx) - 1    # cache slots left to write
        for _ in range(min(k, max(budget, 0))):
            drafts.append(int(np.asarray(tok)[0, 0]))
            if len(drafts) < k:
                tok, cache = self._decode(self.params, cache, tok)
        st["cache"] = cache
        return drafts

    def detach(self, slot: int) -> None:
        self._slots.pop(slot, None)
