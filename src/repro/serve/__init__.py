from .kv_cache import PagedKVCache  # noqa: F401
from .scheduler import Request, ServeEngine  # noqa: F401
from .step import (  # noqa: F401
    greedy_generate, make_decode_step, make_paged_decode_step,
    make_prefill_step,
)
