from .backend import ServeBackend, StreamEvent  # noqa: F401
from .elastic import ElasticController, ElasticPolicy  # noqa: F401
from .faults import (  # noqa: F401
    FaultInjector, ReplicaFailure, parse_fault_spec,
)
from .frontend import (  # noqa: F401
    ServeFrontend, ShedRejection, TenantPolicy, TokenStream,
)
from .kv_cache import PagedKVCache  # noqa: F401
from .options import ServeOptions  # noqa: F401
from .prefix import PrefixCache  # noqa: F401
from .recovery import RequestJournal  # noqa: F401
from .router import RequestRouter  # noqa: F401
from .scheduler import (  # noqa: F401
    SLO_CLASSES, Request, ServeEngine, default_bucket_edges,
)
from .spec import DraftModelDrafter, PromptLookupDrafter  # noqa: F401
from .telemetry import (  # noqa: F401
    MetricsRegistry, SpanEvent, Telemetry, check_spans, chrome_trace,
    merge_stats,
)
from .step import (  # noqa: F401
    ServePrograms, greedy_generate, make_chunk_prefill_step,
    make_decode_step, make_paged_decode_step, make_prefill_step,
    make_verify_step,
)

# serve.parallel (TPServePrograms) is imported lazily by ServeEngine:
# it pulls in mesh/shard_map machinery single-device serving never needs
