"""Seeded, deterministic fault injection for the serve stack.

A real fleet loses replicas: a process OOMs mid-step, a host wedges
and stops making progress, a network partition makes a replica
unreachable.  The serve stack's synthetic step clock lets us model all
of that *deterministically*: a fault is a scripted event keyed to a
replica's own step count, so a crash trace replays bit-for-bit from
its seed — the chaos analog of the stack's bitwise-exactness bar.

``FaultInjector`` wraps any ``ServeBackend`` (a bare engine, or each
replica inside a ``RequestRouter``) and proxies the full protocol.
Two fault shapes, mirroring how processes actually die:

* **crash** — at the scripted step, ``step()`` raises
  :class:`ReplicaFailure` and the replica is *permanently dead*: every
  subsequent call that would need the process — ``step``, ``submit``,
  ``extract``, ``extract_all``, ``cancel``, ``drain_events`` — raises
  too.  In particular the router canNOT rescue inflight requests via
  the graceful-drain path (``extract_all``); recovery must come from
  router-side state (serve/recovery.py's ``RequestJournal``).
  ``stats()`` stays readable — counters are the analog of externally
  scraped metrics, which survive the process they describe — so the
  router can fold the dead replica's dispatch history into its
  departed-stats accumulator and keep the fleet identities exact.
* **stall** — for N scripted rounds ``step()`` does nothing and
  reports busy: the replica is alive but makes no progress (a wedged
  host).  A stall shorter than the router's watchdog patience heals
  invisibly; a longer one gets the replica declared FAILED, which
  this wrapper then makes permanent (``mark_dead`` — once the router
  gives up on a replica, a late revival must not double-serve its
  requests).

Schedules come either from an explicit script (``crash_at=`` /
``stall_at=`` + ``stall_for=``) or from a seed
(:meth:`FaultInjector.seeded`), which draws the script from
``random.Random(seed)`` — replayable chaos for the fuzzer and the
fault benchmark.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

__all__ = ["ReplicaFailure", "FaultInjector", "parse_fault_spec"]


class ReplicaFailure(RuntimeError):
    """A replica died (or was declared dead): the wrapped backend is
    unresponsive and nothing can be extracted from it."""

    def __init__(self, uid: str, kind: str, msg: str = ""):
        self.uid = uid
        self.kind = kind                    # "crash" | "stall" | "dead"
        super().__init__(msg or f"replica {uid} {kind}")


class FaultInjector:
    """A ``ServeBackend`` proxy with a scripted fault schedule.

    The schedule is keyed to THIS wrapper's step count (the number of
    times ``step()`` has been called), not the global clock — a
    replica that joins late crashes the same number of steps into its
    own life regardless of when it joined, which keeps seeded traces
    stable under elastic churn.

    Attribute reads not named here (``cache``, ``waiting``, ``active``,
    ``max_batch``, ``uid``, ``tel``, ``finished``, ...) proxy to the
    wrapped backend: the router introspects replicas for affinity and
    load scoring, and that must keep working up to the instant of
    death (after which the router drops the replica anyway).
    """

    def __init__(self, backend, *, crash_at: Optional[int] = None,
                 stall_at: Optional[int] = None, stall_for: int = 0):
        if stall_for < 0:
            raise ValueError("stall_for must be >= 0")
        if stall_for and stall_at is None:
            raise ValueError("stall_for without stall_at")
        self._backend = backend
        self.crash_at = crash_at
        self.stall_at = stall_at
        self.stall_for = int(stall_for)
        self.n_steps = 0                    # step() calls on this wrapper
        self.dead = False
        self.fault_kind: Optional[str] = None

    # ------------------------------------------------------ construction
    @classmethod
    def seeded(cls, backend, seed: int, *, horizon: int = 64,
               p_crash: float = 0.5, min_stall: int = 4,
               max_stall: int = 12) -> "FaultInjector":
        """Draw one fault from ``random.Random(seed)``: a crash or a
        stall (probability ``p_crash`` of crashing) at a uniform step
        in ``[1, horizon]``.  Same seed -> same schedule, always."""
        rng = random.Random(seed)
        at = rng.randint(1, max(1, horizon))
        if rng.random() < p_crash:
            return cls(backend, crash_at=at)
        return cls(backend, stall_at=at,
                   stall_for=rng.randint(min_stall, max_stall))

    # ------------------------------------------------------------- kill
    def mark_dead(self, kind: str = "dead") -> None:
        """Point of no return: the router (or a test) declares this
        replica failed.  Idempotent; from here every protocol call
        raises ``ReplicaFailure``."""
        if not self.dead:
            self.dead = True
            self.fault_kind = self.fault_kind or kind

    def _alive(self) -> None:
        if self.dead:
            raise ReplicaFailure(self.uid, self.fault_kind or "dead")

    @property
    def stalled(self) -> bool:
        """True while inside the scripted stall window."""
        return (not self.dead and self.stall_at is not None
                and self.stall_at <= self.n_steps
                < self.stall_at + self.stall_for)

    # ---------------------------------------------------- ServeBackend
    def step(self, now: float = float("inf")) -> bool:
        self._alive()
        self.n_steps += 1
        if self.crash_at is not None and self.n_steps >= self.crash_at:
            self.dead = True
            self.fault_kind = "crash"
            raise ReplicaFailure(self.uid, "crash")
        if self.stalled:
            # wedged: no dispatch, no events, no progress — but the
            # process answers, so report busy while holding work
            return bool(self._backend.n_inflight)
        return self._backend.step(now)

    def submit(self, req) -> None:
        self._alive()
        self._backend.submit(req)

    def check_admissible(self, req) -> None:
        self._alive()
        self._backend.check_admissible(req)

    def drain_events(self):
        self._alive()
        return self._backend.drain_events()

    def extract(self, rid: int):
        self._alive()
        return self._backend.extract(rid)

    def extract_all(self):
        self._alive()
        return self._backend.extract_all()

    def cancel(self, rid: int) -> bool:
        self._alive()
        return self._backend.cancel(rid)

    def run(self, requests, **kw):
        # run() drives step() in a loop, so scripted faults fire the
        # same way; a crash propagates to the caller as it should
        self._alive()
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return list(self._backend.finished)

    def stats(self) -> Dict[str, float]:
        # deliberately NOT gated on _alive(): counters describe work
        # already done and survive the process (externally scraped),
        # and the router's crash-fold depends on reading them
        return self._backend.stats()

    @property
    def n_inflight(self) -> int:
        # readable after death: the router's failure handler needs to
        # know the dead replica held work (the requests themselves are
        # unreachable — that is what the journal is for)
        return self._backend.n_inflight

    @property
    def capacity(self) -> int:
        return self._backend.capacity

    # ------------------------------------------------------------ proxy
    def __getattr__(self, name):
        # everything else (cache, waiting, prefilling, active,
        # max_batch, uid, tel, finished, events, ...) reads through
        return getattr(self._backend, name)


def parse_fault_spec(spec: str) -> List[Tuple[int, Dict[str, int]]]:
    """Parse a CLI fault script: ``"0:crash@12,1:stall@8x5"`` ->
    ``[(0, {"crash_at": 12}), (1, {"stall_at": 8, "stall_for": 5})]``.
    Each segment is ``<replica_index>:<kind>@<step>[x<rounds>]``;
    ``rounds`` applies to stalls only.  Empty spec -> []."""
    out: List[Tuple[int, Dict[str, int]]] = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        idx, _, rest = part.partition(":")
        kind, _, when = rest.partition("@")
        if not (idx and kind and when):
            raise ValueError(f"bad fault segment {part!r}; want "
                             "'<replica>:<crash|stall>@<step>[x<n>]'")
        if kind == "crash":
            out.append((int(idx), {"crash_at": int(when)}))
        elif kind == "stall":
            at, _, dur = when.partition("x")
            out.append((int(idx), {"stall_at": int(at),
                                   "stall_for": int(dur or 4)}))
        else:
            raise ValueError(f"unknown fault kind {kind!r} in {part!r}")
    return out
