"""Serving steps: prefill (full-sequence -> cache), decode (one token
against the cache), and multi-token speculative verification.

Four program flavors:

* ``make_decode_step`` — lockstep batch against a contiguous cache; its
  ``greedy_generate`` driver is the *parity oracle* the continuous-
  batching engine (serve/scheduler.py) is token-exact against.
* ``make_paged_decode_step`` — per-request positions against a paged KV
  cache (serve/kv_cache.py); one jit'd program serves every mix of
  requests because the batch/page shapes are fixed.
* ``make_chunk_prefill_step`` — batched masked prompt ingestion
  (chunked prefill): one chunk each for up to B_pf co-ingesting
  requests per dispatch, inactive rows routed to the null page;
  context length bucketed by the scheduler.
* ``make_verify_step`` — score T = k+1 tokens per request in one pass
  (speculative decode); T = 1 is bit-for-bit one paged decode step.
* ``make_fused_step`` — the decode/verify rows AND the chunked-prefill
  rows of one engine step in a single dispatch (the steady-state
  uber-program); each half is bit-identical to its standalone program
  (models/lm.fused_step_paged spells out the disjointness argument).

Invariants every program in this module preserves (the engine's parity
guarantee composes out of them — docs/serving.md):

* **Fixed shapes, traced values** — batch size, chunk size, page-table
  width (per bucket), and T are compile-time constants; positions,
  lengths, and page ids are traced.  One compile serves every request
  mix, so numerics can never depend on *which* requests are batched.
* **Greedy argmax at the program boundary** — token selection happens
  inside the jit'd program in f32 logits; the host only ever sees
  int32 token ids, never logits to re-reduce.
* **The caller owns authoritative lengths/tables** — programs treat
  ``state["lengths"]`` / ``state["page_tables"]`` as read-only inputs
  (``decode_step_paged`` returns lengths+1 as a convenience the engine
  overrides); host bookkeeping in serve/kv_cache.py is the source of
  truth, which is what lets verification advance a *variable* number
  of positions per step.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["make_prefill_step", "make_decode_step",
           "make_paged_decode_step", "make_chunk_prefill_step",
           "make_verify_step", "make_fused_step", "greedy_generate",
           "ServePrograms"]


def make_prefill_step(model, max_len=None) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)
    return prefill_step


def make_decode_step(model, sample: str = "greedy") -> Callable:
    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt[:, None], cache
    return serve_step


def make_paged_decode_step(model, sample: str = "greedy",
                           tp_axis: Optional[str] = None) -> Callable:
    def paged_step(params, state, tokens):
        logits, state = model.decode_step_paged(params, state, tokens,
                                                tp_axis=tp_axis)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt[:, None], state
    return paged_step


def make_verify_step(model, sample: str = "greedy",
                     tp_axis: Optional[str] = None) -> Callable:
    """Speculative-verification step: score T tokens per request in one
    batched pass (token 0 = last confirmed token, 1..T-1 = draft) and
    return (greedy next-token ids (B, T), new page state).  Row b's
    ``nxt[b, t]`` is the target model's own prediction after consuming
    tokens 0..t — the host accepts the longest draft prefix that
    matches and takes ``nxt[b, a]`` as the free bonus token."""
    def verify_step(params, state, tokens):
        logits, state = model.verify_step_paged(params, state, tokens,
                                                tp_axis=tp_axis)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt, state
    return verify_step


def make_chunk_prefill_step(model, sample: str = "greedy",
                            tp_axis: Optional[str] = None) -> Callable:
    """Batched chunked-prefill step: ingest up to C prompt tokens each
    for up to B_pf requests into the paged cache in ONE dispatch and
    return (greedy next tokens (B_pf, 1), new page state).  Rows with
    ``n_valid[b] == 0`` are inactive (null-page routed); a row's token
    is only meaningful on the chunk that completes its prompt (it is
    that request's first generated token); other rows' logits are
    discarded by the engine.  Which requests share a dispatch can
    never change a row's numerics (models/lm.prefill_chunk_paged)."""
    def chunk_step(params, state, tokens, table_rows, starts, n_valid):
        logits, state = model.prefill_chunk_paged(
            params, state, tokens, table_rows, starts, n_valid,
            tp_axis=tp_axis)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt[:, None], state
    return chunk_step


def make_fused_step(model, sample: str = "greedy",
                    tp_axis: Optional[str] = None) -> Callable:
    """Fused engine step: one dispatch covering both halves of a
    steady-state iteration — the decode/verify rows (``tokens``
    (B, T) against ``state``'s tables/lengths) and the chunked-prefill
    rows (``p_tokens`` (B_pf, C) with their table rows / starts /
    valid counts).  Returns ``((d_nxt (B, T), p_nxt (B_pf, 1)), new
    page state)``: ``d_nxt`` is exactly what the decode (T == 1) or
    verify (T > 1) program would return, ``p_nxt`` exactly what the
    chunked-prefill program would return — the scheduler applies both
    with the same host logic as the unfused paths."""
    def fused_step(params, state, tokens, p_tokens, p_table_rows,
                   p_starts, p_n_valid):
        (d_logits, p_logits), state = model.fused_step_paged(
            params, state, tokens, p_tokens, p_table_rows, p_starts,
            p_n_valid, tp_axis=tp_axis)
        if sample == "greedy":
            d_nxt = jnp.argmax(d_logits, axis=-1).astype(jnp.int32)
            p_nxt = jnp.argmax(p_logits, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return (d_nxt, p_nxt[:, None]), state
    return fused_step


class ServePrograms:
    """The jit-compiled serving programs (decode / chunked prefill /
    verify) for one model, independent of any engine instance.

    Engines historically built their own ``jax.jit`` wrappers, which
    meant N replicas of the same model paid N compiles of the *same*
    program (jit caches are per-wrapper) — measured as the dominant
    cost of a multi-replica run at smoke sizes.  A ``ServePrograms``
    is built once and shared: every ``ServeEngine(programs=...)``
    reuses one compile cache across replicas.  The verify program is
    built lazily so non-speculative engines never trace it.

    The tensor-parallel counterpart (same attribute surface, programs
    shard_map'd over a mesh) is serve/parallel.py's
    ``TPServePrograms``; the engine treats the two interchangeably.
    """

    tp = 1          # single-device: no mesh, params/pages used as-is

    def __init__(self, model):
        self.model = model
        self.decode = jax.jit(make_paged_decode_step(model))
        self.chunk = jax.jit(make_chunk_prefill_step(model))
        self._verify = None
        self._fused = None

    @property
    def verify(self):
        if self._verify is None:
            self._verify = jax.jit(make_verify_step(self.model))
        return self._verify

    @property
    def fused(self):
        # lazy like verify: --no-fused engines never trace it
        if self._fused is None:
            self._fused = jax.jit(make_fused_step(self.model))
        return self._fused

    # sharding hooks (overridden by TPServePrograms)
    def prepare_params(self, params):
        return params

    def prepare_pages(self, pages):
        return pages


def greedy_generate(model, params, prompt_batch, n_steps: int,
                    cache_len: int):
    """Batched greedy decoding driver (example path, jit'd per step)."""
    step = jax.jit(make_decode_step(model))
    max_len = max(cache_len, prompt_batch["tokens"].shape[1] + n_steps)
    last, cache = jax.jit(make_prefill_step(model, max_len=max_len))(
        params, prompt_batch)
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for _ in range(n_steps - 1):
        tok, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
