"""Tensor-parallel serving: the paged decode / verify / chunked-prefill
programs shard_map'd over a ``tp`` mesh axis.

This is the scale-*up* half of distributed serving (serve/router.py is
the scale-*out* half): one engine, its KV memory system and attention
arithmetic sharded across devices.  The sharding layout is chosen so
the sharded engine is **bit-identical** to the single-device one — the
serve stack's token-parity guarantee survives the mesh:

* **What is sharded.**  Attention heads: wq/wk/wv (and their biases)
  are split on the head output dim, so shard i computes heads
  ``[i*H/tp, (i+1)*H/tp)`` — and the paged KV cache splits the same
  way, ``k_pages/v_pages: (L, P, ps, KVH/tp, Dh)`` per device, which
  is the memory-system scaling that motivates TP serving in the first
  place (a single chip's HBM bounds resident KV; tp chips bound tp×).
  The FFN hidden dim (wg/wu, gelu w1/b1) splits identically.
* **What is replicated.**  Page tables, lengths, tokens, norms, the
  embedding/unembedding table, and the contraction-side projections
  wo / wd (w2).  Every shard therefore holds the *full* residual
  stream and computes the (cheap) unembed redundantly.  The batched
  chunked-prefill program's per-row inputs (table rows, starts, valid
  counts) are control metadata like page tables and stay replicated
  too — only its gathered K/V context and page writes are sharded (on
  KVH, with the pages themselves).
* **Why it is bitwise.**  No cross-shard *reduction* ever runs.  Each
  shard's ops are exactly the head/hidden slice of the single-device
  ops (XLA computes each output element's contraction identically
  regardless of sibling columns), and the only collectives are
  ``all_gather``s — concatenations in mesh order — placed just before
  the replicated wo/wd projections (components._tp_gather_heads).  A
  psum-based megatron layout would be cheaper on interconnect but
  reorders the output-projection summation, breaking parity; on real
  hardware you would trade that consciously (docs/ARCHITECTURE.md).

The per-shard program body is the *unchanged* model code run on a
shard-local view: ``DecoderLM`` over a cfg with ``n_heads``,
``n_kv_heads`` and ``d_ff`` divided by tp (plus ``tp_axis`` gather
hooks).  Host-side scheduling (serve/scheduler.py, serve/kv_cache.py)
is untouched — page ids are device-agnostic, so the allocator, prefix
trie, COW and speculation bookkeeping cannot tell the engine is
sharded.

Scope: the dense scanned-attention family (``supports_paged_decode``
and ``cfg.moe is None`` — moe_block owns its own shard_map, which
cannot nest inside this one); tp must divide n_heads, n_kv_heads and
d_ff.  Development and CI run on forced-host-device CPU meshes
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``); the layout
is device-count-, not device-kind-, specific.
"""
from __future__ import annotations

from typing import Dict, Optional

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.mesh import make_mesh
from ..sharding.compat import shard_map_compat
from ..sharding.rules import SERVE_TP_AXIS, serve_tp_spec
from .step import (make_chunk_prefill_step, make_fused_step,
                   make_paged_decode_step, make_verify_step)

__all__ = ["TPServePrograms", "make_tp_mesh", "validate_tp",
           "tp_param_specs", "PAGE_SPEC"]

#: k_pages/v_pages (L, n_pages, page_size, KVH, Dh): sharded on the
#: KV-head axis, the serving analogue of the training rules' act_heads.
PAGE_SPEC = P(None, None, None, SERVE_TP_AXIS)


def make_tp_mesh(tp: int):
    """A 1-D ``tp``-axis mesh over the first ``tp`` local devices."""
    n = len(jax.devices())
    if tp > n:
        raise ValueError(f"tp={tp} exceeds {n} visible devices "
                         "(CPU dev: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    return make_mesh((tp,), (SERVE_TP_AXIS,))


def validate_tp(model, tp: int) -> None:
    cfg = model.cfg
    if not model.supports_paged_decode():
        raise ValueError(f"{cfg.name}: tensor-parallel serving covers "
                         "the paged-decode family only")
    if cfg.moe is not None:
        raise ValueError(f"{cfg.name}: MoE FFNs run their own "
                         "shard_map (components.moe_block), which "
                         "cannot nest inside the serving TP program")
    for dim, v in (("n_heads", cfg.n_heads),
                   ("n_kv_heads", cfg.n_kv_heads), ("d_ff", cfg.d_ff)):
        if v % tp:
            raise ValueError(f"{cfg.name}: tp={tp} does not divide "
                             f"{dim}={v}")


def tp_param_specs(model):
    """PartitionSpec pytree mirroring ``model.param_specs()`` under the
    serving TP layout (sharding/rules.serve_tp_spec per leaf)."""
    import jax.tree_util as jtu

    def leaf_spec(path, ps):
        return serve_tp_spec(path[-1].key, len(ps.shape))

    return jtu.tree_map_with_path(
        leaf_spec, model.param_specs(),
        is_leaf=lambda x: hasattr(x, "axes"))


def _local_model(model, tp: int):
    """Shard-local view: the same DecoderLM over a cfg whose sharded
    dims are divided by tp — inside shard_map the param shards *are*
    full tensors of this smaller model, so the model code runs
    unchanged (only the _tp_gather_heads hooks know about the mesh)."""
    cfg = model.cfg
    local = dataclasses.replace(
        cfg, n_heads=cfg.n_heads // tp, n_kv_heads=cfg.n_kv_heads // tp,
        d_ff=cfg.d_ff // tp)
    return type(model)(local)


class TPServePrograms:
    """Sharded counterpart of step.ServePrograms: same attribute
    surface (decode / chunk / verify callables with identical
    signatures, prepare_params / prepare_pages hooks), so ServeEngine
    uses either interchangeably — and N router replicas can share one
    instance to share one compile cache."""

    def __init__(self, model, *, tp: Optional[int] = None, mesh=None):
        if mesh is None:
            if tp is None or tp < 2:
                raise ValueError("TPServePrograms needs tp >= 2 or an "
                                 "explicit mesh")
            mesh = make_tp_mesh(tp)
        if SERVE_TP_AXIS not in mesh.axis_names:
            raise ValueError(f"mesh axes {mesh.axis_names} lack "
                             f"'{SERVE_TP_AXIS}'")
        self.mesh = mesh
        self.tp = mesh.shape[SERVE_TP_AXIS]
        validate_tp(model, self.tp)
        self.model = model
        self._local = _local_model(model, self.tp)
        self._pspecs = tp_param_specs(model)
        full_state = {"k_pages": PAGE_SPEC, "v_pages": PAGE_SPEC,
                      "page_tables": P(), "lengths": P()}
        kv_state = {"k_pages": PAGE_SPEC, "v_pages": PAGE_SPEC}
        self.decode = jax.jit(shard_map_compat(
            make_paged_decode_step(self._local, tp_axis=SERVE_TP_AXIS),
            mesh=mesh, in_specs=(self._pspecs, full_state, P()),
            out_specs=(P(), full_state), check_vma=False))
        # batched chunked prefill: (tokens, table_rows, starts,
        # n_valid) are per-row control metadata — replicated, like the
        # decode program's page tables; the heads of the gathered
        # context and the page scatter shard with kv_state
        self.chunk = jax.jit(shard_map_compat(
            make_chunk_prefill_step(self._local, tp_axis=SERVE_TP_AXIS),
            mesh=mesh,
            in_specs=(self._pspecs, kv_state, P(), P(), P(), P()),
            out_specs=(P(), kv_state), check_vma=False))
        self._verify = None
        self._fused = None
        self._params_cache: Dict[int, object] = {}

    @property
    def verify(self):
        if self._verify is None:
            full_state = {"k_pages": PAGE_SPEC, "v_pages": PAGE_SPEC,
                          "page_tables": P(), "lengths": P()}
            self._verify = jax.jit(shard_map_compat(
                make_verify_step(self._local, tp_axis=SERVE_TP_AXIS),
                mesh=self.mesh,
                in_specs=(self._pspecs, full_state, P()),
                out_specs=(P(), full_state), check_vma=False))
        return self._verify

    @property
    def fused(self):
        # the fused step's decode half takes the full masked state, its
        # prefill half the same replicated control metadata as chunk;
        # outputs are (replicated tokens, sharded page state) — so the
        # specs are exactly the union of decode's and chunk's
        if self._fused is None:
            full_state = {"k_pages": PAGE_SPEC, "v_pages": PAGE_SPEC,
                          "page_tables": P(), "lengths": P()}
            self._fused = jax.jit(shard_map_compat(
                make_fused_step(self._local, tp_axis=SERVE_TP_AXIS),
                mesh=self.mesh,
                in_specs=(self._pspecs, full_state, P(), P(), P(), P(),
                          P()),
                out_specs=((P(), P()), full_state), check_vma=False))
        return self._fused

    def prepare_params(self, params):
        """device_put ``params`` into the TP layout (cached by object
        identity so router replicas sharing one params tree also share
        one sharded copy; the original is kept referenced so a
        recycled id can never alias a dead tree)."""
        key = id(params)
        if key not in self._params_cache:
            shardings = jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), self._pspecs,
                is_leaf=lambda x: isinstance(x, P))
            self._params_cache[key] = (
                params, jax.device_put(params, shardings))
        return self._params_cache[key][1]

    def prepare_pages(self, pages):
        return jax.device_put(pages, NamedSharding(self.mesh, PAGE_SPEC))
