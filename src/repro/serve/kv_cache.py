"""Paged KV cache: fixed-size pages, a free-list allocator, per-slot
page tables, and copy-on-write prefix sharing.

This is the paper's "which operand stays resident" question applied to
decode: the KV cache is the stationary operand, and paging lets its
residency be managed per page-size token block instead of per
max-length sequence.  Prefix sharing extends the same discipline across
*requests*: identical prompt prefixes map to the same physical pages,
so N requests carrying one system prompt pay its KV cost once.

Device layout (for a scanned all-attention stack of L layers):

    k_pages, v_pages : (L, n_pages, page_size, KVH, Dh)   bf16
    page_tables      : (max_batch, max_pages_per_seq)     int32
    lengths          : (max_batch,)                       int32

Under tensor-parallel serving (serve/parallel.py) the page arrays are
sharded on the KVH axis — each device holds every page's slice of its
own KV heads — while page tables, lengths, and ALL of this module's
host-side bookkeeping stay replicated/device-agnostic: a page id means
"the same page on every shard", so allocation, refcounts, COW, the
prefix trie, and speculation rollback are untouched by the mesh.

Invariants the engine relies on (exercised by check_invariants and
tests/test_serve_engine.py):

* **Free-list discipline** — every page id in [1, n_pages) is either on
  the free list or referenced; a page is handed out by exactly one
  ``_acquire`` per reference and returns to the free list only when its
  refcount reaches zero.  No page is ever in both states.
* **Null-page masking** — page 0 is reserved: inactive batch slots and
  padding chunk rows carry all-zero page tables, so their (masked)
  writes land on page 0 instead of corrupting a live page.  The
  allocator never hands page 0 out and the trie never stores it.
* **Refcount >= 1 while referenced** — a page's refcount equals the
  number of slot page tables containing it plus one if a prefix-trie
  node owns it.  Shared pages (refcount > 1) are read-only: any write
  target with refcount > 1 is copied first (``_cow_page``), so eviction
  of one reader can never free a page another reader still gathers.
* **Compute dtype == page dtype** — pages store bf16 and the model
  computes in bf16, so K/V read back from pages is bit-identical to the
  in-flight K/V of whole-prompt prefill; the engine's token-parity
  guarantee (docs/serving.md) depends on this.
* **Speculative writes stay behind the same discipline** — a verify
  step writes K/V for a whole draft window before acceptance is known,
  so ``ensure_headroom(n_tokens=k+1)`` privatizes/allocates every page
  in the window *first*, and ``rollback_spec`` returns pages past the
  confirmed frontier afterwards; rejected positions inside kept pages
  are plain stale-past-``lengths`` data that masking already hides
  (docs/speculative.md walks the rollback invariants).

The manager is host-side Python (allocation is control flow, not math);
the page arrays live on device and are updated functionally by the
decode step / chunked-prefill scatter.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .prefix import PrefixCache

__all__ = ["PagedKVCache", "pages_needed"]

NULL_PAGE = 0


@jax.jit
def _copy_page(pages, src, dst):
    """pages[:, dst] <- pages[:, src] with *traced* page ids — one
    compile serves every copy-on-write (baking the ids in as constants
    would recompile per (src, dst) pair)."""
    page = lax.dynamic_slice_in_dim(pages, src, 1, axis=1)
    return lax.dynamic_update_slice_in_dim(pages, page, dst, axis=1)


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages a sequence of ``n_tokens`` occupies — the sizing helper
    for ``max_pages_per_seq`` (a request that prefills P tokens and
    generates G needs ``pages_needed(P + G, page_size)``)."""
    return max(1, math.ceil(n_tokens / page_size))


class PagedKVCache:
    def __init__(self, model, *, max_batch: int, n_pages: int,
                 page_size: int, max_pages_per_seq: int,
                 prefix_sharing: bool = True):
        cfg = model.cfg
        if not (model.scanned and model.first_dense == 0
                and set(cfg.layer_kinds) == {"attn"}):
            raise ValueError(
                "paged KV cache supports scanned all-attention stacks; "
                f"got layer kinds {set(cfg.layer_kinds)}")
        if n_pages < 2:
            raise ValueError("need at least the null page plus one")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_batch = max_batch
        self.max_pages_per_seq = max_pages_per_seq

        L = cfg.n_layers
        shape = (L, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        self.k_pages = jnp.zeros(shape, jnp.bfloat16)
        self.v_pages = jnp.zeros(shape, jnp.bfloat16)

        # host-side bookkeeping
        self._free: List[int] = list(range(n_pages - 1, NULL_PAGE, -1))
        self._ref = np.zeros((n_pages,), np.int32)
        self._tables: Dict[int, List[int]] = {}      # slot -> page ids
        self.page_tables = np.zeros((max_batch, max_pages_per_seq),
                                    np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.prefix = PrefixCache(page_size) if prefix_sharing else None
        # stats
        self.n_shared_tokens = 0
        self.n_cow = 0
        self.n_prefix_evictions = 0

    # ---------------------------------------------------------- refcount
    def _acquire(self, pid: int) -> None:
        self._ref[pid] += 1

    def _release(self, pid: int) -> None:
        self._ref[pid] -= 1
        assert self._ref[pid] >= 0, f"page {pid} over-released"
        if self._ref[pid] == 0:
            self._free.append(pid)

    # ------------------------------------------------------------ alloc
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return pages_needed(n_tokens, self.page_size)

    def _alloc_page(self, slot: int) -> Optional[int]:
        if not self._free:
            return None
        tbl = self._tables[slot]
        if len(tbl) >= self.max_pages_per_seq:
            return None
        pid = self._free.pop()
        self._acquire(pid)
        self.page_tables[slot, len(tbl)] = pid
        tbl.append(pid)
        return pid

    def _attach_page(self, slot: int, pid: int) -> None:
        tbl = self._tables[slot]
        self._acquire(pid)
        self.page_tables[slot, len(tbl)] = pid
        tbl.append(pid)

    def alloc_slot(self, slot: int, n_tokens: int, *,
                   prompt=None, reserve_tokens: int = 0) -> Optional[int]:
        """Claim pages for a fresh slot holding ``n_tokens`` prompt
        tokens, sharing any trie-resident prefix of ``prompt``.

        All-or-nothing; returns the number of prefix tokens whose KV is
        already resident (0 without a hit), or None if the allocator
        cannot cover the fresh pages plus one decode-headroom page plus
        ``reserve_tokens`` worth of replay growth (slot untouched).
        The first write-target page is made private (copy-on-write)
        before returning, so callers may scatter into
        ``pages[shared:]`` immediately.
        """
        assert slot not in self._tables, f"slot {slot} already allocated"
        need = self.pages_for(n_tokens)
        if need > self.max_pages_per_seq:
            return None
        matches: List = []
        shared = 0
        if prompt is not None and self.prefix is not None:
            matches, shared = self.prefix.lookup(prompt)
        fresh = need - len(matches)
        # a partial last match means position `shared` lands inside a
        # shared page -> one COW copy at admission
        cow = 1 if matches and shared < len(matches) * self.page_size \
            else 0
        reserve = 1 + (self.pages_for(n_tokens + reserve_tokens) - need)
        if fresh + cow + reserve > self.free_pages:
            return None
        self._tables[slot] = []
        for pid, _ in matches:
            self._attach_page(slot, pid)
        for _ in range(fresh):
            pid = self._alloc_page(slot)
            assert pid is not None    # free list checked above
        if cow:
            copied = self._cow_page(slot, len(matches) - 1)
            assert copied    # budgeted above
        self.lengths[slot] = shared
        self.n_shared_tokens += shared
        return shared

    def _cow_page(self, slot: int, idx: int) -> bool:
        """Give ``slot`` a private copy of its ``idx``-th page (no-op if
        already private).  Returns False if the free list is empty."""
        pid = self._tables[slot][idx]
        if self._ref[pid] == 1:
            return True
        if not self._free:
            return False
        new = self._free.pop()
        self._acquire(new)
        self._release(pid)
        self._tables[slot][idx] = new
        self.page_tables[slot, idx] = new
        src, dst = np.int32(pid), np.int32(new)
        self.k_pages = _copy_page(self.k_pages, src, dst)
        self.v_pages = _copy_page(self.v_pages, src, dst)
        self.n_cow += 1
        return True

    def ensure_headroom(self, slot: int, n_tokens: int = 1) -> bool:
        """Make sure the next ``n_tokens`` token writes (positions
        ``lengths[slot] .. lengths[slot] + n_tokens - 1``) each have a
        *private* page: grows the table at page boundaries, and copies
        a shared write target (copy-on-write — the page a finished
        request donated to the prefix trie must not be mutated by its
        own donor's decode).  ``n_tokens`` > 1 is the speculative-
        decode shape: a verify step writes K/V for the whole draft
        window before acceptance is known.

        Returns False if the allocator is exhausted (caller preempts or
        evicts).  Partial progress is kept — the call is idempotent, so
        the caller's make-room-and-retry loop converges without redoing
        COW copies: already-private pages and already-grown table
        entries satisfy their range check immediately on retry."""
        assert n_tokens >= 1
        start = int(self.lengths[slot])
        tbl = self._tables[slot]
        first = start // self.page_size
        last = (start + n_tokens - 1) // self.page_size
        for idx in range(first, last + 1):
            if idx < len(tbl):
                if not self._cow_page(slot, idx):
                    return False
            else:
                assert idx == len(tbl), (idx, len(tbl))
                if self._alloc_page(slot) is None:
                    return False
        return True

    def rollback_spec(self, slot: int) -> int:
        """Release speculative page growth past the write frontier
        (called after a verify step whose trailing draft tokens were
        rejected).  Keeps every page holding confirmed tokens *plus*
        the page the next write lands on; trailing pages — allocated by
        ``ensure_headroom(n_tokens > 1)`` for positions the request did
        not confirm — drop their slot reference and return to the free
        list (they were made private before the write, so refcount hits
        zero here unless another reader raced a share in, which the COW
        discipline forbids for write targets).  Rejected positions
        *inside* kept pages need no work at all: they sit past
        ``lengths[slot]``, where every attention mask already hides
        them, and the next confirmed write overwrites them in place.
        Returns the number of pages released."""
        tbl = self._tables[slot]
        keep = int(self.lengths[slot]) // self.page_size + 1
        freed = 0
        while len(tbl) > keep:
            pid = tbl.pop()
            self.page_tables[slot, len(tbl)] = NULL_PAGE
            self._release(pid)
            freed += 1
        return freed

    def free_slot(self, slot: int) -> None:
        """Drop every page reference of ``slot`` (eviction or
        completion); pages return to the free list only when no other
        slot and no trie node still references them."""
        for pid in self._tables.pop(slot):
            self._release(pid)
        self.page_tables[slot] = NULL_PAGE
        self.lengths[slot] = 0

    # ---------------------------------------------------------- sharing
    def register_prefix(self, slot: int, prompt) -> None:
        """Donate ``slot``'s prompt pages to the prefix trie (called
        once the prompt is fully ingested).  The trie takes its own
        reference on newly recorded pages, so they outlive the request;
        the donor's next write into a donated partial page triggers COW
        like any other shared write."""
        if self.prefix is None:
            return
        for pid in self.prefix.insert(prompt, self._tables[slot]):
            self._acquire(pid)

    def release_prefix_pages(self, n: int = 1) -> int:
        """Evict up to ``n`` LRU prefix-trie leaves, dropping their trie
        references (pages free once no slot uses them).  Returns the
        number of nodes evicted."""
        if self.prefix is None:
            return 0
        pages = self.prefix.pop_lru_leaves(n)
        for pid in pages:
            self._release(pid)
        self.n_prefix_evictions += len(pages)
        return len(pages)

    # ------------------------------------------------------- inspection
    def used_pages(self, slot: int) -> List[int]:
        return list(self._tables.get(slot, ()))

    def check_invariants(self) -> None:
        refs: Dict[int, int] = {}
        for slot, tbl in self._tables.items():
            assert len(tbl) == len(set(tbl)), \
                f"slot {slot} references a page twice"
            for p in tbl:
                refs[p] = refs.get(p, 0) + 1
        trie_pages = self.prefix.pages() if self.prefix is not None else []
        assert len(trie_pages) == len(set(trie_pages)), \
            "page owned by two trie nodes"
        for p in trie_pages:
            refs[p] = refs.get(p, 0) + 1
        assert NULL_PAGE not in refs, "null page referenced"
        assert NULL_PAGE not in self._free, "null page in free list"
        free = set(self._free)
        assert len(free) == len(self._free), "free list duplicate"
        for p in range(1, self.n_pages):
            assert self._ref[p] == refs.get(p, 0), \
                f"page {p}: refcount {self._ref[p]} != {refs.get(p, 0)}"
            assert (p in free) == (self._ref[p] == 0), \
                f"page {p}: free-list / refcount disagree"
        for slot, tbl in self._tables.items():
            assert len(tbl) >= self.pages_for(int(self.lengths[slot]))
