"""Paged KV cache: fixed-size pages, a free-list allocator, and
per-slot page tables.

This is the paper's "which operand stays resident" question applied to
decode: the KV cache is the stationary operand, and paging lets its
residency be managed per 16-token block instead of per max-length
sequence.  A request holds exactly ``ceil(len / page_size)`` pages at
any moment, so heavy-traffic decode packs many more sequences into the
same HBM than contiguous max-length allocation would.

Device layout (for a scanned all-attention stack of L layers):

    k_pages, v_pages : (L, n_pages, page_size, KVH, Dh)   bf16
    page_tables      : (max_batch, max_pages_per_seq)     int32
    lengths          : (max_batch,)                       int32

Page 0 is reserved as the *null page*: inactive batch slots carry an
all-zero page table, so their (masked) decode writes land there instead
of corrupting a live page.  The allocator never hands page 0 out.

The manager is host-side Python (allocation is control flow, not math);
the page arrays live on device and are updated functionally by the
decode step / prefill scatter.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["PagedKVCache", "pages_needed"]

NULL_PAGE = 0


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages a sequence of ``n_tokens`` occupies — the sizing helper
    for ``max_pages_per_seq`` (a request that prefills P tokens and
    generates G needs ``pages_needed(P + G, page_size)``)."""
    return max(1, math.ceil(n_tokens / page_size))


class PagedKVCache:
    def __init__(self, model, *, max_batch: int, n_pages: int,
                 page_size: int, max_pages_per_seq: int):
        cfg = model.cfg
        if not (model.scanned and model.first_dense == 0
                and set(cfg.layer_kinds) == {"attn"}):
            raise ValueError(
                "paged KV cache supports scanned all-attention stacks; "
                f"got layer kinds {set(cfg.layer_kinds)}")
        if n_pages < 2:
            raise ValueError("need at least the null page plus one")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_batch = max_batch
        self.max_pages_per_seq = max_pages_per_seq

        L = cfg.n_layers
        shape = (L, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        self.k_pages = jnp.zeros(shape, jnp.bfloat16)
        self.v_pages = jnp.zeros(shape, jnp.bfloat16)

        # host-side bookkeeping
        self._free: List[int] = list(range(n_pages - 1, NULL_PAGE, -1))
        self._tables: Dict[int, List[int]] = {}      # slot -> page ids
        self.page_tables = np.zeros((max_batch, max_pages_per_seq),
                                    np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)

    # ------------------------------------------------------------ alloc
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return pages_needed(n_tokens, self.page_size)

    def can_admit(self, prompt_len: int) -> bool:
        # prompt pages + one decode-headroom page
        return self.free_pages >= self.pages_for(prompt_len) + 1

    def _alloc_page(self, slot: int) -> Optional[int]:
        if not self._free:
            return None
        pid = self._free.pop()
        tbl = self._tables[slot]
        if len(tbl) >= self.max_pages_per_seq:
            self._free.append(pid)
            return None
        self.page_tables[slot, len(tbl)] = pid
        tbl.append(pid)
        return pid

    def alloc_slot(self, slot: int, n_tokens: int) -> bool:
        """Claim ``ceil(n_tokens / page_size)`` pages for a fresh slot.
        All-or-nothing; returns False (slot untouched) on exhaustion."""
        assert slot not in self._tables, f"slot {slot} already allocated"
        need = self.pages_for(n_tokens)
        if need > min(self.free_pages, self.max_pages_per_seq):
            return False
        self._tables[slot] = []
        for _ in range(need):
            pid = self._alloc_page(slot)
            assert pid is not None    # free list checked above
        self.lengths[slot] = n_tokens
        return True

    def ensure_headroom(self, slot: int) -> bool:
        """Make sure the next token write (at index ``lengths[slot]``)
        has a page; grows the table by one page at page boundaries.
        Returns False if the allocator is exhausted (caller preempts)."""
        need = int(self.lengths[slot]) // self.page_size
        tbl = self._tables[slot]
        if need < len(tbl):
            return True
        assert need == len(tbl), (need, len(tbl))
        return self._alloc_page(slot) is not None

    def free_slot(self, slot: int) -> None:
        """Return every page of ``slot`` to the free list (eviction or
        completion)."""
        for pid in self._tables.pop(slot):
            self._free.append(pid)
        self.page_tables[slot] = NULL_PAGE
        self.lengths[slot] = 0

    def used_pages(self, slot: int) -> List[int]:
        return list(self._tables.get(slot, ()))

    def check_invariants(self) -> None:
        used = [p for t in self._tables.values() for p in t]
        assert len(used) == len(set(used)), "page double-booked"
        assert NULL_PAGE not in used, "null page handed out"
        assert NULL_PAGE not in self._free, "null page in free list"
        assert sorted(used + self._free) == list(range(1, self.n_pages)), \
            "page leak"
        for slot, tbl in self._tables.items():
            assert len(tbl) >= self.pages_for(int(self.lengths[slot]))

    # ---------------------------------------------------------- device
    def write_prefill(self, slot: int, layer_kv: dict) -> None:
        """Scatter a contiguous prefill cache into this slot's pages.

        ``layer_kv`` is the scanned-stack cache entry from
        ``model.prefill``: {"k": (L, 1, S, KVH, Dh), "v": ...}.
        """
        S = int(self.lengths[slot])
        ps = self.page_size
        ids = jnp.asarray(self._tables[slot], jnp.int32)
        n = len(self._tables[slot])
        pad = n * ps - S
        for name, pages in (("k", "k_pages"), ("v", "v_pages")):
            x = layer_kv[name][:, 0].astype(jnp.bfloat16)   # (L, S, KVH, Dh)
            if pad:
                x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            x = x.reshape(x.shape[0], n, ps, *x.shape[2:])
            setattr(self, pages, getattr(self, pages).at[:, ids].set(x))

    def device_tables(self):
        return jnp.asarray(self.page_tables), jnp.asarray(self.lengths)
