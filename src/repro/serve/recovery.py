"""Journal-based crash recovery: the router-side request mirror.

A graceful drain (PR 8) rescues a replica's requests by *asking* it —
``extract_all`` returns each request at its confirmed-token frontier.
A crashed replica answers nothing (``serve/faults.py`` models this:
``extract`` raises), so everything needed to rebuild its requests must
already live on the router side.  That is the ``RequestJournal``:

* **assign** — when the router dispatches a request to a replica, the
  journal records the request object and which replica holds it.  The
  ``Request`` itself carries the durable inputs (prompt, budget,
  arrival, tenant, SLO class, trace).
* **observe** — the router drains every replica's stream events each
  step; the journal counts confirmed tokens per request as they flow
  past.  A token the router has *seen* is a token the client will get
  (it sits in router memory from that instant), so the journal's
  ``confirmed`` frontier is exactly the delivered-stream length.
  Finished/cancelled requests leave the journal.
* **lost** — on failure detection the journal surrenders the dead
  replica's entries.  Reconstruction truncates each request's
  ``generated`` to the journal frontier (tokens generated but never
  drained died with the process — greedy decoding re-derives them
  identically) and resets ingestion progress; re-admission on a
  survivor then rides the normal recompute-replay path, which is
  token-exact by construction.  Because the router drains events
  every step, the frontier in practice equals the full confirmed
  stream at the instant of death — nothing the client saw is ever
  re-sent, nothing it didn't see is ever skipped.

The journal is pure router-side bookkeeping: dict operations per
dispatch/event, no model work, and no effect on any dispatch decision
— an untouched (fault-free) run is bitwise- and dispatch-identical
with or without it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["JournalEntry", "RequestJournal"]


@dataclasses.dataclass
class JournalEntry:
    """One inflight request's mirror: where it is and how much of its
    stream the router has seen."""
    req: object                       # the live Request object
    replica: Optional[int]            # stable router id; None = queued
    confirmed: int = 0                # tokens drained past the router


class RequestJournal:
    def __init__(self) -> None:
        self._entries: Dict[int, JournalEntry] = {}   # rid -> entry

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries

    def entry(self, rid: int) -> Optional[JournalEntry]:
        return self._entries.get(rid)

    # --------------------------------------------------------- tracking
    def assign(self, req, replica: int) -> None:
        """Record (or re-record) a dispatch: ``req`` now lives on
        ``replica``.  The confirmed frontier persists across
        re-assignment — a migrated or recovered request keeps the
        stream it already delivered."""
        e = self._entries.get(req.rid)
        if e is None:
            self._entries[req.rid] = JournalEntry(
                req, replica, confirmed=len(req.generated))
        else:
            e.replica = replica

    def unassign(self, rid: int) -> None:
        """The request left its replica but stays live (migration /
        recovery re-queue): keep the frontier, drop the location."""
        e = self._entries.get(rid)
        if e is not None:
            e.replica = None

    def observe(self, events: Iterable) -> None:
        """Advance frontiers from drained ``StreamEvent``s; terminal
        events retire their entries (a finished stream needs no
        recovery, and its rid may be reused by a caller)."""
        for ev in events:
            e = self._entries.get(ev.rid)
            if e is None:
                continue
            e.confirmed += len(ev.tokens)
            if ev.finished:
                del self._entries[ev.rid]

    def discard(self, rid: int) -> None:
        """Forget a request (cancel / extract-by-caller): it no longer
        needs crash protection.  Idempotent."""
        self._entries.pop(rid, None)

    # --------------------------------------------------------- recovery
    def lost(self, replica: int) -> List[JournalEntry]:
        """Surrender every entry assigned to ``replica`` (it died):
        the entries leave the journal and are returned oldest-first
        (arrival, rid) for head-of-queue re-admission.  The caller
        re-``assign``s each survivor at its next dispatch."""
        hit = [e for e in self._entries.values()
               if e.replica == replica]
        for e in hit:
            del self._entries[e.req.rid]
        hit.sort(key=lambda e: (e.req.arrival, e.req.rid))
        return hit

    @staticmethod
    def reconstruct(entry: JournalEntry) -> Tuple[object, int]:
        """Rebuild a lost request for re-admission: truncate its
        stream to the journal-confirmed frontier (tokens beyond it
        never left the dead process; deterministic decode re-derives
        them bit-for-bit) and reset ingestion progress so prefill
        restarts from the prompt.  Returns ``(request,
        replay_burden)`` where the burden is the decode steps a
        survivor will spend replaying the confirmed stream."""
        req = entry.req
        del req.generated[entry.confirmed:]
        req.prefill_pos = 0
        return req, max(0, entry.confirmed - 1)
