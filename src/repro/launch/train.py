"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On a real pod this runs under one process per host with the production
mesh; on this box it runs the same code on the local mesh.  Fault
tolerance is live either way: checkpoint every N steps, restart from
LATEST, straggler events logged.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs
from repro.checkpoint import latest_step, restore_checkpoint
from repro.data.pipeline import SyntheticPipeline
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import build_model
from repro.models.base import abstract_params
from repro.runtime import DriverConfig, TrainDriver
from repro.sharding import tree_shardings
from repro.train.optimizer import OptConfig, init_opt_state, opt_state_specs
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = (configs.get_smoke if args.smoke else configs.get)(args.arch)
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    pshard = tree_shardings(model.param_specs(), mesh)
    oshard = tree_shardings(opt_state_specs(model.param_specs()), mesh)

    params = model.init(jax.random.PRNGKey(0))
    params = jax.device_put(params, pshard)
    opt_state = jax.device_put(init_opt_state(params), oshard)
    start = 0
    if latest_step(args.ckpt_dir) is not None:
        tree, start = restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt_state},
            shardings={"params": pshard, "opt": oshard})
        params, opt_state = tree["params"], tree["opt"]
        print(f"resumed from step {start}")

    pipe = SyntheticPipeline(cfg, batch=args.batch, seq=args.seq)
    step_fn = jax.jit(make_train_step(
        model, cfg, opt=OptConfig(lr=args.lr), n_micro=args.n_micro),
        out_shardings=(pshard, oshard, None))

    driver = TrainDriver(
        DriverConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every),
        lambda p, o, b: step_fn(p, o, b),
        lambda s: pipe.device_batch(s))
    with mesh:
        params, opt_state = driver.run(params, opt_state, start_step=start)
    for m in driver.metrics_log:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"{m['wall_s'] * 1e3:.0f} ms")
    print(f"done: {args.steps} steps; events: "
          f"{[(e.kind, e.step) for e in driver.events][-5:]}")


if __name__ == "__main__":
    main()
