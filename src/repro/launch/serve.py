"""Serving launcher: continuous-batching engine over the paged KV
cache (default), or the naive lockstep loop (--naive) for comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 16 --batch 8 --prompt-len 64 --gen 32 --rate 50

Distributed serving: ``--tp N`` shards every engine over an N-device
mesh (CPU dev: XLA_FLAGS=--xla_force_host_platform_device_count=N);
``--replicas M`` puts M engine replicas behind the request router
(``--router-policy prefix|least-loaded|round-robin``).  The two
compose.  Engine knobs (chunk size, page size, context buckets, prefix
sharing) are documented in docs/serving.md.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.serve import Request, RequestRouter, ServeEngine, ServePrograms
from repro.serve.kv_cache import pages_needed
from repro.serve.step import make_decode_step, make_prefill_step


def synth_requests(cfg, n: int, prompt_len: int, gen: int,
                   rate: float, seed: int = 0, prefix_len: int = 0):
    """Poisson arrival trace with markov-ish prompts (same generator
    family as the training pipeline).  ``prefix_len`` > 0 prepends one
    shared system-prompt prefix to every request (the prefix-cache
    benchmark shape)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))

    def walk(length):
        base = rng.integers(0, cfg.vocab_size)
        drift = rng.integers(0, 17, size=length)
        return ((base + np.cumsum(drift)) % cfg.vocab_size).astype(np.int32)

    # draw the prefix only when asked, so prefix_len=0 traces stay
    # draw-for-draw identical to earlier benchmarks at the same seed
    prefix = walk(prefix_len) if prefix_len else None
    reqs = []
    for i in range(n):
        prompt = walk(prompt_len)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gen,
                            arrival=float(arrivals[i])))
    return reqs


def run_engine(model, params, reqs, *, batch, page_size, n_pages,
               realtime, chunk_size=32, prefill_batch=1,
               prefix_sharing=True,
               bucket_edges=None, spec_k=0, drafter_factory=None,
               tp=1, replicas=1, router_policy="prefix"):
    """Serve ``reqs`` on ``replicas`` engine replicas (each of
    ``n_pages`` pages, sharded ``tp``-way when tp > 1) and return
    aggregate stats.  One ``ServePrograms`` bundle is shared by every
    replica — one compile cache regardless of fleet size."""
    if tp > 1:
        from repro.serve.parallel import TPServePrograms
        programs = TPServePrograms(model, tp=tp)
    else:
        programs = ServePrograms(model)
    mpps = max(pages_needed(len(r.prompt) + r.max_new_tokens, page_size)
               for r in reqs)

    def mk():
        return ServeEngine(model, params, max_batch=batch,
                           n_pages=n_pages, page_size=page_size,
                           max_pages_per_seq=mpps,
                           chunk_size=chunk_size,
                           prefill_batch=prefill_batch,
                           prefix_sharing=prefix_sharing,
                           bucket_edges=bucket_edges, spec_k=spec_k,
                           drafter=(drafter_factory() if drafter_factory
                                    else None),
                           programs=programs)

    if replicas > 1:
        front = RequestRouter([mk() for _ in range(replicas)],
                              policy=router_policy)
        engines = front.replicas
    else:
        front = mk()
        engines = [front]
    t0 = time.perf_counter()
    done = front.run(reqs, realtime=realtime)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    ttfts = [r.ttft for r in done if r.ttft is not None
             and r.ttft != float("inf")]
    drafted = sum(e.n_drafted for e in engines)
    n_pf_disp = sum(e.n_prefill_dispatches for e in engines)
    n_pf_chunks = sum(e.n_prefill_chunks for e in engines)
    return {"tokens": toks, "wall_s": dt,
            "tok_per_s": toks / max(dt, 1e-9),
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else float("nan"),
            "decode_steps": sum(e.n_decode_steps for e in engines),
            "prefill_chunks": n_pf_chunks,
            "prefill_dispatches": n_pf_disp,
            "prefill_rows_mean": n_pf_chunks / max(n_pf_disp, 1),
            "engine_stats": [e.stats() for e in engines],
            "shared_tokens": sum(e.cache.n_shared_tokens
                                 for e in engines),
            "cow_copies": sum(e.cache.n_cow for e in engines),
            "spec_rounds": sum(e.n_spec_rounds for e in engines),
            "drafted": drafted,
            "draft_accepted": sum(e.n_draft_accepted for e in engines),
            "accept_rate": sum(e.n_draft_accepted for e in engines)
            / max(drafted, 1),
            "dispatched": (front.n_dispatched if replicas > 1
                           else [len(done)]),
            "affinity_hits": (front.n_affinity_hits if replicas > 1
                              else 0)}


def run_naive(model, params, cfg, args):
    batch = SyntheticPipeline(cfg, batch=args.batch,
                              seq=args.prompt_len).device_batch(0)
    # decode headroom: without max_len the cache has prompt-length
    # capacity and decode writes clamp onto the last slot (wrong tokens)
    prefill = jax.jit(make_prefill_step(
        model, max_len=args.prompt_len + args.gen))
    step = jax.jit(make_decode_step(model))
    t0 = time.time()
    last, cache = prefill(params, batch)
    tok = np.argmax(np.asarray(last), -1).astype(np.int32)[:, None]
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    tok = jax.numpy.asarray(tok)
    for _ in range(args.gen - 1):
        tok, cache = step(params, cache, tok)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen} tokens/seq in {dt:.2f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s)")
    print("generated ids (first seq):", gen[0][:16], "...")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--naive", action="store_true",
                    help="lockstep greedy loop instead of the engine")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of system prompt shared by every "
                         "request (exercises the prefix cache)")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=0,
                    help="0 -> sized to the trace")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="prompt tokens ingested per engine step")
    ap.add_argument("--prefill-batch", type=int, default=0,
                    help="requests co-ingesting one prompt chunk each "
                         "per prefill dispatch (0 -> --batch; 1 -> "
                         "serialized PR 2 path; tokens are unchanged, "
                         "only dispatch count)")
    ap.add_argument("--stats", action="store_true",
                    help="dump per-engine counter stats (dispatches, "
                         "co-ingestion occupancy, cache reuse) after "
                         "the run")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the prefix cache (recompute every "
                         "prompt from scratch)")
    ap.add_argument("--bucket-edges", type=str, default="",
                    help="comma-separated context buckets in pages "
                         "(default: doubling)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens verified per engine step "
                         "(speculative decode; tokens are unchanged, "
                         "only faster)")
    ap.add_argument("--no-spec", action="store_true",
                    help="disable speculative decode (one token per "
                         "decode step)")
    ap.add_argument("--draft-config", type=str, default="",
                    help="arch id of a draft model for speculation "
                         "(default: model-free n-gram prompt lookup); "
                         "resolved at the same --smoke size as --arch")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: shard each engine's "
                         "attention heads, FFN and paged KV cache over "
                         "a tp-device mesh (token streams unchanged)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the request router "
                         "(each gets its own --n-pages pool)")
    ap.add_argument("--router-policy", type=str, default="prefix",
                    choices=["prefix", "least-loaded", "round-robin"],
                    help="replica selection: prefix affinity (default),"
                         " least outstanding tokens, or round-robin")
    args = ap.parse_args()

    cfg = (configs.get_smoke if args.smoke else configs.get)(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.naive:
        run_naive(model, params, cfg, args)
        return

    reqs = synth_requests(cfg, args.requests, args.prompt_len, args.gen,
                          args.rate, prefix_len=args.shared_prefix)
    total = args.shared_prefix + args.prompt_len + args.gen
    per_seq = pages_needed(total, args.page_size) + 1
    n_pages = args.n_pages or (1 + args.batch * per_seq
                               + pages_needed(max(args.shared_prefix, 1),
                                              args.page_size))
    edges = ([int(e) for e in args.bucket_edges.split(",")]
             if args.bucket_edges else None)
    spec_k = 0 if args.no_spec else args.spec_k
    drafter_factory = None
    if spec_k and args.draft_config:
        from repro.serve import DraftModelDrafter
        dcfg = (configs.get_smoke if args.smoke
                else configs.get)(args.draft_config)
        dmodel = build_model(dcfg)
        dparams = dmodel.init(jax.random.PRNGKey(1))

        # one drafter per replica: drafter state is keyed by batch slot
        def drafter_factory():
            return DraftModelDrafter(dmodel, dparams, cfg_target=cfg)
    stats = run_engine(model, params, reqs, batch=args.batch,
                       page_size=args.page_size, n_pages=n_pages,
                       realtime=True, chunk_size=args.chunk_size,
                       prefill_batch=args.prefill_batch or args.batch,
                       prefix_sharing=not args.no_prefix_sharing,
                       bucket_edges=edges, spec_k=spec_k,
                       drafter_factory=drafter_factory,
                       tp=args.tp, replicas=args.replicas,
                       router_policy=args.router_policy)
    spec_note = (f"{stats['spec_rounds']} verify rounds, "
                 f"accept rate {stats['accept_rate']:.2f} "
                 f"({stats['draft_accepted']}/{stats['drafted']} drafts), "
                 if spec_k else "")
    dist_note = ""
    if args.tp > 1 or args.replicas > 1:
        dist_note = (f"tp={args.tp} x {args.replicas} replica(s) "
                     f"[{args.router_policy}] "
                     f"dispatched {stats['dispatched']}, "
                     f"{stats['affinity_hits']} affinity hits, ")
    print(f"{args.requests} requests ({args.shared_prefix}+"
          f"{args.prompt_len}+{args.gen} tok) "
          f"batch={args.batch} pages={n_pages}x{args.page_size}: "
          f"{stats['tok_per_s']:.1f} tok/s, "
          f"TTFT {stats['ttft_mean_s'] * 1e3:.0f} ms, "
          f"{dist_note}"
          f"{stats['decode_steps']} decode steps, "
          f"{spec_note}"
          f"{stats['prefill_chunks']} prefill chunks in "
          f"{stats['prefill_dispatches']} dispatches "
          f"({stats['prefill_rows_mean']:.2f} rows/dispatch), "
          f"{stats['shared_tokens']} prefix tokens reused, "
          f"{stats['cow_copies']} COW copies")
    if args.stats:
        for i, es in enumerate(stats["engine_stats"]):
            print(f"engine[{i}] stats: "
                  + ", ".join(f"{k}={v:.2f}" if isinstance(v, float)
                              else f"{k}={v}" for k, v in es.items()))


if __name__ == "__main__":
    main()
