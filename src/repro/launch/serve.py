"""Batched-serving launcher: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticPipeline
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.serve.step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = (configs.get_smoke if args.smoke else configs.get)(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticPipeline(cfg, batch=args.batch,
                              seq=args.prompt_len).device_batch(0)

    prefill = jax.jit(make_prefill_step(model))
    step = jax.jit(make_decode_step(model))
    t0 = time.time()
    last, cache = prefill(params, batch)
    tok = np.argmax(np.asarray(last), -1).astype(np.int32)[:, None]
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    tok = jax.numpy.asarray(tok)
    for _ in range(args.gen - 1):
        tok, cache = step(params, cache, tok)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen} tokens/seq in {dt:.2f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s)")
    print("generated ids (first seq):", gen[0][:16], "...")


if __name__ == "__main__":
    main()
