"""Serving launcher: continuous-batching engine over the paged KV
cache (default), the async streaming front-end (--stream), or the
naive lockstep loop (--naive) for comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 16 --batch 8 --prompt-len 64 --gen 32 --rate 50

Distributed serving: ``--tp N`` shards every engine over an N-device
mesh (CPU dev: XLA_FLAGS=--xla_force_host_platform_device_count=N);
``--replicas M`` puts M engine replicas behind the request router
(``--router-policy prefix|least-loaded|round-robin``).  The two
compose.  ``--max-replicas N`` makes the fleet *elastic* instead:
replicas scale between ``--min-replicas`` and N with demand (control
round every ``--scale-interval`` steps), live requests migrating off
draining replicas with token streams unchanged.  ``--stream`` serves the same trace through ``ServeFrontend``
instead: per-request token streams, SLO classes (every 4th request is
interactive), and ``--tenant-weights`` fair sharing.  Engine knobs
(chunk size, page size, context buckets, prefix sharing) are
consolidated in ``repro.serve.ServeOptions`` and documented in
docs/serving.md.
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.serve import Request, ServeOptions
from repro.serve.kv_cache import pages_needed
from repro.serve.step import make_decode_step, make_prefill_step


def synth_requests(cfg, n: int, prompt_len: int, gen: int,
                   rate: float, seed: int = 0, prefix_len: int = 0):
    """Poisson arrival trace with markov-ish prompts (same generator
    family as the training pipeline).  ``prefix_len`` > 0 prepends one
    shared system-prompt prefix to every request (the prefix-cache
    benchmark shape)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))

    def walk(length):
        base = rng.integers(0, cfg.vocab_size)
        drift = rng.integers(0, 17, size=length)
        return ((base + np.cumsum(drift)) % cfg.vocab_size).astype(np.int32)

    # draw the prefix only when asked, so prefix_len=0 traces stay
    # draw-for-draw identical to earlier benchmarks at the same seed
    prefix = walk(prefix_len) if prefix_len else None
    reqs = []
    for i in range(n):
        prompt = walk(prompt_len)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gen,
                            arrival=float(arrivals[i])))
    return reqs


def serve_trace(opts: ServeOptions, model, params, reqs, *,
                realtime: bool = True, smoke: bool = False):
    """Serve ``reqs`` on the backend ``opts`` describes and return the
    aggregate stats dict the CLI prints (throughput, TTFT, dispatch
    and cache-reuse counters).  With ``opts.trace_out`` set, the run's
    telemetry (spans + step timeline + metrics) lands there as JSONL
    (scripts/trace_report.py reads it)."""
    front = opts.build(model, params, smoke=smoke)
    out = _drive(front, reqs, realtime=realtime)
    _write_trace(opts, front, realtime=realtime)
    return out


def _write_trace(opts: ServeOptions, backend, *, realtime: bool) -> None:
    tel = getattr(backend, "tel", None)
    if tel is None or not opts.trace_out:
        return
    tel.clock_label = "seconds" if realtime else "steps"
    tel.write_jsonl(opts.trace_out)
    print(f"telemetry: wrote {opts.trace_out}")


def _drive(front, reqs, *, realtime: bool):
    """Run the trace and aggregate counters through the backend's own
    ``stats()`` — the ``ServeBackend`` contract every backend (engine,
    router, elastic controller) implements.  Summing over the live
    replica list here would silently drop the work of replicas that
    left an elastic fleet mid-trace; the protocol's stats fold departed
    replicas in."""
    t0 = time.perf_counter()
    done = front.run(reqs, realtime=realtime)
    dt = time.perf_counter() - t0
    st = front.stats()
    # the (possibly routed, possibly elastic) fleet behind the front:
    # per-engine breakdowns read the live members
    router = getattr(front, "router", front)
    engines = getattr(router, "replicas", [front])
    toks = sum(len(r.generated) for r in done)
    ttfts = [r.ttft for r in done if r.ttft is not None
             and r.ttft != float("inf")]
    return {"tokens": toks, "wall_s": dt,
            "tok_per_s": toks / max(dt, 1e-9),
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else float("nan"),
            "decode_steps": st["n_decode_steps"],
            "fused_dispatches": st["n_fused_dispatches"],
            "total_dispatches": st["n_total_dispatches"],
            "prefill_chunks": st["n_prefill_chunks"],
            "prefill_dispatches": st["n_prefill_dispatches"],
            "prefill_rows_mean": st["prefill_rows_mean"],
            "engine_stats": [e.stats() for e in engines],
            "shared_tokens": st["n_shared_tokens"],
            "cow_copies": st["n_cow"],
            "spec_rounds": st["n_spec_rounds"],
            "drafted": st["n_drafted"],
            "draft_accepted": st["n_draft_accepted"],
            # derived by telemetry.merge_stats inside stats() — the
            # same formula per replica and fleet-wide
            "accept_rate": st["accept_rate"],
            "dispatched": list(getattr(router, "n_dispatched",
                                       [len(done)])),
            "affinity_hits": int(st.get("n_affinity_hits", 0)),
            # elastic-fleet counters (0 on fixed backends)
            "replicas_peak": int(st.get("n_replicas_peak",
                                        len(engines))),
            "scale_ups": int(st.get("n_scale_ups", 0)),
            "scale_downs": int(st.get("n_scale_downs", 0)),
            "migrations": int(st.get("n_migrations", 0))}


def run_engine(model, params, reqs, *, batch, page_size, n_pages,
               realtime, chunk_size=32, prefill_batch=1,
               prefix_sharing=True,
               bucket_edges=None, spec_k=0, drafter_factory=None,
               tp=1, replicas=1, router_policy="prefix"):
    """Deprecated: build a ``repro.serve.ServeOptions`` and call
    ``serve_trace`` (or ``opts.build(...).run(...)``) instead.  Kept
    for one release as a kwargs-compatible shim."""
    warnings.warn("run_engine is deprecated; use ServeOptions + "
                  "serve_trace", DeprecationWarning, stacklevel=2)
    opts = ServeOptions(batch=batch, page_size=page_size,
                        n_pages=n_pages, chunk_size=chunk_size,
                        prefill_batch=prefill_batch,
                        prefix_sharing=prefix_sharing,
                        bucket_edges=bucket_edges, spec_k=spec_k,
                        tp=tp, replicas=replicas,
                        router_policy=router_policy)
    front = opts.sized_for(reqs).build(model, params)
    if drafter_factory is not None and spec_k:
        # the shim predates ServeOptions.draft_config: splice the
        # caller's factory into the already-built backend
        for e in getattr(front, "replicas", [front]):
            e.drafter = drafter_factory()
    return _drive(front, reqs, realtime=realtime)


def run_stream(opts: ServeOptions, model, params, reqs, *,
               smoke: bool = False):
    """Serve the trace through the async front-end: submit each
    request when its arrival time comes due (wall clock), pump until
    every stream completes, and report per-SLO-class TTFT plus the
    per-tenant token split.  Every 4th request is interactive; tenants
    rotate round-robin through ``--tenant-weights`` names."""
    fe = opts.build_frontend(model, params, smoke=smoke, realtime=True)
    tenants = list(opts.tenant_weights) or ["default"]
    pending = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    streams = {}
    t0 = time.perf_counter()
    while pending or fe.busy:
        now = time.perf_counter() - t0
        while pending and pending[0].arrival <= now:
            r = pending.pop(0)
            r.tenant = tenants[r.rid % len(tenants)]
            r.slo_class = "interactive" if r.rid % 4 == 0 else "batch"
            streams[r.rid] = fe.submit_request(r)
        if not fe.pump() and pending:
            time.sleep(max(0.0, pending[0].arrival
                           - (time.perf_counter() - t0)))
    dt = time.perf_counter() - t0
    done = fe.completed
    toks = sum(len(r.generated) for r in done)
    print(f"stream: {len(done)} streams, {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s)")
    for cls in ("interactive", "batch"):
        ts = [r.ttft for r in done
              if r.slo_class == cls and r.ttft is not None]
        if ts:
            print(f"  {cls:<12} n={len(ts):<3} "
                  f"TTFT mean {np.mean(ts) * 1e3:.0f} ms "
                  f"p99 {np.percentile(ts, 99) * 1e3:.0f} ms")
    st = fe.stats()
    shares = {t: st[f"tenant_tokens[{t}]"] for t in tenants
              if f"tenant_tokens[{t}]" in st}
    if len(shares) > 1:
        print("  tenant tokens: "
              + ", ".join(f"{t}={int(v)}" for t, v in shares.items()))
    print(f"  {int(st['n_slo_preemptions'])} SLO preemptions, "
          f"{int(st['n_cancelled'])} cancelled")
    _write_trace(opts, fe, realtime=True)


def run_naive(model, params, cfg, args):
    batch = SyntheticPipeline(cfg, batch=args.batch,
                              seq=args.prompt_len).device_batch(0)
    # decode headroom: without max_len the cache has prompt-length
    # capacity and decode writes clamp onto the last slot (wrong tokens)
    prefill = jax.jit(make_prefill_step(
        model, max_len=args.prompt_len + args.gen))
    step = jax.jit(make_decode_step(model))
    t0 = time.time()
    last, cache = prefill(params, batch)
    tok = np.argmax(np.asarray(last), -1).astype(np.int32)[:, None]
    t_prefill = time.time() - t0
    out = [tok]
    t0 = time.time()
    tok = jax.numpy.asarray(tok)
    for _ in range(args.gen - 1):
        tok, cache = step(params, cache, tok)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen} tokens/seq in {dt:.2f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s)")
    print("generated ids (first seq):", gen[0][:16], "...")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--naive", action="store_true",
                    help="lockstep greedy loop instead of the engine")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of system prompt shared by every "
                         "request (exercises the prefix cache)")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--stats", action="store_true",
                    help="dump per-engine counter stats (dispatches, "
                         "co-ingestion occupancy, cache reuse) after "
                         "the run")
    ServeOptions.add_cli(ap)
    args = ap.parse_args()

    cfg = (configs.get_smoke if args.smoke else configs.get)(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.naive:
        run_naive(model, params, cfg, args)
        return

    reqs = synth_requests(cfg, args.requests, args.prompt_len, args.gen,
                          args.rate, prefix_len=args.shared_prefix)
    opts = ServeOptions.from_args(args).sized_for(
        reqs, shared_prefix=args.shared_prefix)

    if args.stream:
        run_stream(opts, model, params, reqs, smoke=args.smoke)
        return

    stats = serve_trace(opts, model, params, reqs, realtime=True,
                        smoke=args.smoke)
    spec_note = (f"{stats['spec_rounds']} verify rounds, "
                 f"accept rate {stats['accept_rate']:.2f} "
                 f"({stats['draft_accepted']}/{stats['drafted']} drafts), "
                 if opts.spec_k else "")
    dist_note = ""
    if opts.tp > 1 or opts.replicas > 1 or opts.max_replicas > 0:
        dist_note = (f"tp={opts.tp} x {opts.replicas} replica(s) "
                     f"[{opts.router_policy}] "
                     f"dispatched {stats['dispatched']}, "
                     f"{stats['affinity_hits']} affinity hits, ")
    if opts.max_replicas > 0:
        dist_note += (f"elastic {opts.min_replicas}.."
                      f"{opts.max_replicas} (peak "
                      f"{stats['replicas_peak']}, "
                      f"{stats['scale_ups']} up / "
                      f"{stats['scale_downs']} down, "
                      f"{stats['migrations']} migrations), ")
    print(f"{args.requests} requests ({args.shared_prefix}+"
          f"{args.prompt_len}+{args.gen} tok) "
          f"batch={opts.batch} pages={opts.n_pages}x{opts.page_size}: "
          f"{stats['tok_per_s']:.1f} tok/s, "
          f"TTFT {stats['ttft_mean_s'] * 1e3:.0f} ms, "
          f"{dist_note}"
          f"{stats['decode_steps']} decode steps, "
          f"{spec_note}"
          f"{stats['prefill_chunks']} prefill chunks in "
          f"{stats['prefill_dispatches']} dispatches "
          f"({stats['prefill_rows_mean']:.2f} rows/dispatch), "
          f"{stats['fused_dispatches']}/{stats['total_dispatches']} "
          f"launches fused, "
          f"{stats['shared_tokens']} prefix tokens reused, "
          f"{stats['cow_copies']} COW copies")
    if args.stats:
        for i, es in enumerate(stats["engine_stats"]):
            print(f"engine[{i}] stats: "
                  + ", ".join(f"{k}={v:.2f}" if isinstance(v, float)
                              else f"{k}={v}" for k, v in es.items()))


if __name__ == "__main__":
    main()
