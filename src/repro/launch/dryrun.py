import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (arch x shape x mesh) cell this lowers + compiles the real
step function (train_step / prefill / serve_step) against
ShapeDtypeStruct stand-ins on the production mesh, then records:

* ``memory_analysis``  — per-device bytes (proves HBM fit),
* ``cost_analysis``    — XLA's per-device FLOPs/bytes (loop bodies x1),
* loop-aware FLOPs/bytes/collective traffic from ``hlo_cost`` (the
  roofline inputs),
* the collective schedule by kind.

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
        --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs
from repro.configs import SHAPES, shape_by_name
from repro.data.specs import train_specs, train_axes, decode_token_specs
from repro.launch import hlo_cost
from repro.launch.mesh import HW, make_production_mesh
from repro.models import build_model
from repro.models.base import ParamSpec, abstract_params
from repro.sharding import DEFAULT_RULES, logical_spec, tree_shardings
from repro.train.optimizer import OptConfig, opt_state_specs
from repro.train.step import auto_microbatches, make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _shardings_for(spec_tree, mesh, rules=DEFAULT_RULES):
    return tree_shardings(spec_tree, mesh, rules)


def _batch_shardings(cfg, batch, seq, mesh, rules=DEFAULT_RULES):
    specs = train_specs(cfg, batch, seq)
    axes = train_axes(cfg, batch, seq)
    return specs, {
        k: NamedSharding(mesh, logical_spec(axes[k], v.shape, mesh, rules))
        for k, v in specs.items()}


def apply_overrides(cfg, overrides: dict):
    """dataclasses.replace with string-typed values from --set k=v."""
    import dataclasses
    typed = {}
    for k, v in overrides.items():
        field = {f.name: f for f in dataclasses.fields(cfg)}[k]
        t = field.type
        if t in ("int", int):
            typed[k] = int(v)
        elif t in ("float", float):
            typed[k] = float(v)
        elif t in ("bool", bool):
            typed[k] = v in ("1", "true", "True")
        else:
            typed[k] = v
    return dataclasses.replace(cfg, **typed)


def build_lowerable(arch: str, shape_name: str, mesh, rules=DEFAULT_RULES,
                    cfg=None):
    """Returns (jitted_fn, abstract_args, meta)."""
    cfg = cfg or configs.get(arch)
    shape = shape_by_name(shape_name)
    model = build_model(cfg)
    pspecs = model.param_specs()
    pshard = _shardings_for(pspecs, mesh, rules)
    pabs = abstract_params(pspecs)
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind}

    if shape.kind == "train":
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("pod", 1) * sizes.get("data", 1)
        n_micro = auto_microbatches(cfg, shape.global_batch, shape.seq_len,
                                    dp)
        meta["n_micro"] = n_micro
        opt = OptConfig(keep_master=(cfg.param_dtype != "float32"))
        step = make_train_step(model, cfg, opt=opt, n_micro=n_micro)
        ospecs = opt_state_specs(pspecs, opt)
        oshard = _shardings_for(ospecs, mesh, rules)
        oabs = abstract_params(ospecs)
        bspecs, bshard = _batch_shardings(cfg, shape.global_batch,
                                          shape.seq_len, mesh, rules)
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None))
        return fn, (pabs, oabs, bspecs), meta

    if shape.kind == "prefill":
        bspecs, bshard = _batch_shardings(cfg, shape.global_batch,
                                          shape.seq_len, mesh, rules)
        cshard = _shardings_for(model.cache_specs(shape.global_batch,
                                                  shape.seq_len), mesh, rules)
        lshard = NamedSharding(mesh, logical_spec(
            ("batch", "act_vocab"), (shape.global_batch, cfg.vocab_size),
            mesh, rules))
        fn = jax.jit(model.prefill, in_shardings=(pshard, bshard),
                     out_shardings=(lshard, cshard))
        return fn, (pabs, bspecs), meta

    # decode
    cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
    cshard = _shardings_for(cspecs, mesh, rules)
    cabs = abstract_params(cspecs)
    tok_sds, tok_axes = decode_token_specs(cfg, shape.global_batch)
    tshard = NamedSharding(mesh, logical_spec(tok_axes, tok_sds.shape,
                                              mesh, rules))
    lshard = NamedSharding(mesh, logical_spec(
        ("batch", "act_vocab"), (shape.global_batch, cfg.vocab_size),
        mesh, rules))
    fn = jax.jit(model.decode_step, in_shardings=(pshard, cshard, tshard),
                 out_shardings=(lshard, cshard))
    return fn, (pabs, cabs, tok_sds), meta


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             rules=DEFAULT_RULES, cfg=None, tag: str = "") -> dict:
    from repro.sharding import set_active_rules
    set_active_rules(rules)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    world = mesh.devices.size
    t0 = time.time()
    fn, args, meta = build_lowerable(arch, shape_name, mesh, rules, cfg=cfg)
    with mesh:
        lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    cost = hlo_cost.analyze_module(txt, world)

    cfg = cfg or configs.get(arch)
    shape = shape_by_name(shape_name)
    n_params = cfg.n_params()
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    factor = 6 if shape.kind == "train" else 2
    model_flops_global = factor * n_active * tokens
    model_flops_dev = model_flops_global / world

    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    t_compute = cost.flops / HW.peak_flops_bf16
    t_memory = cost.bytes / HW.hbm_bw
    t_coll = cost.coll_total / HW.ici_link_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "world": world, **meta, "tag": tag,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "hbm_budget_bytes": HW.hbm_bytes,
            "fits": bool(per_dev_bytes <= HW.hbm_bytes),
        },
        "xla_cost_analysis": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "loop_aware": {
            "flops": cost.flops,
            "bytes": cost.bytes,
            "transcendentals": cost.transcendentals,
            "collective_bytes": cost.coll_bytes,
            "collective_ops": cost.coll_ops,
            "collective_total_bytes": cost.coll_total,
        },
        "model_flops": {
            "n_params": n_params, "n_active_params": n_active,
            "global": model_flops_global, "per_device": model_flops_dev,
            "useful_ratio": (model_flops_dev / cost.flops
                             if cost.flops else 0.0),
        },
        "roofline": {
            **terms,
            "bottleneck": bottleneck.replace("_s", ""),
            "step_time_s": max(terms.values()),
            "roofline_fraction": (t_compute / max(terms.values())
                                  if max(terms.values()) > 0 else 0.0),
            "model_fraction": (model_flops_dev / HW.peak_flops_bf16
                               / max(terms.values())
                               if max(terms.values()) > 0 else 0.0),
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--set", dest="overrides", action="append", default=[],
                    help="config override key=value (e.g. loss_chunk=512)")
    ap.add_argument("--rules", default="default",
                    help="sharding-rules variant (default | sp)")
    ap.add_argument("--variant", default=None,
                    help="'opt' applies configs.OPT_SETTINGS per arch")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.overrides)
    from repro.sharding import RULE_VARIANTS
    rules = RULE_VARIANTS[args.rules]

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        todo = [(a, s.name) for a, s, skip in configs.cells()
                if skip is None]
        skips = [(a, s.name, skip) for a, s, skip in configs.cells()
                 if skip is not None]
        (out / "skips.json").write_text(json.dumps(
            [{"arch": a, "shape": s, "reason": r} for a, s, r in skips],
            indent=2))
    else:
        assert args.arch and args.shape
        todo = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in todo:
        for mesh_kind in meshes:
            name = f"{arch}__{shape}__{mesh_kind}"
            if args.tag != "baseline":
                name += f"__{args.tag}"
            path = out / f"{name}.json"
            if args.skip_existing and path.exists():
                print(f"[skip] {name}")
                continue
            try:
                cell_over, cell_rules = dict(overrides), rules
                if args.variant == "opt":
                    ov, rv = configs.opt_settings_for(arch, shape)
                    cell_over = {**ov, **cell_over}
                    cell_rules = RULE_VARIANTS[rv]
                cfg = apply_overrides(configs.get(arch), cell_over) \
                    if cell_over else None
                res = run_cell(arch, shape, mesh_kind, tag=args.tag,
                               cfg=cfg, rules=cell_rules)
                path.write_text(json.dumps(res, indent=2))
                r = res["roofline"]
                m = res["memory"]
                print(f"[ok] {name}: bottleneck={r['bottleneck']} "
                      f"step={r['step_time_s']:.4f}s "
                      f"frac={r['model_fraction']:.3f} "
                      f"mem={m['per_device_bytes']/1e9:.2f}GB "
                      f"fits={m['fits']} compile={res['compile_s']:.0f}s",
                      flush=True)
            except Exception as e:
                failures += 1
                print(f"[FAIL] {name}: {type(e).__name__}: {e}",
                      flush=True)
                (out / f"{name}.error.txt").write_text(
                    traceback.format_exc())
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
