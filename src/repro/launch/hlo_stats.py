"""Collective-traffic extraction from optimized (post-SPMD) HLO text.

``cost_analysis`` gives per-device FLOPs and bytes but not collective
traffic; we parse ``compiled.as_text()`` and sum, per collective kind,
the bytes each device puts on the interconnect:

    all-reduce         2 * size * (n-1)/n      (ring RS+AG)
    all-gather         size_out * (n-1)/n
    reduce-scatter     size_in  * (n-1)/n  (= size_out * (n-1))
    all-to-all         size * (n-1)/n
    collective-permute size

where n is the replica-group size parsed from the op (falling back to
the world size).  Shapes are the op's *result* shape — per-device in
post-SPMD HLO.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable

__all__ = ["CollectiveStats", "parse_collectives", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %all-gather.3 = bf16[16,512]{1,0} all-gather(%p), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
# tuple-result ops:  (bf16[..], bf16[..]) all-to-all(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*(?:\},?\{[^}]*)*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(n * b)


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:            # iota form: [num_groups, group_size]
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        ids = [t for t in first.replace("{", "").split(",") if t.strip()]
        if ids:
            return len(ids)
    return world


@dataclasses.dataclass
class CollectiveStats:
    ops: Dict[str, int]
    bytes_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_lines: Iterable[str], world: int
                      ) -> CollectiveStats:
    ops = {k: 0 for k in _COLL_KINDS}
    link_bytes = {k: 0.0 for k in _COLL_KINDS}
    for line in hlo_lines:
        if "-start" in line or any(k in line for k in _COLL_KINDS):
            m = _OP_RE.search(line)
            sizes = []
            kind = None
            if m:
                kind = m.group(3)
                sizes = [_shape_bytes(m.group(1), m.group(2))]
            else:
                mt = _TUPLE_RE.search(line)
                if mt:
                    kind = mt.group(2)
                    sizes = [_shape_bytes(d, s)
                             for d, s in _SHAPE_RE.findall(mt.group(1))]
            if kind is None:
                continue
            kind = kind.replace("-start", "")
            if kind.endswith("-done"):
                continue
            n = _group_size(line, world)
            size = sum(sizes)
            if kind == "all-reduce":
                moved = 2.0 * size * (n - 1) / n
            elif kind == "all-gather":
                moved = size * (n - 1) / n
            elif kind == "reduce-scatter":
                moved = size * (n - 1)          # result is 1/n of input
            elif kind == "all-to-all":
                moved = size * (n - 1) / n
            else:                               # collective-permute
                moved = size
            ops[kind] += 1
            link_bytes[kind] += moved
    return CollectiveStats(ops=ops, bytes_by_kind=link_bytes)
