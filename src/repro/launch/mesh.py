"""Production mesh definitions + TPU v5e hardware constants.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — the dry-run
driver sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
*before* any jax initialization; everything else sees 1 CPU device.
"""
from __future__ import annotations

import dataclasses

import jax

__all__ = ["make_mesh", "make_production_mesh", "make_local_mesh", "HW",
           "Hardware"]


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` was added after
    0.4.37 (where all axes are Auto by default)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))


@dataclasses.dataclass(frozen=True)
class Hardware:
    """TPU v5e-class chip (brief-provided constants)."""
    peak_flops_bf16: float = 197e12       # per chip
    hbm_bw: float = 819e9                  # B/s
    ici_link_bw: float = 50e9              # B/s per link
    hbm_bytes: float = 16e9                # capacity per chip
    ici_links_per_chip: int = 4            # 2-D torus (v5e)


HW = Hardware()
