"""Loop-aware cost model over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits each while-loop body **once**, so
scanned-layer models (all the deep configs here) undercount FLOPs/bytes
by the trip count.  This module re-derives the three roofline inputs
from ``compiled.as_text()`` with:

* ``known_trip_count`` multipliers on while bodies (fallback: the
  loop-condition comparison constant),
* fusion-boundary byte accounting (fusion internals are VMEM-resident:
  only fusion operands/results touch HBM),
* in-place update handling (dynamic-update-slice / scan carries alias
  their buffer: traffic is the update, not the buffer),
* collective-traffic accounting per kind with replica-group sizes
  (bytes each device puts on the interconnect).

Validated against ``cost_analysis()`` on loop-free programs in
``tests/test_hlo_cost.py``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_module", "HloCost"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count.....n.:.(\d+)')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")

_TRANSCENDENTAL = {
    "exponential", "log", "tanh", "sqrt", "rsqrt", "power", "logistic",
    "exponential-minus-one", "log-plus-one", "cosine", "sine", "atan2",
    "erf", "cbrt",
}
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare",
    "select", "clamp", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "is-finite",
}
_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "bitcast-convert", "copy-start", "copy-done", "domain",
    "opt-barrier", "custom-call",
}


@dataclasses.dataclass
class Shape:
    parts: List[Tuple[str, Tuple[int, ...]]]

    @property
    def bytes(self) -> float:
        total = 0.0
        for dt, dims in self.parts:
            n = 1
            for d in dims:
                n *= d
            total += n * DTYPE_BYTES.get(dt, 4)
        return total

    @property
    def elements(self) -> float:
        return sum(float(_prod(dims)) for _, dims in self.parts)


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _parse_shape(text: str) -> Shape:
    parts = [(dt, tuple(int(x) for x in dims.split(",")) if dims else ())
             for dt, dims in _SHAPE_RE.findall(text)]
    return Shape(parts)


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    shape: Shape
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, Shape]
    root: Optional[Op] = None


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLL_KINDS})
    coll_ops: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLL_KINDS})

    def scaled(self, m: float) -> "HloCost":
        return HloCost(
            self.flops * m, self.transcendentals * m, self.bytes * m,
            {k: v * m for k, v in self.coll_bytes.items()},
            {k: v * m for k, v in self.coll_ops.items()})

    def add(self, o: "HloCost") -> None:
        self.flops += o.flops
        self.transcendentals += o.transcendentals
        self.bytes += o.bytes
        for k in COLL_KINDS:
            self.coll_bytes[k] += o.coll_bytes[k]
            self.coll_ops[k] += o.coll_ops[k]

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())


def _split_operands(argstr: str) -> List[str]:
    """Operand names from an op's argument list (ignores literals).

    The pinned jax 0.4.37 emits *typed* operand lists —
    ``dot(f32[256,256]{1,0} %Arg_0.1, ...)`` — where naive
    comma-splitting yields dtype tokens (``f32``) instead of names, so
    every symtab lookup missed and dot contractions collapsed to 1
    (the recalibration bug behind the old test_hlo_cost xfails).  When
    ``%``-prefixed names are present they are authoritative; the bare
    fallback keeps hand-written HLO fixtures working."""
    if "%" in argstr:
        return re.findall(r"%([\w.\-]+)", argstr)
    out = []
    for tok in argstr.split(","):
        tok = tok.strip()
        m = re.match(r"([A-Za-z_][\w.\-]*)", tok)
        if m:
            out.append(m.group(1))
    return out


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(1), [], {})
                # parameters from the signature
                for pm in re.finditer(r"%?([\w.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)",
                                      m.group(2)):
                    cur.symtab[pm.group(1)] = _parse_shape(pm.group(2))
            continue
        if s == "}" or s.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(s)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        # rhs = "<shape> <kind>(<args>), attrs..."  (shape may be a tuple)
        km = re.match(r"(\(.*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
                      r"([\w\-]+)", rhs)
        if not km:
            continue
        shape = _parse_shape(km.group(1))
        kind = km.group(2)
        rest = rhs[km.end():]
        am = _OPERANDS_RE.search(rest)
        operands = _split_operands(am.group(1)) if am else []
        op = Op(name, kind, shape, operands, s)
        cur.symtab[name] = shape
        cur.ops.append(op)
        if s.startswith("ROOT"):
            cur.root = op
    return comps


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        if ids:
            return len(ids)
    return world


def _collective_cost(op: Op, world: int) -> Tuple[str, float]:
    base = op.kind.replace("-start", "")
    n = _group_size(op.line, world)
    size = op.shape.bytes
    if base == "all-reduce":
        moved = 2.0 * size * (n - 1) / n
    elif base == "all-gather":
        moved = size * (n - 1) / n
    elif base == "reduce-scatter":
        moved = size * (n - 1)
    elif base == "all-to-all":
        moved = size * (n - 1) / n
    else:
        moved = size
    return base, moved


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    cm = _COND_RE.search(op.line)
    if cm and cm.group(1) in comps:
        cond = comps[cm.group(1)]
        consts = re.findall(r"constant\((\d+)\)", "\n".join(
            o.line for o in cond.ops))
        if consts:
            return int(consts[-1])
    return 1


class _Analyzer:
    def __init__(self, comps: Dict[str, Computation], world: int):
        self.comps = comps
        self.world = world
        self._memo: Dict[Tuple[str, bool], HloCost] = {}

    def comp_cost(self, name: str, inside_fusion: bool) -> HloCost:
        key = (name, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = HloCost()          # cycle guard
        comp = self.comps[name]
        total = HloCost()
        for op in comp.ops:
            total.add(self.op_cost(op, comp, inside_fusion))
        self._memo[key] = total
        return total

    def _operand_bytes(self, op: Op, comp: Computation) -> float:
        return sum(comp.symtab[o].bytes for o in op.operands
                   if o in comp.symtab)

    def _param_slice_bytes(self, called: Computation) -> Dict[int, float]:
        """For each fusion parameter consumed *only* through
        dynamic-slice (a windowed read of a big stacked buffer — the
        scan-residual pattern), the true traffic is the slice, not the
        buffer.  Returns {param_index: effective_bytes}."""
        out: Dict[int, float] = {}
        params = [o for o in called.ops if o.kind == "parameter"]
        for idx, pop in enumerate(params):
            consumers = [o for o in called.ops
                         if pop.name in o.operands]
            if consumers and all(o.kind == "dynamic-slice"
                                 for o in consumers):
                out[idx] = sum(o.shape.bytes for o in consumers)
        return out

    def _fusion_io_bytes(self, op: Op, comp: Computation,
                         called: Optional[Computation]) -> float:
        out_b = op.shape.bytes
        slice_bytes = self._param_slice_bytes(called) if called else {}
        io = 0.0
        aliased = False
        root = called.root if called else None
        has_dus = called is not None and any(
            o.kind == "dynamic-update-slice" for o in called.ops)
        for i, name in enumerate(op.operands):
            sh = comp.symtab.get(name)
            if sh is None:
                continue
            if i in slice_bytes:
                io += slice_bytes[i]            # windowed read
            elif (has_dus and not aliased and sh.bytes == out_b
                  and root is not None):
                # in-place update of the scan-carry buffer: traffic is
                # the update window (read-modify-write), not the buffer
                aliased = True
                io += 2 * _update_bytes(called)
            else:
                io += sh.bytes
        if not aliased:
            io += out_b                          # result write
        return io

    def op_cost(self, op: Op, comp: Computation,
                inside_fusion: bool) -> HloCost:
        c = HloCost()
        kind = op.kind
        out_b = op.shape.bytes
        out_e = op.shape.elements

        if kind in _ZERO_COST:
            return c
        if kind == "while":
            bm = _BODY_RE.search(op.line)
            trips = _trip_count(op, self.comps)
            if bm and bm.group(1) in self.comps:
                c.add(self.comp_cost(bm.group(1), False).scaled(trips))
            return c
        if kind == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", op.line)
            names = []
            if branches:
                names = [b.strip().lstrip("%")
                         for b in branches[0].split(",")]
            else:
                names = re.findall(r"(?:true|false)_computation=%?([\w.\-]+)",
                                   op.line)
            costs = [self.comp_cost(n, False) for n in names
                     if n in self.comps]
            if costs:
                big = max(costs, key=lambda x: x.flops + x.bytes)
                c.add(big)
            return c
        if kind in ("call", "async-start"):
            cm = _CALLS_RE.search(op.line) or re.search(
                r"to_apply=%?([\w.\-]+)", op.line)
            if cm and cm.group(1) in self.comps:
                c.add(self.comp_cost(cm.group(1), inside_fusion))
            return c
        if kind == "fusion":
            cm = _CALLS_RE.search(op.line)
            called = self.comps.get(cm.group(1)) if cm else None
            if called is not None:
                inner = self.comp_cost(called.name, True)
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
            if not inside_fusion:
                c.bytes += self._fusion_io_bytes(op, comp, called)
            return c
        if kind.startswith(tuple(k for k in COLL_KINDS)) and \
                not kind.endswith("-done"):
            base, moved = _collective_cost(op, self.world)
            c.coll_bytes[base] += moved
            c.coll_ops[base] += 1
            if not inside_fusion:
                c.bytes += out_b
            return c
        if kind.endswith("-done"):
            return c

        # ---- FLOPs ------------------------------------------------------
        if kind in ("dot", "dot-general"):
            contracted = _dot_contracted(op, comp)
            c.flops += 2.0 * out_e * contracted
        elif kind == "convolution":
            k_elems = _conv_kernel_elems(op, comp)
            c.flops += 2.0 * out_e * k_elems
        elif kind in _TRANSCENDENTAL:
            c.transcendentals += out_e
            c.flops += out_e
        elif kind in _ELEMENTWISE or kind == "map":
            c.flops += out_e
        elif kind in ("reduce", "reduce-window"):
            in_e = sum(comp.symtab[o].elements for o in op.operands[:1]
                       if o in comp.symtab)
            c.flops += in_e
        elif kind == "sort":
            import math as _m
            c.flops += out_e * max(_m.log2(max(out_e, 2)), 1)
        elif kind in ("scatter",):
            upd = (comp.symtab[op.operands[2]].elements
                   if len(op.operands) > 2 and op.operands[2] in comp.symtab
                   else out_e)
            c.flops += upd

        # ---- bytes (HBM traffic at op granularity) -----------------------
        if not inside_fusion:
            if kind == "dynamic-update-slice":
                upd = (comp.symtab[op.operands[1]].bytes
                       if len(op.operands) > 1 and op.operands[1]
                       in comp.symtab else 0.0)
                c.bytes += 2 * upd
            elif kind == "dynamic-slice":
                c.bytes += 2 * out_b
            elif kind == "gather":
                idx = (comp.symtab[op.operands[1]].bytes
                       if len(op.operands) > 1 and op.operands[1]
                       in comp.symtab else 0.0)
                c.bytes += 2 * out_b + idx
            elif kind == "scatter":
                upd_b = (comp.symtab[op.operands[2]].bytes
                         if len(op.operands) > 2 and op.operands[2]
                         in comp.symtab else out_b)
                c.bytes += 3 * upd_b
            else:
                c.bytes += self._operand_bytes(op, comp) + out_b
        return c


def _update_bytes(comp: Optional[Computation]) -> float:
    if comp is None or comp.root is None:
        return 0.0
    root = comp.root
    if root.kind == "dynamic-update-slice" and len(root.operands) > 1:
        sh = comp.symtab.get(root.operands[1])
        if sh:
            return sh.bytes
    # root wraps a dus (bitcast chains): find any dus op
    for op in comp.ops:
        if op.kind == "dynamic-update-slice" and len(op.operands) > 1:
            sh = comp.symtab.get(op.operands[1])
            if sh:
                return sh.bytes
    return 0.0


def _dot_contracted(op: Op, comp: Computation) -> float:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    lhs = comp.symtab.get(op.operands[0]) if op.operands else None
    if not m or lhs is None or not lhs.parts:
        return 1.0
    dims = lhs.parts[0][1]
    idx = [int(x) for x in m.group(1).split(",") if x.strip()]
    return float(_prod([dims[i] for i in idx if i < len(dims)]) or 1)


def _conv_kernel_elems(op: Op, comp: Computation) -> float:
    if len(op.operands) > 1 and op.operands[1] in comp.symtab:
        rhs = comp.symtab[op.operands[1]]
        if rhs.parts:
            dims = rhs.parts[0][1]
            # kernel spatial * input-feature elems (all but out-features)
            return float(_prod(dims) / max(dims[-1], 1)) \
                if dims else 1.0
    return 1.0


def top_contributors(text: str, world: int, n: int = 25,
                     by: str = "bytes") -> List[Tuple[str, float]]:
    """Per-op contributions (loop multipliers applied) sorted by
    ``by`` in {"bytes", "flops"} — the profile for the hypothesis loop."""
    comps = parse_module(text)
    if not comps:
        return []
    called = set()
    for comp in comps.values():
        for op in comp.ops:
            for pat in (_CALLS_RE, _BODY_RE, _COND_RE):
                m = pat.search(op.line)
                if m:
                    called.add(m.group(1))
    roots = [nm for nm in comps if nm not in called]
    entry = next((nm for nm in roots if "main" in nm), roots[0])
    an = _Analyzer(comps, world)
    rows: List[Tuple[str, float]] = []

    def walk(comp_name: str, mult: float, prefix: str):
        comp = comps[comp_name]
        for op in comp.ops:
            if op.kind == "while":
                bm = _BODY_RE.search(op.line)
                trips = _trip_count(op, comps)
                if bm and bm.group(1) in comps:
                    walk(bm.group(1), mult * trips, prefix + op.name + "/")
                continue
            c = an.op_cost(op, comp, False)
            val = (c.bytes if by == "bytes" else
                   c.coll_total if by == "coll" else c.flops)
            if val:
                opnds = [comp.symtab[o].parts for o in op.operands[:4]
                         if o in comp.symtab]
                rows.append((prefix + f"{op.kind}:{op.name} "
                             f"out={op.shape.parts} in={opnds}",
                             val * mult))
    walk(entry, 1.0, "")
    rows.sort(key=lambda r: -r[1])
    return rows[:n]


def analyze_module(text: str, world: int, entry: Optional[str] = None
                   ) -> HloCost:
    comps = parse_module(text)
    if not comps:
        return HloCost()
    if entry is None:
        # heuristic: ENTRY computation is the one named main-ish, else the
        # one not called by anyone
        called = set()
        for comp in comps.values():
            for op in comp.ops:
                for pat in (_CALLS_RE, _BODY_RE, _COND_RE):
                    m = pat.search(op.line)
                    if m:
                        called.add(m.group(1))
        roots = [n for n in comps if n not in called]
        entry = next((n for n in roots if "main" in n), roots[0])
    return _Analyzer(comps, world).comp_cost(entry, False)
