"""Performance/energy model of the RISC-NN machine (paper §4, Table 2).

An event-driven model at *ExeBlock-stage* granularity: each PE has four
decoupled units (LD / CAL / FLOW / ST) plus an Instruction Loader, all of
which process their stage queues concurrently (paper Fig 5).  Shared
resources — the DDR4 channel behind the memory-controller cache, and the
two data NoCs — are modelled as servers with finite bandwidth, which is
what produces the multi-instance contention sweet spots of Table 7.

The cache is a real set-associative LRU simulated over the word-address
trace of every LD/ST (instruction loads bypass it, paper §3.10).

Outputs: makespan (cycles), MAC-unit utilisation (Figs 11/12), DRAM and
per-NoC traffic (Figs 13/14), and energy via :mod:`repro.core.energy`
(Figs 15/16/19/22/23).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .energy import DEFAULT_ENERGY, EnergyModel
from .exeblock import ExecutionGraph, ExeBlock
from .isa import Op, Stage

__all__ = ["MachineConfig", "SimResult", "simulate"]


@dataclass(frozen=True)
class MachineConfig:
    """Table 2 defaults."""
    n_pes: int = 64
    simd: int = 8
    freq_ghz: float = 1.887
    # DDR4-2400, one 64-bit channel: 19.2 GB/s -> bytes per core cycle
    dram_bw_bytes_cycle: float = 19.2 / 1.887
    dram_latency_cycles: int = 120
    cache_bytes: int = 1 << 20
    cache_ways: int = 4
    cache_line: int = 64
    cache_slices: int = 8
    cache_bw_bytes_cycle: float = 8 * 16.0   # 8 slices x 128-bit
    noc_flit_bytes: int = 16                  # 128-bit data NoCs
    hop_cycles: int = 1
    ld_issue_cycles: float = 1.0
    st_issue_cycles: float = 1.0
    cal_cycles_per_instr: float = 1.0
    copy_cycles_per_instr: float = 1.0
    instr_bytes: int = 8                      # 64-bit instructions
    #: aggregate inter-PE NoC bandwidth.  The 8x8 mesh has 2*2*8*7
    #: directed links x 16 B/cycle; multicast-tree traffic (FLOW) is
    #: neighbour-dominated, so the effective aggregate is far above the
    #: bisection.  We use 32 concurrent links as the serviceable
    #: aggregate (conservative vs. the 224-link ceiling).
    interpe_bw_bytes_cycle: float = 32 * 16.0

    @property
    def word_bytes(self) -> int:
        return self.simd * 2  # SIMD x 16-bit

    @property
    def mesh_side(self) -> int:
        return int(math.isqrt(self.n_pes))

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.n_pes * self.simd


class _LRUCache:
    """Set-associative LRU over line addresses."""

    def __init__(self, cfg: MachineConfig) -> None:
        self.line = cfg.cache_line
        self.ways = cfg.cache_ways
        self.n_sets = cfg.cache_bytes // (cfg.cache_line * cfg.cache_ways)
        self.sets: List[Dict[int, int]] = [dict() for _ in range(self.n_sets)]
        self.tick = 0
        self.hits = 0
        self.misses = 0
        self.writebacks = 0
        self.dirty: set = set()

    def access(self, byte_addr: int, write: bool) -> bool:
        """Returns True on hit.  Allocate-on-miss, write-back policy."""
        self.tick += 1
        line = byte_addr // self.line
        s = self.sets[line % self.n_sets]
        if line in s:
            s[line] = self.tick
            self.hits += 1
            if write:
                self.dirty.add(line)
            return True
        self.misses += 1
        if len(s) >= self.ways:
            victim = min(s, key=s.get)
            del s[victim]
            if victim in self.dirty:
                self.dirty.discard(victim)
                self.writebacks += 1
        s[line] = self.tick
        if write:
            self.dirty.add(line)
        return False


@dataclass
class SimResult:
    cycles: float
    mac_utilization: float          # arithmetic-CAL busy / (PEs x cycles)
    madd_utilization: float         # MADD-only (the paper's MAC metric)
    dram_bytes: float               # off-chip traffic (misses + wb + instr)
    mem_noc_bytes: float
    interpe_noc_bytes: float
    ctrl_noc_bytes: float
    cache_hit_rate: float
    energy_pj: float
    energy_breakdown: Dict[str, float]
    executed_cal_instrs: int
    executed_instrs: int

    @property
    def time_us(self) -> float:
        return self.cycles / (1.887e3)

    def ops(self) -> float:
        """Total lane-ops (a MAC = 2 ops, paper Table 2)."""
        return self.executed_cal_instrs * 2  # per-lane handled by caller

    def summary(self) -> Dict[str, float]:
        return {
            "cycles": self.cycles,
            "mac_util": self.mac_utilization,
            "madd_util": self.madd_utilization,
            "dram_bytes": self.dram_bytes,
            "mem_noc_bytes": self.mem_noc_bytes,
            "interpe_noc_bytes": self.interpe_noc_bytes,
            "cache_hit_rate": self.cache_hit_rate,
            "energy_pj": self.energy_pj,
        }


def _pe_xy(pe: int, side: int) -> Tuple[int, int]:
    return pe % side, pe // side


def _mem_hops(pe: int, cfg: MachineConfig) -> int:
    """Hops from a PE to its nearest edge memory-controller slice
    (controllers sit on the mesh edge, paper Fig 1)."""
    x, y = _pe_xy(pe, cfg.mesh_side)
    return min(y, cfg.mesh_side - 1 - y) + 1


def _pe_hops(a: int, b: int, cfg: MachineConfig) -> int:
    ax, ay = _pe_xy(a, cfg.mesh_side)
    bx, by = _pe_xy(b, cfg.mesh_side)
    return abs(ax - bx) + abs(ay - by)


@dataclass
class _Unit:
    free_at: float = 0.0
    busy: float = 0.0

    def acquire(self, ready: float, service: float) -> Tuple[float, float]:
        start = max(ready, self.free_at)
        end = start + service
        self.free_at = end
        self.busy += service
        return start, end


@dataclass
class _Server:
    """Shared bandwidth server (DRAM channel / inter-PE NoC aggregate)."""
    bw: float
    free_at: float = 0.0
    bytes_served: float = 0.0

    def transfer(self, ready: float, nbytes: float,
                 latency: float = 0.0) -> float:
        if nbytes <= 0:
            return ready
        start = max(ready, self.free_at)
        end = start + nbytes / self.bw
        self.free_at = end
        self.bytes_served += nbytes
        return end + latency


def simulate(graph: ExecutionGraph, cfg: MachineConfig = MachineConfig(),
             energy: EnergyModel = DEFAULT_ENERGY,
             sparse_cal_fraction: Optional[float] = None) -> SimResult:
    """Run the performance model over an ExecutionGraph.

    ``sparse_cal_fraction`` overrides nothing — sparse skipping comes from
    the blocks' own ``executed_pcs()``; the arg is accepted for ablations
    that scale CAL work analytically (None = faithful).
    """
    ld_u = [_Unit() for _ in range(cfg.n_pes)]
    cal_u = [_Unit() for _ in range(cfg.n_pes)]
    flow_u = [_Unit() for _ in range(cfg.n_pes)]
    st_u = [_Unit() for _ in range(cfg.n_pes)]
    loader_u = [_Unit() for _ in range(cfg.n_pes)]
    dram = _Server(bw=cfg.dram_bw_bytes_cycle)
    interpe = _Server(bw=cfg.interpe_bw_bytes_cycle)
    cache_srv = _Server(bw=cfg.cache_bw_bytes_cycle)   # shared front-end
    cache = _LRUCache(cfg)

    e = {k: 0.0 for k in ("cal", "opm", "iram", "ctrl", "noc", "cache",
                          "dram", "instr_load")}
    mem_noc_bytes = 0.0
    ctrl_noc_bytes = 0.0
    exec_cal = 0
    exec_madd_cycles = 0
    exec_instrs = 0
    makespan = 0.0
    instr_loaded: Dict[Tuple[int, str], float] = {}

    for task in graph.tasks:
        order = task.topo_order()
        flow_end: Dict[Tuple[str, int], float] = {}
        task_enable = makespan  # host enables tasks consecutively
        ctrl_noc_bytes += cfg.n_pes * 11  # 85-bit task-enable broadcast

        for r in range(task.repeats):
            for b in order:
                pe = b.logical_pe % cfg.n_pes
                pcs = b.executed_pcs()
                instrs = [b.instrs[pc] for pc in pcs]
                n_ld = sum(1 for i in instrs if i.op is Op.LD)
                n_st = sum(1 for i in instrs if i.op is Op.ST)
                n_copy = sum(1 for i in instrs if i.op is Op.COPY)
                cal_instrs = [i for i in instrs if i.stage is Stage.CAL]
                n_cal = len(cal_instrs)
                n_madd = sum(1 for i in cal_instrs if i.op is Op.MADD)

                # ---- instruction loading (once per block: ExeBlock Reuse)
                key = (task.task_id, b.name)
                if key not in instr_loaded:
                    ib = len(b.instrs) * cfg.instr_bytes
                    s, done = loader_u[pe].acquire(task_enable,
                                                   ib / cfg.dram_bw_bytes_cycle)
                    done = dram.transfer(s, ib, cfg.dram_latency_cycles)
                    loader_u[pe].free_at = done
                    instr_loaded[key] = done
                    e["instr_load"] += ib * energy.e_dram_per_byte_pj
                    mem_noc_bytes += ib
                    e["noc"] += (ib / cfg.noc_flit_bytes) * _mem_hops(pe, cfg) \
                        * energy.e_noc_hop_per_flit_pj
                inst_ready = instr_loaded[key]

                # ---- LD stage
                ld_ready = max(task_enable, inst_ready)
                hit_b = miss_b = 0.0
                for i in instrs:
                    if i.op is Op.LD:
                        addr = (task.ld_base + ((i.f1 << 16) | i.f2)) \
                            * cfg.word_bytes
                        if cache.access(addr, write=False):
                            hit_b += cfg.word_bytes
                        else:
                            miss_b += cfg.word_bytes
                if n_ld:
                    issue = n_ld * cfg.ld_issue_cycles
                    s, _ = ld_u[pe].acquire(ld_ready, issue)
                    # hit traffic contends on the shared cache front-end
                    # (8 slices): this is what separates the reuse
                    # schemes in steady state — LD pressure.
                    hit_done = cache_srv.transfer(s, hit_b)
                    dram_done = dram.transfer(s, miss_b,
                                              cfg.dram_latency_cycles
                                              if miss_b else 0)
                    ld_end = max(s + issue, hit_done, dram_done) \
                        + _mem_hops(pe, cfg) * cfg.hop_cycles
                    ld_u[pe].free_at = ld_end
                    nbytes = n_ld * cfg.word_bytes
                    mem_noc_bytes += nbytes
                    e["cache"] += (n_ld) * energy.e_cache_access_pj
                    e["dram"] += miss_b * energy.e_dram_per_byte_pj
                    e["noc"] += (nbytes / cfg.noc_flit_bytes) \
                        * _mem_hops(pe, cfg) * energy.e_noc_hop_per_flit_pj
                    e["opm"] += n_ld * energy.e_opm_access_pj
                    e["iram"] += n_ld * (energy.e_iram_fetch_pj
                                         + energy.e_ctrl_per_instr_pj)
                else:
                    ld_end = ld_ready

                # ---- activation: all predecessors' FLOW of this repeat
                preds = [p for p, succs in
                         ((blk.name, blk.successors) for blk in task.blocks)
                         if b.name in succs]
                act = max((flow_end.get((p, r), 0.0) for p in preds),
                          default=0.0)
                if preds:
                    ctrl_noc_bytes += len(preds) * 11

                # ---- CAL stage
                cal_ready = max(ld_end, act)
                cal_svc = n_cal * cfg.cal_cycles_per_instr
                s, cal_end = cal_u[pe].acquire(cal_ready, cal_svc)
                exec_cal += sum(1 for i in cal_instrs
                                if i.op not in (Op.PREREAD0, Op.PREREAD1))
                exec_madd_cycles += n_madd
                for i in cal_instrs:
                    if i.op is Op.MADD:
                        e["cal"] += energy.e_mac_lane_pj * cfg.simd
                    elif i.op not in (Op.PREREAD0, Op.PREREAD1):
                        e["cal"] += energy.e_alu_lane_pj * cfg.simd
                    e["opm"] += 4 * energy.e_opm_access_pj
                    e["iram"] += energy.e_iram_fetch_pj
                    e["ctrl"] += energy.e_ctrl_per_instr_pj

                # ---- FLOW stage
                if n_copy:
                    svc = n_copy * cfg.copy_cycles_per_instr
                    s, _ = flow_u[pe].acquire(cal_end, svc)
                    nbytes = n_copy * cfg.word_bytes
                    hops = [
                        _pe_hops(pe, i.f2 % cfg.n_pes, cfg)
                        for i in instrs if i.op is Op.COPY]
                    net_done = interpe.transfer(
                        s, nbytes, max(hops, default=0) * cfg.hop_cycles)
                    fl_end = max(s + svc, net_done)
                    flow_u[pe].free_at = fl_end
                    e["noc"] += sum(hops) * energy.e_noc_hop_per_flit_pj
                    e["opm"] += 2 * n_copy * energy.e_opm_access_pj
                    e["iram"] += n_copy * (energy.e_iram_fetch_pj
                                           + energy.e_ctrl_per_instr_pj)
                else:
                    fl_end = cal_end
                flow_end[(b.name, r)] = fl_end
                if b.successors:
                    ctrl_noc_bytes += len(b.successors) * 11

                # ---- ST stage
                if n_st:
                    hit_b = miss_b = 0.0
                    for i in instrs:
                        if i.op is Op.ST:
                            addr = (task.st_base + ((i.f1 << 16) | i.f2)) \
                                * cfg.word_bytes
                            if cache.access(addr, write=True):
                                hit_b += cfg.word_bytes
                            else:
                                miss_b += cfg.word_bytes
                    issue = n_st * cfg.st_issue_cycles
                    s, _ = st_u[pe].acquire(fl_end, issue)
                    hit_done = cache_srv.transfer(s, hit_b)
                    # write-back cache: miss fills occupy DRAM
                    dram_done = dram.transfer(s, miss_b, 0)
                    st_end = max(s + issue, hit_done, dram_done) \
                        + _mem_hops(pe, cfg) * cfg.hop_cycles
                    st_u[pe].free_at = st_end
                    nbytes = n_st * cfg.word_bytes
                    mem_noc_bytes += nbytes
                    e["cache"] += n_st * energy.e_cache_access_pj
                    e["dram"] += miss_b * energy.e_dram_per_byte_pj
                    e["noc"] += (nbytes / cfg.noc_flit_bytes) \
                        * _mem_hops(pe, cfg) * energy.e_noc_hop_per_flit_pj
                    e["opm"] += n_st * energy.e_opm_access_pj
                    e["iram"] += n_st * (energy.e_iram_fetch_pj
                                         + energy.e_ctrl_per_instr_pj)
                    # in-DRAM table lookups add one table read per value
                    n_lut = sum(1 for i in instrs
                                if i.op is Op.ST and i.lookup_type)
                    e["dram"] += n_lut * cfg.simd * 2 \
                        * energy.e_dram_per_byte_pj
                else:
                    st_end = fl_end

                exec_instrs += len(instrs)
                makespan = max(makespan, st_end)

    # dirty-line writebacks at the end
    wb_bytes = cache.writebacks * cfg.cache_line
    e["dram"] += wb_bytes * energy.e_dram_per_byte_pj

    total_accesses = cache.hits + cache.misses
    instr_bytes = sum(len(b.instrs) * cfg.instr_bytes
                      for t in graph.tasks for b in t.blocks)
    dram_bytes = cache.misses * cfg.cache_line + wb_bytes + instr_bytes
    energy_pj = sum(e.values())
    cycles = max(makespan, 1.0)
    cal_busy = sum(u.busy for u in cal_u)
    return SimResult(
        cycles=cycles,
        mac_utilization=cal_busy / (cfg.n_pes * cycles),
        madd_utilization=exec_madd_cycles / (cfg.n_pes * cycles),
        dram_bytes=dram_bytes,
        mem_noc_bytes=mem_noc_bytes,
        interpe_noc_bytes=interpe.bytes_served,
        ctrl_noc_bytes=ctrl_noc_bytes,
        cache_hit_rate=cache.hits / total_accesses if total_accesses else 0.0,
        energy_pj=energy_pj,
        energy_breakdown=e,
        executed_cal_instrs=exec_cal,
        executed_instrs=exec_instrs,
    )
