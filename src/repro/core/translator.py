"""The RISC-NN translator (paper §3.12).

Responsibilities, exactly as the paper lists them:

1. **Map ExeBlocks to physical PEs** — load-balanced over instruction count
   and Operand-RAM pressure, while keeping every ExeBlock that shares a
   logical PE id on the same physical PE (data sharing through the OPM
   requires co-residency, paper Fig 8/9).
2. **Map logical in-PE addresses to physical Operand-RAM entries** —
   balancing bank occupancy so the three CAL read ports hit distinct
   banks.  Where a CAL instruction still has an intra-bank conflict the
   translator injects ``PREREAD0``/``PREREAD1`` (paper §3.7).
3. **Map logical DRAM addresses to physical DRAM addresses.**
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from .exeblock import ExecutionGraph, ExeBlock, Task
from .isa import Instr, Op, Stage

__all__ = ["TranslatorConfig", "TranslationReport", "translate"]


@dataclass(frozen=True)
class TranslatorConfig:
    n_pes: int = 64
    opm_banks: int = 16
    opm_rows: int = 128           # entries per bank (Table 2)
    iram_words_per_pe: int = 8 * 512  # 8 banks x 512 x 64-bit words


@dataclass
class TranslationReport:
    pe_map: Dict[int, int] = field(default_factory=dict)
    #: per (physical PE) -> logical addr -> physical OPM entry
    opm_map: Dict[int, Dict[int, int]] = field(default_factory=dict)
    prereads_injected: int = 0
    max_opm_entries: int = 0
    max_instrs_per_pe: int = 0
    bank_occupancy: Dict[int, List[int]] = field(default_factory=dict)

    def physical_bank(self, cfg: TranslatorConfig, entry: int) -> int:
        return entry % cfg.opm_banks


def _balance_pes(graph: ExecutionGraph, cfg: TranslatorConfig) -> Dict[int, int]:
    """Greedy longest-processing-time assignment of logical PE groups."""
    load: Dict[int, int] = {}
    for _, b in graph.all_blocks():
        load[b.logical_pe] = load.get(b.logical_pe, 0) + len(b.instrs) \
            + len(b.opm_entries())
    pe_load = [0] * cfg.n_pes
    mapping: Dict[int, int] = {}
    for lpe, w in sorted(load.items(), key=lambda kv: (-kv[1], kv[0])):
        tgt = min(range(cfg.n_pes), key=lambda p: (pe_load[p], p))
        mapping[lpe] = tgt
        pe_load[tgt] += w
    return mapping


def _allocate_banks(addrs_in_use: List[int],
                    conflicts: List[Tuple[int, ...]],
                    cfg: TranslatorConfig) -> Dict[int, int]:
    """Assign each logical address a physical entry, spreading co-read
    operands across banks (greedy colouring on the CAL co-occurrence
    hypergraph), then packing rows bank-interleaved."""
    neighbour: Dict[int, set] = {a: set() for a in addrs_in_use}
    for grp in conflicts:
        for a in grp:
            neighbour.setdefault(a, set()).update(x for x in grp if x != a)
    bank_of: Dict[int, int] = {}
    bank_rows = [0] * cfg.opm_banks
    # high-degree first
    for a in sorted(neighbour, key=lambda a: (-len(neighbour[a]), a)):
        used = {bank_of[n] for n in neighbour[a] if n in bank_of}
        # least-occupied bank not used by any co-read neighbour, if possible
        candidates = [b for b in range(cfg.opm_banks)
                      if b not in used and bank_rows[b] < cfg.opm_rows]
        if not candidates:
            candidates = [b for b in range(cfg.opm_banks)
                          if bank_rows[b] < cfg.opm_rows]
        if not candidates:
            raise ValueError(
                f"Operand RAM overflow: >{cfg.opm_banks * cfg.opm_rows} "
                "entries needed on one PE")
        b = min(candidates, key=lambda b: (bank_rows[b], b))
        bank_of[a] = b
        bank_rows[b] += 1
    # physical entry = row * banks + bank  (uniform interleaved addressing)
    row_next = [0] * cfg.opm_banks
    entry_of: Dict[int, int] = {}
    for a in sorted(bank_of):
        b = bank_of[a]
        entry_of[a] = row_next[b] * cfg.opm_banks + b
        row_next[b] += 1
    return entry_of


def _rewrite_block(block: ExeBlock, entry_maps: Dict[int, Dict],
                   pe_map: Dict[int, int],
                   cfg: TranslatorConfig) -> Tuple[ExeBlock, int]:
    """Rewrite a block's addresses to physical; inject PREREADs for any
    residual CAL bank conflicts.  Returns (new block, prereads injected).

    Logical OPM addresses are namespaced per *logical* PE — two logical
    PEs co-resident on one physical PE keep disjoint physical entries.
    """
    lpe = block.logical_pe
    entry_of = {a: e for (l, a), e in entry_maps[pe_map[lpe]].items()
                if l == lpe}
    out: List[Instr] = []
    injected = 0
    for ins in block.instrs:
        if ins.op is Op.LD or ins.op is Op.ST:
            out.append(Instr(ins.op, f0=entry_of[ins.f0], f1=ins.f1,
                             f2=ins.f2, lookup_type=ins.lookup_type))
        elif ins.op is Op.COPY:
            dst_pe = pe_map[ins.f2]
            dst_entry = entry_maps[dst_pe][(ins.f2, ins.f1)]
            out.append(Instr(Op.COPY, f0=entry_of[ins.f0],
                             f1=dst_entry, f2=dst_pe))
        elif ins.op in (Op.PREREAD0, Op.PREREAD1):
            out.append(Instr(ins.op, f0=entry_of.get(ins.f0, ins.f0),
                             f1=entry_of.get(ins.f1, ins.f1)))
        else:  # arithmetic CAL
            p0, p1, p2 = (entry_of[ins.f0], entry_of[ins.f1], entry_of[ins.f2])
            b0, b1, b2 = (p % cfg.opm_banks for p in (p0, p1, p2))
            # CAL ports 0-2 must be served simultaneously (paper §3.5);
            # resolve residual same-bank reads with PREREADs (§3.7).
            # Port 2 only reads for MADD (the accumulator) and has no
            # pre-read register; ports reading the *same* address share
            # one bank access (broadcast), so only distinct addresses in
            # the same bank conflict.
            ports_of: Dict[int, List[int]] = {}
            ports_of.setdefault(p0, []).append(0)
            ports_of.setdefault(p1, []).append(1)
            if ins.op is Op.MADD:
                ports_of.setdefault(p2, []).append(2)
            by_bank: Dict[int, List[int]] = {}
            for a in ports_of:
                by_bank.setdefault(a % cfg.opm_banks, []).append(a)
            pre0 = pre1 = False
            for alist in by_bank.values():
                if len(alist) <= 1:
                    continue
                # keep (at most) one address on the live bank port —
                # preferentially the one port 2 needs (it cannot divert)
                alist = sorted(alist,
                               key=lambda a: (0 if 2 in ports_of[a] else 1, a))
                for a in alist[1:]:
                    if 0 in ports_of[a]:
                        pre0 = True
                    if 1 in ports_of[a]:
                        pre1 = True
            if pre0:
                out.append(Instr(Op.PREREAD0, f0=p0))
                injected += 1
            if pre1:
                out.append(Instr(Op.PREREAD1, f1=p1))
                injected += 1
            out.append(Instr(ins.op, f0=p0, f1=p1, f2=p2))
    nb = ExeBlock(name=block.name, instrs=out, logical_pe=pe_map[block.logical_pe],
                  priority=block.priority, successors=list(block.successors),
                  sparse_execution=block.sparse_execution,
                  inst_dram_address=block.inst_dram_address)
    return nb, injected


def translate(graph: ExecutionGraph,
              cfg: TranslatorConfig = TranslatorConfig()
              ) -> Tuple[ExecutionGraph, TranslationReport]:
    """Lower a logical ExecutionGraph to a physical one."""
    report = TranslationReport()
    pe_map = _balance_pes(graph, cfg)
    report.pe_map = pe_map

    # gather, per physical PE, every (logical-PE, logical-address) key and
    # CAL co-occurrence groups (for bank spreading)
    addrs: Dict[int, set] = {}
    confl: Dict[int, List[Tuple]] = {}
    for task in graph.tasks:
        for b in task.blocks:
            pe = pe_map[b.logical_pe]
            lpe = b.logical_pe
            A = addrs.setdefault(pe, set())
            C = confl.setdefault(pe, [])
            for ins in b.instrs:
                if ins.op in (Op.LD, Op.ST):
                    A.add((lpe, ins.f0))
                elif ins.op is Op.COPY:
                    A.add((lpe, ins.f0))
                    addrs.setdefault(pe_map[ins.f2], set()).add(
                        (ins.f2, ins.f1))
                elif ins.stage is Stage.CAL and ins.op not in (
                        Op.PREREAD0, Op.PREREAD1):
                    A.update(((lpe, ins.f0), (lpe, ins.f1), (lpe, ins.f2)))
                    C.append(((lpe, ins.f0), (lpe, ins.f1), (lpe, ins.f2)))

    entry_maps: Dict[int, Dict] = {}
    for pe, aset in addrs.items():
        entry_maps[pe] = _allocate_banks(sorted(aset), confl.get(pe, []), cfg)
    report.opm_map = entry_maps
    report.max_opm_entries = max((len(m) for m in entry_maps.values()),
                                 default=0)

    new_tasks: List[Task] = []
    instr_count: Dict[int, int] = {}
    for task in graph.tasks:
        new_blocks = []
        for b in task.blocks:
            pe = pe_map[b.logical_pe]
            nb, inj = _rewrite_block(b, entry_maps, pe_map, cfg)
            report.prereads_injected += inj
            instr_count[pe] = instr_count.get(pe, 0) + len(nb.instrs)
            new_blocks.append(nb)
        new_tasks.append(Task(task_id=task.task_id, blocks=new_blocks,
                              ld_base=task.ld_base, st_base=task.st_base,
                              repeats=task.repeats))
    report.max_instrs_per_pe = max(instr_count.values(), default=0)
    if report.max_instrs_per_pe > cfg.iram_words_per_pe:
        raise ValueError(
            f"Instruction RAM overflow: {report.max_instrs_per_pe} > "
            f"{cfg.iram_words_per_pe} words on one PE")
    occupancy = {}
    for pe, m in entry_maps.items():
        occ = [0] * cfg.opm_banks
        for e in m.values():
            occ[e % cfg.opm_banks] += 1
        occupancy[pe] = occ
    report.bank_occupancy = occupancy
    return ExecutionGraph(name=graph.name, tasks=new_tasks), report
