"""Sparse-NN support: pruning -> sparse vectors -> Sparse PC Inc (paper
§3.4, §5.4, Figs 18/19).

The compiler-side flow is exactly the paper's Fig 18: identify
ineffective weights, emit a per-ExeBlock *sparse vector* (one bit per
instruction), and let the Instruction-Loader semantics
(`ExeBlock.apply_sparse_vector`) rewrite each instruction's
``Sparse PC Inc`` so the CAL pipeline jumps over dead MACs.

Two entry points:

* :func:`conv_sparse_vectors` — exact mapping for the panel-structured
  conv programs (No/Filter/Ifmap reuse): MADD j of item (o, pos) uses
  weight (o, c, k=j), so a pruned-weight set maps deterministically to
  instruction bits.  The interpreter equivalence test (sparse program ==
  dense program with zeroed weights) runs on this path.
* :func:`random_sparse_vectors` — statistical pruning at a given keep
  rate for perf/energy studies on any program (Fig 19 uses the layer
  compress rates of Table 3).
"""
from __future__ import annotations

import copy
from typing import Dict, Iterable, List, Sequence, Set, Tuple

import numpy as np

from .dataflows import ConvSpec, Reuse, panel_items
from .exeblock import ExeBlock, ExecutionGraph
from .isa import Op, Stage

__all__ = ["conv_sparse_vectors", "random_sparse_vectors", "apply_pruning",
           "prune_weights"]


def prune_weights(weights: np.ndarray, keep_frac: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Magnitude pruning to ``keep_frac`` (the paper's 'compress rate'):
    returns the pruned weights (zeros at dropped positions)."""
    flat = np.abs(weights).ravel()
    k = max(1, int(round(keep_frac * flat.size)))
    thresh = np.partition(flat, -k)[-k]
    mask = np.abs(weights) >= thresh
    return weights * mask


def conv_sparse_vectors(graph: ExecutionGraph, spec: ConvSpec,
                        scheme: Reuse, pruned: Set[Tuple[int, int]],
                        *, items_per_block: int,
                        n_items: int, channel: int = 0,
                        instance: int = 0) -> Dict[str, List[bool]]:
    """Per-block sparse vectors for the simple panel schemes.

    ``pruned`` is a set of (out_channel, k) weight coordinates (for the
    fixed input channel) that pruning removed.  In the generated
    programs, each item's CAL chain is K consecutive MADDs in k-order.
    """
    assert scheme in (Reuse.NO_REUSE, Reuse.FILTER_REUSE,
                      Reuse.IFMAP_REUSE), "exact mapping: panel schemes"
    items = panel_items(spec, scheme, n_items=n_items, instance=instance)
    vectors: Dict[str, List[bool]] = {}
    task = graph.tasks[-1]
    cal_blocks = [b for b in task.blocks if b.n_cal > 0]
    # panel blocks appear in item order; skip loader/multicast-only blocks
    idx = 0
    for b in cal_blocks:
        rng_cal = b.stage_pcs.range(Stage.CAL)
        n_madd = sum(1 for pc in rng_cal if b.instrs[pc].op is Op.MADD)
        if n_madd % spec.k:
            continue                      # not an item chain block
        n_block_items = n_madd // spec.k
        block_items = items[idx:idx + n_block_items]
        idx += n_block_items
        valid = [True] * len(b.instrs)
        it = iter([(o, k) for (o, _pos) in block_items
                   for k in range(spec.k)])
        for pc in rng_cal:
            if b.instrs[pc].op is Op.MADD:
                o, k = next(it)
                if (o, k) in pruned:
                    valid[pc] = False
        if b.instrs and not valid[0]:
            valid[0] = True               # hardware fetches PC 0
        vectors[b.name] = valid
    return vectors


def random_sparse_vectors(graph: ExecutionGraph, keep_frac: float,
                          rng: np.random.Generator
                          ) -> Dict[str, List[bool]]:
    """Statistical pruning: drop (1-keep_frac) of each block's MADDs."""
    vectors: Dict[str, List[bool]] = {}
    for _t, b in graph.all_blocks():
        madds = [pc for pc, ins in enumerate(b.instrs)
                 if ins.op is Op.MADD]
        if not madds:
            continue
        n_drop = int(round((1.0 - keep_frac) * len(madds)))
        drop = set(rng.choice(madds, size=n_drop, replace=False).tolist()) \
            if n_drop else set()
        valid = [pc not in drop for pc in range(len(b.instrs))]
        if b.instrs and not valid[0]:
            valid[0] = True
        vectors[b.name] = valid
    return vectors


def apply_pruning(graph: ExecutionGraph,
                  vectors: Dict[str, List[bool]]) -> ExecutionGraph:
    """Return a sparse copy of ``graph`` with Sparse PC Inc rewritten
    (Instruction-Loader semantics, paper §3.4)."""
    g = copy.deepcopy(graph)
    for _t, b in g.all_blocks():
        if b.name in vectors:
            b.apply_sparse_vector(vectors[b.name])
    return g
