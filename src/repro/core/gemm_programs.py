"""CISC NN-accelerator instructions as ExeBlock programs (paper Tables 4/5).

The paper's expressiveness claim: every *necessary* TPU / Cambricon CISC
instruction can be implemented on the RISC-NN PE array.  This module
generates those programs; ``tests/test_gemm_programs.py`` validates each
against a numpy oracle, which is the machine-checkable form of Table 4.

Static counts (Table 5) are reproduced exactly for the element-wise ops
(MMS, MAM, VGTM, VMV) whose decomposition is fully determined; for
MMM / MMV / OP the paper's exact multicast/reduction decomposition is not
published, so our counts are reported side-by-side in
``benchmarks/table5_cisc.py`` (LD/CAL/ST match where derivable).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

from .exeblock import ExeBlock, ExecutionGraph, Task
from .isa import Instr, Op, make_copy, make_ld, make_st

__all__ = ["PAPER_TABLE5", "build_program", "oracle", "CISC_OPS",
           "seed_operands", "read_result"]

#: paper Table 5 static counts
PAPER_TABLE5: Dict[str, Dict[str, int]] = {
    "MMM": dict(size="64x64", ld=192, cal=4096, copy=4928, st=4096,
                exeblocks=255, opm=5120),
    "MMV": dict(size="64x64", ld=4160, cal=4096, copy=525, st=64,
                exeblocks=255, opm=8256),
    "MMS": dict(size="64x64", ld=4160, cal=4096, copy=0, st=4096,
                exeblocks=64, opm=8256),
    "MAM": dict(size="64x64", ld=8192, cal=4096, copy=0, st=4096,
                exeblocks=64, opm=12288),
    "OP": dict(size="64x64", ld=128, cal=4096, copy=896, st=4096,
               exeblocks=127, opm=5120),
    "VGTM": dict(size="1024", ld=2048, cal=1024, copy=0, st=1024,
                 exeblocks=64, opm=3072),
    "VMV": dict(size="1024", ld=2048, cal=1024, copy=0, st=1024,
                exeblocks=64, opm=3072),
}

CISC_OPS = tuple(PAPER_TABLE5)

# DRAM layout: A at 0, B at |A|, scalar/vector after, result via ST base.
_N = 64
_V = 1024
#: results are stored via a dedicated ST base so they never alias operands
_ST_BASE = 1 << 20


def _rowwise_elementwise(name: str, op: Op, n_rows: int, n_cols: int,
                         two_operands: bool, n_pes: int) -> ExecutionGraph:
    """MMS/MAM/VGTM/VMV pattern: one block per row/chunk, no sharing.

    MMS: out = A * s (scalar broadcast: the scalar is one extra LD/block).
    MAM: out = A + B.  VGTM: out = max(a, b).  VMV: out = min(a, b).
    """
    blocks = []
    asz = n_rows * n_cols
    for r in range(n_rows):
        pe = r % n_pes
        a = list(range(0, n_cols))
        base = r * n_cols
        ins = [make_ld(a[j], base + j) for j in range(n_cols)]
        if two_operands:
            b = list(range(n_cols, 2 * n_cols))
            ins += [make_ld(b[j], asz + base + j) for j in range(n_cols)]
            out = list(range(2 * n_cols, 3 * n_cols))
        else:  # scalar in one entry
            s = n_cols
            ins.append(make_ld(s, 2 * asz))
            out = list(range(n_cols + 1, 2 * n_cols + 1))
        cal = [Instr(op, f0=a[j], f1=(b[j] if two_operands else s),
                     f2=out[j]) for j in range(n_cols)]
        st = [make_st(out[j], base + j) for j in range(n_cols)]
        blocks.append(ExeBlock(name=f"{name}_r{r}", instrs=ins + cal + st,
                               logical_pe=pe))
    return ExecutionGraph(name, [Task(task_id=0, blocks=blocks,
                                      st_base=_ST_BASE)])


def _tree_children(n: int, arity: int = 3) -> Dict[int, List[int]]:
    return {i: [c for c in range(i * arity + 1, i * arity + 1 + arity)
                if c < n] for i in range(n)}


def _mmv(n_pes: int) -> ExecutionGraph:
    """y = A @ x, A 64x64: 64 row blocks; x loaded once by the root and
    multicast over a 3-ary tree embedded in the row blocks."""
    n = _N
    x_addr = list(range(n, 2 * n))
    children = _tree_children(n)
    blocks = []
    for r in range(n):
        pe = r % n_pes
        ins: List[Instr] = []
        if r == 0:
            ins += [make_ld(x_addr[j], n * n + j) for j in range(n)]
        a = list(range(0, n))
        ins += [make_ld(a[j], r * n + j) for j in range(n)]
        acc = 2 * n
        ins.append(make_ld(acc, n * n + n + r))  # zero-initialised psum
        cal = [Instr(Op.MADD, f0=a[j], f1=x_addr[j], f2=acc)
               for j in range(n)]
        flow = []
        for ch in children[r]:
            flow += [make_copy(x_addr[j], x_addr[j], ch % n_pes)
                     for j in range(n)]
        st = [make_st(acc, r)]
        blocks.append(ExeBlock(
            name=f"MMV_r{r}", instrs=ins + cal + flow + st, logical_pe=pe,
            successors=[f"MMV_r{c}" for c in children[r]]))
    return ExecutionGraph("MMV", [Task(task_id=0, blocks=blocks,
                                       st_base=_ST_BASE)])


def _op_outer(n_pes: int) -> ExecutionGraph:
    """OP: out = x y^T (64x64 outer product).  LD = 128 (both vectors),
    CAL = 4096 MUL, ST = 4096; y multicast over the row blocks' tree."""
    n = _N
    y_addr = list(range(1, 1 + n))
    children = _tree_children(n)
    blocks = []
    for r in range(n):
        pe = r % n_pes
        ins: List[Instr] = []
        ins.append(make_ld(0, r))  # x[r]
        if r == 0:
            ins += [make_ld(y_addr[j], n + j) for j in range(n)]
        out = list(range(1 + n, 1 + 2 * n))
        cal = [Instr(Op.MUL, f0=0, f1=y_addr[j], f2=out[j])
               for j in range(n)]
        flow = []
        for ch in children[r]:
            flow += [make_copy(y_addr[j], y_addr[j], ch % n_pes)
                     for j in range(n)]
        st = [make_st(out[j], r * n + j) for j in range(n)]
        blocks.append(ExeBlock(
            name=f"OP_r{r}", instrs=ins + cal + flow + st, logical_pe=pe,
            successors=[f"OP_r{c}" for c in children[r]]))
    return ExecutionGraph("OP", [Task(task_id=0, blocks=blocks,
                                      st_base=_ST_BASE)])


def _mmm(n_pes: int, inner_chunk: int = 1) -> ExecutionGraph:
    """C = A @ B, 64x64x64, decomposed the way the paper's Table 5 row
    implies: the task iterates over the inner dimension (ExeBlock Reuse),
    each iteration rank-`inner_chunk` updating C.  Per iteration:
    LD = one column of A + one row of B (+ C resident, data-stationary),
    CAL = 4096 MADDs, ST on the final iteration.

    We generate `inner_chunk` iterations explicitly as consecutive tasks
    sharing OPM entries (Inter-Task Data Reuse) to keep programs bounded;
    the benchmark reports the per-iteration counts, which is what Table 5
    tabulates (LD 192 ~= 64 A + 64 B + 64 C-init; CAL 4096; ST 4096)."""
    n = _N
    a_col = list(range(0, n))          # A[:, k] one entry per row block? no:
    # layout per PE: each block owns one row of C (64 entries), one a-value
    # and receives the B row.
    b_row = list(range(n, 2 * n))
    children = _tree_children(n)
    tasks = []
    for k in range(inner_chunk):
        blocks = []
        for r in range(n):
            pe = r % n_pes
            ins: List[Instr] = []
            ins.append(make_ld(0, k * n + r))          # A[r, k]
            if r == 0:
                ins += [make_ld(b_row[j], n * n + k * n + j)
                        for j in range(n)]
            c_out = list(range(2 * n, 3 * n))
            if k == 0:
                ins += [make_ld(c_out[j], 2 * n * n + r * n + j)
                        for j in range(n)]
            cal = [Instr(Op.MADD, f0=0, f1=b_row[j], f2=c_out[j])
                   for j in range(n)]
            flow = []
            for ch in children[r]:
                flow += [make_copy(b_row[j], b_row[j], ch % n_pes)
                         for j in range(n)]
            st = [make_st(c_out[j], r * n + j) for j in range(n)] \
                if k == inner_chunk - 1 else []
            blocks.append(ExeBlock(
                name=f"MMM_k{k}_r{r}", instrs=ins + cal + flow + st,
                logical_pe=pe,
                successors=[f"MMM_k{k}_r{c}" for c in children[r]]))
        tasks.append(Task(task_id=k, blocks=blocks, st_base=_ST_BASE))
    return ExecutionGraph("MMM", tasks)


def build_program(name: str, n_pes: int = 64, **kw) -> ExecutionGraph:
    if name == "MMS":
        return _rowwise_elementwise("MMS", Op.MUL, _N, _N, False, n_pes)
    if name == "MAM":
        return _rowwise_elementwise("MAM", Op.ADD, _N, _N, True, n_pes)
    if name == "VGTM":
        return _rowwise_elementwise("VGTM", Op.MAX, _V // 16, 16, True, n_pes)
    if name == "VMV":
        return _rowwise_elementwise("VMV", Op.MIN, _V // 16, 16, True, n_pes)
    if name == "MMV":
        return _mmv(n_pes)
    if name == "OP":
        return _op_outer(n_pes)
    if name == "MMM":
        return _mmm(n_pes, **kw)
    raise ValueError(f"unknown CISC op {name}")


# ------------------------------------------------------------------ oracles
def seed_operands(state, name: str, rng: np.random.Generator,
                  simd: int = 8) -> Tuple[np.ndarray, ...]:
    n, v = _N, _V
    if name in ("MMS",):
        a = rng.normal(size=(n * n, simd)).astype(np.float32)
        s = rng.normal(size=(1, simd)).astype(np.float32)
        state.dram_write_array(0, a)
        state.dram_write(2 * n * n, s[0])
        return a.reshape(n, n, simd), s
    if name in ("MAM",):
        a = rng.normal(size=(n * n, simd)).astype(np.float32)
        b = rng.normal(size=(n * n, simd)).astype(np.float32)
        state.dram_write_array(0, a)
        state.dram_write_array(n * n, b)
        return a.reshape(n, n, simd), b.reshape(n, n, simd)
    if name in ("VGTM", "VMV"):
        a = rng.normal(size=(v, simd)).astype(np.float32)
        b = rng.normal(size=(v, simd)).astype(np.float32)
        state.dram_write_array(0, a)
        state.dram_write_array(v, b)
        return a, b
    if name == "MMV":
        a = rng.normal(size=(n * n, simd)).astype(np.float32)
        x = rng.normal(size=(n, simd)).astype(np.float32)
        state.dram_write_array(0, a)
        state.dram_write_array(n * n, x)
        # psum init region zeros by default
        return a.reshape(n, n, simd), x
    if name == "OP":
        x = rng.normal(size=(n, simd)).astype(np.float32)
        y = rng.normal(size=(n, simd)).astype(np.float32)
        state.dram_write_array(0, x)
        state.dram_write_array(n, y)
        return x, y
    if name == "MMM":
        a = rng.normal(size=(n * n, simd)).astype(np.float32)
        b = rng.normal(size=(n * n, simd)).astype(np.float32)
        state.dram_write_array(0, a)          # A stored column-major chunks
        state.dram_write_array(n * n, b)      # B row-major by k
        return a.reshape(n, n, simd), b.reshape(n, n, simd)
    raise ValueError(name)


def oracle(name: str, operands: Tuple[np.ndarray, ...],
           inner_chunk: int = 1) -> np.ndarray:
    n = _N
    if name == "MMS":
        a, s = operands
        return a * s[0]
    if name == "MAM":
        return operands[0] + operands[1]
    if name == "VGTM":
        return np.maximum(*operands)
    if name == "VMV":
        return np.minimum(*operands)
    if name == "MMV":
        a, x = operands
        return np.einsum("rjs,js->rs", a, x)
    if name == "OP":
        x, y = operands
        return np.einsum("rs,js->rjs", x, y)
    if name == "MMM":
        a, b = operands
        # A laid out as a[k, r] chunks: dram word k*n + r = A[r, k]
        # C[r, j] = sum_k A[r,k] * B[k,j] over the first `inner_chunk` ks
        ak = a[:inner_chunk]                    # (k, r, simd)
        bk = b[:inner_chunk]                    # (k, j, simd)
        return np.einsum("krs,kjs->rjs", ak, bk)
    raise ValueError(name)


def read_result(state, name: str, simd: int = 8) -> np.ndarray:
    n, v = _N, _V
    if name in ("MMS", "MAM", "OP", "MMM"):
        return _read_st(state, n * n, simd).reshape(n, n, simd)
    if name in ("VGTM", "VMV"):
        return _read_st(state, v, simd)
    if name == "MMV":
        return _read_st(state, n, simd)
    raise ValueError(name)


def _read_st(state, count: int, simd: int) -> np.ndarray:
    import numpy as _np
    return _np.stack([state.dram_read(_ST_BASE + i) for i in range(count)])

