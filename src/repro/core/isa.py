"""Very-RISC ISA of RISC-NN (paper Table 1, Section 3.2).

11 fixed-length (64-bit) instructions, all with the same format::

    [ OP(4b) | F0(16b) | F1(16b) | F2(16b) | CTRL(12b) ]

CTRL = [ Sparse PC Inc (8b) | In-DRAM Lookup Type (4b) ].

Two addressing modes:
  * Direct PE addressing   — a 16-bit absolute address into the PE's
    Operand RAM Module (OPM).  COPY uses F2 as a remote PE number.
  * Base-plus-offset DRAM  — DRAM address = task base (LD_Base / ST_Base)
    + the 32-bit offset {F1,F2} ({hi,lo} concatenation).

Each instruction belongs to exactly one ExeBlock execution stage
(LD / CAL / FLOW / ST).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, replace

__all__ = [
    "Op", "Stage", "Instr", "OP_STAGE", "CAL_OPS", "ARITH_OPS",
    "encode", "decode", "dram_offset", "make_ld", "make_st", "make_copy",
    "WORD_BITS", "FIELD_BITS", "OPM_ENTRIES", "SIMD_WIDTH",
]

WORD_BITS = 64
FIELD_BITS = 16
#: Operand RAM Module capacity, entries (16 banks x 128 rows, Table 2).
OPM_ENTRIES = 16 * 128
#: default SIMD width (Table 2: SIMD-8)
SIMD_WIDTH = 8


class Op(enum.IntEnum):
    """4-bit opcode. Exactly the paper's 11 instructions."""
    LD = 0
    ADD = 1
    SUB = 2
    MUL = 3
    MAX = 4
    MIN = 5
    MADD = 6
    PREREAD0 = 7
    PREREAD1 = 8
    COPY = 9
    ST = 10


class Stage(enum.IntEnum):
    """ExeBlock execution stages, in mandatory order (paper §3.1)."""
    LD = 0
    CAL = 1
    FLOW = 2
    ST = 3


OP_STAGE: dict[Op, Stage] = {
    Op.LD: Stage.LD,
    Op.ADD: Stage.CAL, Op.SUB: Stage.CAL, Op.MUL: Stage.CAL,
    Op.MAX: Stage.CAL, Op.MIN: Stage.CAL, Op.MADD: Stage.CAL,
    Op.PREREAD0: Stage.CAL, Op.PREREAD1: Stage.CAL,
    Op.COPY: Stage.FLOW,
    Op.ST: Stage.ST,
}

#: CAL-stage opcodes (8 of them, paper §3.2)
CAL_OPS = tuple(op for op, st in OP_STAGE.items() if st is Stage.CAL)
#: the six calculation-style CAL ops (everything but the PREREADs)
ARITH_OPS = (Op.ADD, Op.SUB, Op.MUL, Op.MAX, Op.MIN, Op.MADD)

_F_MASK = (1 << FIELD_BITS) - 1


@dataclass(frozen=True)
class Instr:
    """One RISC-NN instruction.

    ``sparse_pc_inc`` is the 8-bit *Sparse PC Inc* CTRL sub-field: the PC
    increment to the next valid instruction when the owning ExeBlock runs
    in sparse mode (paper §3.4, §5.4).  ``lookup_type`` is the 4-bit
    *In-DRAM Lookup Type* sub-field used by ST for complex activation /
    classifier functions (paper §3.9); 0 means "plain store".
    """
    op: Op
    f0: int = 0
    f1: int = 0
    f2: int = 0
    sparse_pc_inc: int = 1
    lookup_type: int = 0

    def __post_init__(self) -> None:
        for name in ("f0", "f1", "f2"):
            v = getattr(self, name)
            if not 0 <= v <= _F_MASK:
                raise ValueError(f"{name}={v} out of 16-bit range")
        if not 0 <= self.sparse_pc_inc <= 0xFF:
            raise ValueError(f"sparse_pc_inc={self.sparse_pc_inc} not 8-bit")
        if not 0 <= self.lookup_type <= 0xF:
            raise ValueError(f"lookup_type={self.lookup_type} not 4-bit")
        if self.lookup_type and self.op is not Op.ST:
            raise ValueError("In-DRAM lookup is an ST-only CTRL feature")

    @property
    def stage(self) -> Stage:
        return OP_STAGE[self.op]

    def with_sparse_inc(self, inc: int) -> "Instr":
        return replace(self, sparse_pc_inc=inc)

    def __str__(self) -> str:  # pragma: no cover - debug aid
        s = f"{self.op.name} {self.f0:#06x},{self.f1:#06x},{self.f2:#06x}"
        if self.sparse_pc_inc != 1:
            s += f" [inc={self.sparse_pc_inc}]"
        if self.lookup_type:
            s += f" [lut={self.lookup_type}]"
        return s


def encode(instr: Instr) -> int:
    """Pack into the 64-bit word: OP(4) F0(16) F1(16) F2(16) CTRL(12)."""
    ctrl = (instr.sparse_pc_inc << 4) | instr.lookup_type
    return (
        (int(instr.op) << 60)
        | (instr.f0 << 44)
        | (instr.f1 << 28)
        | (instr.f2 << 12)
        | ctrl
    )


def decode(word: int) -> Instr:
    """Inverse of :func:`encode`."""
    if not 0 <= word < (1 << WORD_BITS):
        raise ValueError("word out of 64-bit range")
    opv = (word >> 60) & 0xF
    if opv > max(Op):
        raise ValueError(f"invalid opcode {opv}")
    return Instr(
        op=Op(opv),
        f0=(word >> 44) & _F_MASK,
        f1=(word >> 28) & _F_MASK,
        f2=(word >> 12) & _F_MASK,
        sparse_pc_inc=(word >> 4) & 0xFF,
        lookup_type=word & 0xF,
    )


def dram_offset(f1: int, f2: int) -> int:
    """32-bit DRAM offset from the {F1,F2} field pair (paper §3.2)."""
    return (f1 << FIELD_BITS) | f2


def _split_offset(offset: int) -> tuple[int, int]:
    if not 0 <= offset < (1 << 32):
        raise ValueError(f"DRAM offset {offset} out of 32-bit range")
    return (offset >> FIELD_BITS) & _F_MASK, offset & _F_MASK


def make_ld(opm_addr: int, offset: int) -> Instr:
    """LD: OPM[F0] = DRAM[LD_Base + {F1,F2}]."""
    f1, f2 = _split_offset(offset)
    return Instr(Op.LD, f0=opm_addr, f1=f1, f2=f2)


def make_st(opm_addr: int, offset: int, lookup_type: int = 0) -> Instr:
    """ST: DRAM[ST_Base + {F1,F2}] = OPM[F0] (optionally via in-DRAM LUT)."""
    f1, f2 = _split_offset(offset)
    return Instr(Op.ST, f0=opm_addr, f1=f1, f2=f2, lookup_type=lookup_type)


def make_copy(src_addr: int, dst_addr: int, dst_pe: int) -> Instr:
    """COPY: PE[F2].OPM[F1] = OPM[F0]."""
    return Instr(Op.COPY, f0=src_addr, f1=dst_addr, f2=dst_pe)
