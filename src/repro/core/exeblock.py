"""ExeBlock / Task / ExecutionGraph IR (paper §3.1, §3.4, §3.12).

An *ExeBlock* is a straight-line program of RISC-NN instructions split into
up to four consecutive stages (LD → CAL → FLOW → ST).  ExeBlocks form a
dataflow DAG: at the end of its FLOW stage an ExeBlock *activates* its
successors; a successor's CAL stage may start only once it has collected
activations from all its predecessors (paper Fig 4).

A *Task* groups ExeBlocks, owns the LD_Base / ST_Base DRAM base addresses,
and is the unit the host enables.  An *Application* (``ExecutionGraph``)
is a sequence of consecutive tasks (paper Fig 2).

Addresses in this IR are *logical* until :mod:`repro.core.translator`
maps them to physical PEs / Operand-RAM banks (paper §3.12).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

from .isa import Instr, Op, Stage

__all__ = ["ExeBlock", "Task", "ExecutionGraph", "MAX_SUCCESSORS", "StagePCs"]

#: paper §3.4: "each ExeBlock has up to 3 successors"
MAX_SUCCESSORS = 3


@dataclass(frozen=True)
class StagePCs:
    """Starting/ending PCs per stage. start == end means "stage absent"."""
    start: tuple[int, int, int, int]
    end: tuple[int, int, int, int]

    def has(self, stage: Stage) -> bool:
        return self.start[stage] != self.end[stage]

    def range(self, stage: Stage) -> range:
        return range(self.start[stage], self.end[stage])


def _derive_stage_pcs(instrs: Sequence[Instr]) -> StagePCs:
    """Partition a straight-line program into the 4 consecutive stages.

    Raises if instructions are not in stage order (an ExeBlock's code is
    "up to four consecutive Execution Stages", paper §3.1).
    """
    starts = [0, 0, 0, 0]
    ends = [0, 0, 0, 0]
    pc = 0
    last_stage = -1
    for ins in instrs:
        st = int(ins.stage)
        if st < last_stage:
            raise ValueError(
                f"instruction {pc} ({ins.op.name}) of stage {ins.stage.name} "
                f"appears after stage {Stage(last_stage).name}"
            )
        if st != last_stage:
            # close intermediate (absent) stages at the current pc
            for s in range(last_stage + 1, st + 1):
                starts[s] = pc
            last_stage = st
        pc += 1
        ends[st] = pc
    for s in range(last_stage + 1, 4):
        starts[s] = ends[s] = pc
    # absent stages between present ones: end = start
    for s in range(4):
        if ends[s] < starts[s]:
            ends[s] = starts[s]
    return StagePCs(start=tuple(starts), end=tuple(ends))


@dataclass
class ExeBlock:
    """One ExeBlock (paper §3.4 'Initialization Step' fields).

    ``logical_pe`` is the programmer-assigned logical PE id (paper §3.12);
    the translator maps it to a physical PE.  ``sparse_execution`` marks
    the block for Sparse-NN instruction skipping (paper §5.4); when set,
    the owning :class:`Task` supplies a sparse vector and
    :meth:`apply_sparse_vector` rewrites the per-instruction
    ``sparse_pc_inc`` fields exactly the way the Instruction Loader does.
    """
    name: str
    instrs: list[Instr]
    logical_pe: int = 0
    priority: int = 0
    successors: list[str] = field(default_factory=list)
    sparse_execution: bool = False
    #: starting DRAM address of this block's instruction image
    inst_dram_address: int = 0

    def __post_init__(self) -> None:
        if len(self.successors) > MAX_SUCCESSORS:
            raise ValueError(
                f"ExeBlock {self.name!r}: {len(self.successors)} successors "
                f"(max {MAX_SUCCESSORS}, paper §3.4)"
            )
        if len(set(self.successors)) != len(self.successors):
            raise ValueError(f"ExeBlock {self.name!r}: duplicate successors")
        self.stage_pcs = _derive_stage_pcs(self.instrs)

    # -- static program properties (Table 5/6 columns) ---------------------
    def count(self, *ops: Op) -> int:
        return sum(1 for i in self.instrs if i.op in ops)

    @property
    def n_ld(self) -> int:
        return self.count(Op.LD)

    @property
    def n_cal(self) -> int:
        return sum(1 for i in self.instrs if i.stage is Stage.CAL)

    @property
    def n_copy(self) -> int:
        return self.count(Op.COPY)

    @property
    def n_st(self) -> int:
        return self.count(Op.ST)

    def opm_entries(self) -> set[int]:
        """Set of Operand-RAM entries this block touches (logical addrs)."""
        touched: set[int] = set()
        for ins in self.instrs:
            if ins.op is Op.LD:
                touched.add(ins.f0)
            elif ins.op is Op.ST:
                touched.add(ins.f0)
            elif ins.op is Op.COPY:
                touched.add(ins.f0)  # source side; dest counts on remote PE
            elif ins.op is Op.PREREAD0:
                touched.add(ins.f0)
            elif ins.op is Op.PREREAD1:
                touched.add(ins.f1)
            elif ins.stage is Stage.CAL:
                touched.update((ins.f0, ins.f1, ins.f2))
        return touched

    # -- sparse execution ---------------------------------------------------
    def apply_sparse_vector(self, valid: Sequence[bool]) -> None:
        """Instruction-Loader semantics (paper §3.4 'Sparse PC Inc Update').

        ``valid`` has one bit per instruction.  For each *valid* instruction
        we write the PC increment to the next valid instruction.  The first
        instruction of a sparse block must be valid (hardware fetches PC 0);
        the translator guarantees this by construction for generated
        programs (CAL chains start with a loader-kept anchor).
        """
        if len(valid) != len(self.instrs):
            raise ValueError(
                f"sparse vector length {len(valid)} != "
                f"instruction count {len(self.instrs)}"
            )
        if self.instrs and not valid[0]:
            raise ValueError("first instruction of a sparse ExeBlock must be valid")
        self.sparse_execution = True
        n = len(self.instrs)
        out: list[Instr] = []
        for pc, ins in enumerate(self.instrs):
            nxt = pc + 1
            while nxt < n and not valid[nxt]:
                nxt += 1
            inc = min(nxt - pc, 0xFF)
            out.append(ins.with_sparse_inc(inc))
        self.instrs = out
        self.stage_pcs = _derive_stage_pcs(self.instrs)
        self._sparse_valid = list(valid)

    def executed_pcs(self) -> list[int]:
        """PCs actually executed, honouring sparse skipping (per stage)."""
        pcs: list[int] = []
        for stage in Stage:
            rng = self.stage_pcs.range(stage)
            if not rng:
                continue
            pc = rng.start
            # in sparse mode the stage's first instruction might itself be
            # skipped; the loader marks that by the *previous stage's* tail
            # inc jumping over it.  We model per-stage entry conservatively:
            if self.sparse_execution:
                valid = getattr(self, "_sparse_valid", [True] * len(self.instrs))
                while pc < rng.stop and not valid[pc]:
                    pc += 1
            while pc < rng.stop:
                pcs.append(pc)
                pc += self.instrs[pc].sparse_pc_inc if self.sparse_execution else 1
        return pcs


@dataclass
class Task:
    """A task: ExeBlocks + DRAM base addresses (paper Fig 2, §3.11)."""
    task_id: int
    blocks: list[ExeBlock]
    ld_base: int = 0
    st_base: int = 0
    #: how many times the task re-enables itself (ExeBlock Reuse, §3.11)
    repeats: int = 1

    def __post_init__(self) -> None:
        names = [b.name for b in self.blocks]
        if len(set(names)) != len(names):
            raise ValueError(f"task {self.task_id}: duplicate ExeBlock names")
        known = set(names)
        for b in self.blocks:
            for s in b.successors:
                if s not in known:
                    raise ValueError(
                        f"task {self.task_id}: {b.name!r} -> unknown successor {s!r}"
                    )
        self._by_name = {b.name: b for b in self.blocks}

    def block(self, name: str) -> ExeBlock:
        return self._by_name[name]

    def predecessors(self) -> dict[str, list[str]]:
        preds: dict[str, list[str]] = {b.name: [] for b in self.blocks}
        for b in self.blocks:
            for s in b.successors:
                preds[s].append(b.name)
        return preds

    def topo_order(self) -> list[ExeBlock]:
        """Kahn topological order; raises on cycles (dataflow must be a DAG)."""
        preds = self.predecessors()
        indeg = {n: len(p) for n, p in preds.items()}
        ready = sorted([n for n, d in indeg.items() if d == 0])
        order: list[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for s in self._by_name[n].successors:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(order) != len(self.blocks):
            raise ValueError(f"task {self.task_id}: ExeBlock graph has a cycle")
        return [self._by_name[n] for n in order]

    # -- static totals (Table 5/6 rows) -------------------------------------
    def opm_entry_set(self) -> set[tuple[int, int]]:
        opm: set[tuple[int, int]] = set()
        for b in self.blocks:
            opm.update((b.logical_pe, a) for a in b.opm_entries())
            for ins in b.instrs:
                if ins.op is Op.COPY:
                    opm.add((ins.f2, ins.f1))  # dest-side entry
        return opm

    def totals(self) -> dict[str, int]:
        return {
            "ld": sum(b.n_ld for b in self.blocks),
            "cal": sum(b.n_cal for b in self.blocks),
            "copy": sum(b.n_copy for b in self.blocks),
            "st": sum(b.n_st for b in self.blocks),
            "exeblocks": len(self.blocks),
            "opm_entries": len(self.opm_entry_set()),
        }


@dataclass
class ExecutionGraph:
    """An application: a sequence of consecutive tasks (paper Fig 2)."""
    name: str
    tasks: list[Task]

    def totals(self) -> dict[str, int]:
        agg = {"ld": 0, "cal": 0, "copy": 0, "st": 0, "exeblocks": 0}
        opm: set[tuple[int, int]] = set()
        for t in self.tasks:
            for k, v in t.totals().items():
                if k != "opm_entries":
                    agg[k] += v
            # physical entries are shared across tasks (Inter-Task Data
            # Reuse, paper §3.11) — count the union, not the sum
            opm |= t.opm_entry_set()
        agg["opm_entries"] = len(opm)
        return agg

    def all_blocks(self) -> Iterable[tuple[Task, ExeBlock]]:
        for t in self.tasks:
            for b in t.blocks:
                yield t, b
