"""Energy accounting for the RISC-NN machine model (paper §4, §5.2.4-5.7).

The paper reports *relative* energy (normalised figures) from PrimeTime PX
simulation of a TSMC-12nm implementation; absolute per-op energies are not
published.  We therefore use 12-nm-class per-operation energy constants
from the public literature (Horowitz ISSCC'14 45-nm numbers scaled by
~0.18x to 12 nm for logic and ~0.4x for SRAM, plus DDR4 interface numbers),
and *calibrate two free parameters* against the paper's own ratios:

* ``E_CTRL_PER_INSTR`` is set so the control-energy share of the SIMD sweep
  matches Fig 22 (0.8% of total at SIMD-64 for All-Reuse AlexNet_CONV2).
* ``E_NOC_HOP_PER_FLIT`` is set so the sqrt-hop NoC scaling projection
  matches Fig 23 (+23.1% total energy at 4096 PEs vs 64 PEs).

All constants are per *lane-operation* or per *event* in picojoules.
Provenance of each number is commented.  `tests/test_energy.py` asserts the
two calibration targets reproduce.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["EnergyModel", "DEFAULT_ENERGY"]


@dataclass(frozen=True)
class EnergyModel:
    # 16-bit fixed-point MAC, 12nm: Horowitz '14 gives 16b int MAC ~ 0.25pJ
    # at 45nm digital; x0.18 tech scaling -> ~0.05 pJ/lane.  One SIMD
    # instruction performs `simd` lane-ops.
    e_mac_lane_pj: float = 0.05
    # same-class ALU op (add/max/...) is ~1/3 of a MAC
    e_alu_lane_pj: float = 0.017
    # Operand RAM: 128-bit access to a small (2KB) SRAM bank,
    # ~0.6 pJ/access at 12nm (scaled from 8KB-SRAM 10 pJ/128b @45nm)
    e_opm_access_pj: float = 0.6
    # Instruction RAM fetch: 64-bit word from 4KB bank
    e_iram_fetch_pj: float = 0.35
    # Decode + issue + ExeBlock bookkeeping, per instruction (calibrated,
    # see module docstring -> Fig 22)
    e_ctrl_per_instr_pj: float = 3.0
    # NoC: energy per 128-bit flit per hop (router + link), calibrated to
    # Fig 23's sqrt-hop scaling (+23.1% @ 4096 PEs)
    e_noc_hop_per_flit_pj: float = 2.6
    # memory-controller front-end cache, per 64B line access (~1MB SRAM)
    e_cache_access_pj: float = 12.0
    # off-chip DDR4 access energy ~ 15-20 pJ/bit interface+core; use
    # 16 pJ/bit = 128 pJ/byte
    e_dram_per_byte_pj: float = 128.0
    # PCIe 3.1 host link: paper Table 2 cites 5 mW/Gb/lane -> 5 pJ/bit
    e_pcie_per_byte_pj: float = 40.0

    def mac_instr(self, simd: int) -> float:
        """Energy of one SIMD MADD instruction (pJ), incl. fetch/ctrl/OPM."""
        return (self.e_mac_lane_pj * simd + self._instr_overhead())

    def alu_instr(self, simd: int) -> float:
        return (self.e_alu_lane_pj * simd + self._instr_overhead())

    def _instr_overhead(self) -> float:
        # fetch + decode/control + 3 operand-RAM reads + 1 write
        return (self.e_iram_fetch_pj + self.e_ctrl_per_instr_pj
                + 4 * self.e_opm_access_pj)


DEFAULT_ENERGY = EnergyModel()
