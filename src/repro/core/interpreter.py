"""Functional oracle for RISC-NN programs.

Executes an :class:`~repro.core.exeblock.ExecutionGraph` with exact ISA
semantics — including PREREAD operand-capture, result forwarding and
sparse-PC-inc skipping — over a numpy machine state.  This is the
reference against which the Pallas kernels, the performance model and
the generated dataflow programs are validated.

Scheduling semantics: blocks run in dataflow (topological) order, ties
broken by (priority desc, name).  Within a block, stages run in order
LD → CAL → FLOW → ST.  This sequentialisation is a *refinement* of the
hardware's overlapped schedule: the activation protocol (paper Fig 4)
guarantees any overlapped execution computes the same values, which is
property-tested in ``tests/test_core_interpreter.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from . import lut
from .exeblock import ExecutionGraph, ExeBlock, Task
from .isa import Instr, Op, SIMD_WIDTH, Stage

__all__ = ["MachineState", "run_graph", "run_block"]


@dataclass
class _PEState:
    """Architectural state of one PE (paper Fig 3/7)."""
    opm: np.ndarray  # (entries, simd) float32
    # PREREAD capture registers (addr, data); one-time use (paper §3.7)
    preread_addr: list = field(default_factory=lambda: [None, None])
    preread_data: list = field(default_factory=lambda: [None, None])
    # previous-cycle result forwarding (paper §3.7)
    result_addr: Optional[int] = None
    result_data: Optional[np.ndarray] = None


@dataclass
class MachineState:
    """DRAM + the PE array.  DRAM is word-addressed; one word = one SIMD
    vector (the 128-bit datapath of Table 2 moves SIMD-8 x 16-bit)."""
    n_pes: int = 64
    simd: int = SIMD_WIDTH
    opm_entries: int = 2048
    dram: Dict[int, np.ndarray] = field(default_factory=dict)
    pes: list = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.pes:
            self.pes = [
                _PEState(opm=np.zeros((self.opm_entries, self.simd), np.float32))
                for _ in range(self.n_pes)
            ]

    # -- DRAM helpers --------------------------------------------------------
    def dram_read(self, addr: int) -> np.ndarray:
        v = self.dram.get(addr)
        if v is None:
            v = np.zeros(self.simd, np.float32)
        return v

    def dram_write(self, addr: int, value: np.ndarray) -> None:
        self.dram[addr] = np.asarray(value, np.float32).copy()

    def dram_write_array(self, base: int, arr: np.ndarray) -> None:
        """Lay a (n, simd) array into DRAM words base..base+n-1."""
        arr = np.asarray(arr, np.float32).reshape(-1, self.simd)
        for i, row in enumerate(arr):
            self.dram[base + i] = row.copy()

    def dram_read_array(self, base: int, n: int) -> np.ndarray:
        return np.stack([self.dram_read(base + i) for i in range(n)])


def _read_operand(pe: _PEState, port: int, addr: int) -> np.ndarray:
    """READ-stage operand fetch with PREREAD bypass (paper §3.7).

    If the operand address matches the port's PreRead Addr Reg the captured
    data is used and the register pair is invalidated (one-time use).
    """
    if port in (0, 1) and pe.preread_addr[port] == addr:
        data = pe.preread_data[port]
        pe.preread_addr[port] = None
        pe.preread_data[port] = None
        return data
    return pe.opm[addr].copy()


def _forwarded(pe: _PEState, addr: int, value: np.ndarray) -> np.ndarray:
    """EXE-stage RAW forwarding: if the operand address equals the previous
    instruction's result address, use the Result Data Reg (paper §3.7)."""
    if pe.result_addr == addr and pe.result_data is not None:
        return pe.result_data
    return value


_ARITH = {
    Op.ADD: lambda a, b, c: a + b,
    Op.SUB: lambda a, b, c: a - b,
    Op.MUL: lambda a, b, c: a * b,
    Op.MAX: lambda a, b, c: np.maximum(a, b),
    Op.MIN: lambda a, b, c: np.minimum(a, b),
    Op.MADD: lambda a, b, c: a * b + c,
}


def _exec_instr(state: MachineState, pe_id: int, ins: Instr,
                ld_base: int, st_base: int) -> None:
    pe = state.pes[pe_id]
    op = ins.op
    if op is Op.LD:
        pe.opm[ins.f0] = state.dram_read(ld_base + ((ins.f1 << 16) | ins.f2))
    elif op is Op.ST:
        val = pe.opm[ins.f0]
        val = lut.apply_lookup(ins.lookup_type, val)
        state.dram_write(st_base + ((ins.f1 << 16) | ins.f2), val)
    elif op is Op.COPY:
        state.pes[ins.f2].opm[ins.f1] = pe.opm[ins.f0].copy()
    elif op is Op.PREREAD0:
        pe.preread_addr[0] = ins.f0
        pe.preread_data[0] = pe.opm[ins.f0].copy()
    elif op is Op.PREREAD1:
        pe.preread_addr[1] = ins.f1
        pe.preread_data[1] = pe.opm[ins.f1].copy()
    else:  # six arithmetic CAL ops
        a = _forwarded(pe, ins.f0, _read_operand(pe, 0, ins.f0))
        b = _forwarded(pe, ins.f1, _read_operand(pe, 1, ins.f1))
        c = _forwarded(pe, ins.f2, _read_operand(pe, 2, ins.f2))
        res = _ARITH[op](a, b, c).astype(np.float32)
        pe.opm[ins.f2] = res
        pe.result_addr = ins.f2
        pe.result_data = res.copy()


def run_block(state: MachineState, block: ExeBlock, *,
              ld_base: int = 0, st_base: int = 0,
              pe_map: Optional[dict] = None) -> None:
    """Execute one ExeBlock's stages in order on its (mapped) PE."""
    pe_id = (pe_map or {}).get(block.logical_pe, block.logical_pe)
    pe = state.pes[pe_id]
    # forwarding / preread registers do not survive across blocks: the CAL
    # unit is re-armed per ExeBlock (control unit resets at Reset Step).
    pe.result_addr = None
    pe.result_data = None
    pe.preread_addr = [None, None]
    pe.preread_data = [None, None]
    for pc in block.executed_pcs():
        ins = block.instrs[pc]
        if ins.op is Op.COPY and pe_map is not None:
            ins = Instr(Op.COPY, f0=ins.f0, f1=ins.f1,
                        f2=pe_map.get(ins.f2, ins.f2),
                        sparse_pc_inc=ins.sparse_pc_inc)
        _exec_instr(state, pe_id, ins, ld_base, st_base)


def _schedule(task: Task) -> list[ExeBlock]:
    """Dataflow order with deterministic tie-break (priority desc, name)."""
    preds = task.predecessors()
    indeg = {n: len(p) for n, p in preds.items()}
    ready = sorted(
        (b for b in task.blocks if indeg[b.name] == 0),
        key=lambda b: (-b.priority, b.name),
    )
    order: list[ExeBlock] = []
    while ready:
        b = ready.pop(0)
        order.append(b)
        for s in b.successors:
            indeg[s] -= 1
            if indeg[s] == 0:
                nb = task.block(s)
                # insert keeping (priority desc, name) order
                i = 0
                while i < len(ready) and (-ready[i].priority, ready[i].name) <= (
                        -nb.priority, nb.name):
                    i += 1
                ready.insert(i, nb)
    if len(order) != len(task.blocks):
        raise ValueError(f"task {task.task_id}: dataflow graph has a cycle")
    return order


def run_graph(graph: ExecutionGraph, state: Optional[MachineState] = None, *,
              pe_map: Optional[dict] = None,
              n_pes: int = 64) -> MachineState:
    """Execute a whole application; returns the final machine state."""
    if state is None:
        state = MachineState(n_pes=n_pes)
    for task in graph.tasks:
        order = _schedule(task)
        for _ in range(task.repeats):
            for block in order:
                run_block(state, block, ld_base=task.ld_base,
                          st_base=task.st_base, pe_map=pe_map)
    return state
