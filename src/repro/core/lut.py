"""In-DRAM table lookup for complex activation/classifier functions (§3.9).

RISC-NN keeps its ISA free of transcendentals: an ``ST`` instruction with a
non-zero 4-bit *In-DRAM Lookup Type* routes the stored value through a
2^16-entry table held in DRAM (128 KB per table) by the memory-side
*In-DRAM Table Loader*.

For 16-bit operands the lookup is *exact*: every representable input has
its own table entry.  We reproduce that contract with a Q8.8 fixed-point
key (the paper's arithmetic is 16-bit fixed point): ``index =
round(x * 256)`` clamped to int16, so the table covers [-128, 128) with
1/256 resolution — exact for any value the 16-bit datapath can hold.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

__all__ = [
    "TABLE_ENTRIES", "TABLE_BYTES", "LOOKUP_TYPES", "quantize_u16",
    "build_table", "apply_lookup", "lookup_fn",
]

TABLE_ENTRIES = 1 << 16
TABLE_BYTES = TABLE_ENTRIES * 2  # 128 KB, paper §3.9
_FRAC_BITS = 8
_SCALE = 1 << _FRAC_BITS


def quantize_u16(x: np.ndarray) -> np.ndarray:
    """Q8.8 fixed-point key of ``x`` as a u16 table index."""
    q = np.clip(np.rint(np.asarray(x, np.float64) * _SCALE), -32768, 32767)
    return q.astype(np.int16).view(np.uint16)


def dequantize(idx: np.ndarray) -> np.ndarray:
    return idx.astype(np.uint16).view(np.int16).astype(np.float32) / _SCALE


#: 4-bit In-DRAM Lookup Type -> function.  Type 0 = plain store (no lookup).
LOOKUP_TYPES: Dict[int, Callable[[np.ndarray], np.ndarray]] = {
    1: lambda x: 1.0 / (1.0 + np.exp(-x)),            # sigmoid
    2: np.tanh,                                        # tanh
    3: np.exp,                                         # exp (softmax numerator)
    4: lambda x: np.log(np.maximum(x, 1e-6)),          # log
    5: lambda x: 1.0 / np.where(np.abs(x) < 1e-6, 1e-6, x),  # reciprocal (VDV)
    6: lambda x: 0.5 * x * (1 + np.tanh(
        np.sqrt(2 / np.pi) * (x + 0.044715 * x ** 3))),  # gelu(tanh)
    7: lambda x: np.log1p(np.exp(-np.abs(x))) + np.maximum(x, 0),  # softplus
}


def lookup_fn(lookup_type: int) -> Callable[[np.ndarray], np.ndarray]:
    try:
        return LOOKUP_TYPES[lookup_type]
    except KeyError:
        raise ValueError(f"unknown In-DRAM lookup type {lookup_type}") from None


def build_table(lookup_type: int) -> np.ndarray:
    """The 2^16-entry in-DRAM table for a lookup type (float32 values)."""
    keys = np.arange(TABLE_ENTRIES, dtype=np.uint16)
    xs = dequantize(keys)
    return lookup_fn(lookup_type)(xs.astype(np.float64)).astype(np.float32)


_TABLE_CACHE: Dict[int, np.ndarray] = {}


def apply_lookup(lookup_type: int, x: np.ndarray) -> np.ndarray:
    """Memory-controller semantics: value -> table[quantize(value)]."""
    if lookup_type == 0:
        return np.asarray(x, np.float32)
    tab = _TABLE_CACHE.get(lookup_type)
    if tab is None:
        tab = _TABLE_CACHE[lookup_type] = build_table(lookup_type)
    return tab[quantize_u16(x)]
