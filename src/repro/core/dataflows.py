"""CNN dataflow program generators — the five reuse schemes of paper §5.2.

Following the paper (which follows Eyeriss' taxonomy), a 2-D convolution
is decomposed into *work items*: one work item = the partial sum of one
(output position, output channel) pair over ONE input channel's kh x kw
plane (K MADDs).  A task iteration processes a panel of
``n_blocks x items_per_block`` work items (the paper's AlexNet_CONV2
programs use 64 blocks x 4 items = 256 psum-updates; Table 6).

Schemes and their work-item panels:

* ``NO_REUSE``     — any panel; every item LDs its own weights/ifmap/psum.
* ``FILTER_REUSE`` — 256 positions x 1 output channel: the single weight
  plane is loaded once and multicast over a <=3-ary ExeBlock tree
  (MAX_SUCCESSORS = 3 forces trees — this is why the paper's FLOW stage
  matters).
* ``IFMAP_REUSE``  — 1 position x 256 output channels: the single ifmap
  patch is loaded once and multicast.
* ``CONV_REUSE``   — 16 x 16 grid with a Task-Prepare; weight planes shared
  within channel groups, ifmap shared *partially* via sliding-window
  overlap along position chains (only the kh new rows are loaded).
* ``ALL_REUSE``    — 16 x 16 grid with a Task-Prepare; both weight planes
  and ifmap patches fully shared along both grid axes.

Static-count ground truth (AlexNet_CONV2, Table 6) is asserted in
``tests/test_dataflows.py``: No/Filter/Ifmap reproduce the paper's
LD/CAL/COPY/ST/OPM counts **exactly**; Conv/All reproduce CAL/ST exactly
and LD/COPY to the paper's ordering (the paper's exact multicast
decomposition for those two is not published; see DESIGN.md).
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .exeblock import ExeBlock, ExecutionGraph, Task
from .isa import Instr, Op, make_copy, make_ld, make_st

__all__ = ["ConvSpec", "Reuse", "build_conv_program", "conv_reference",
           "PAPER_TABLE6", "ALEXNET_CONV2"]


class Reuse(enum.Enum):
    NO_REUSE = "no_reuse"
    CONV_REUSE = "conv_reuse"
    FILTER_REUSE = "filter_reuse"
    IFMAP_REUSE = "ifmap_reuse"
    ALL_REUSE = "all_reuse"


@dataclass(frozen=True)
class ConvSpec:
    """One conv layer (single input-channel chunk per task)."""
    name: str
    in_ch: int
    out_ch: int
    kh: int
    kw: int
    ih: int          # padded input height (pad included by caller)
    iw: int
    stride: int = 1
    batch: int = 8   # = SIMD width: one DRAM word carries 8 images

    @property
    def oh(self) -> int:
        return (self.ih - self.kh) // self.stride + 1

    @property
    def ow(self) -> int:
        return (self.iw - self.kw) // self.stride + 1

    @property
    def k(self) -> int:
        return self.kh * self.kw


#: AlexNet CONV2: 27x27x96 -> 27x27x256, 5x5 pad 2 (padded input 31x31)
ALEXNET_CONV2 = ConvSpec("AlexNet_CONV2", in_ch=96, out_ch=256,
                         kh=5, kw=5, ih=31, iw=31)

#: paper Table 6 — static counts for AlexNet_CONV2 (per instance)
PAPER_TABLE6: Dict[Reuse, Dict[str, int]] = {
    Reuse.NO_REUSE: dict(ld=13056, cal=6400, copy=0, st=256,
                         exeblocks=64, opm_entries=13056),
    Reuse.CONV_REUSE: dict(ld=2976, cal=6400, copy=15200, st=256,
                           exeblocks=256, opm_entries=13056),
    Reuse.FILTER_REUSE: dict(ld=6681, cal=6400, copy=1575, st=256,
                             exeblocks=120, opm_entries=8256),
    Reuse.IFMAP_REUSE: dict(ld=6681, cal=6400, copy=1575, st=256,
                            exeblocks=120, opm_entries=8256),
    Reuse.ALL_REUSE: dict(ld=1136, cal=6400, copy=8400, st=256,
                          exeblocks=254, opm_entries=8256),
}


# ---------------------------------------------------------------------------
# DRAM layout (word addresses; one word = one SIMD vector over batch)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class _Layout:
    spec: ConvSpec

    def w(self, o: int, c: int, k: int) -> int:
        s = self.spec
        return (o * s.in_ch + c) * s.k + k

    def x(self, c: int, y: int, xx: int) -> int:
        s = self.spec
        return s.out_ch * s.in_ch * s.k + (c * s.ih + y) * s.iw + xx

    def p(self, o: int, pos: int) -> int:
        s = self.spec
        return (s.out_ch * s.in_ch * s.k + s.in_ch * s.ih * s.iw
                + o * s.oh * s.ow + pos)

    def patch_offsets(self, c: int, pos: int) -> List[int]:
        s = self.spec
        py, px = divmod(pos, s.ow)
        return [self.x(c, py * s.stride + dy, px * s.stride + dx)
                for dy in range(s.kh) for dx in range(s.kw)]

    def patch_row_offsets(self, c: int, pos: int, dy: int) -> List[int]:
        s = self.spec
        py, px = divmod(pos, s.ow)
        return [self.x(c, py * s.stride + dy, px * s.stride + dx)
                for dx in range(s.kw)]


class _PEAlloc:
    """Per-logical-PE OPM bump allocator with shared-entry interning."""

    def __init__(self) -> None:
        self.next: Dict[int, int] = {}
        self.interned: Dict[Tuple[int, object], int] = {}

    def fresh(self, pe: int, n: int = 1) -> List[int]:
        start = self.next.get(pe, 0)
        self.next[pe] = start + n
        return list(range(start, start + n))

    def shared(self, pe: int, key: object, n: int = 1) -> Tuple[List[int], bool]:
        """Addresses for a shared datum; returns (addrs, first_time)."""
        k = (pe, key)
        if k in self.interned:
            return self.interned[k], False
        addrs = self.fresh(pe, n)
        self.interned[k] = addrs
        return addrs, True


def _madd_chain(w_addrs: Sequence[int], x_addrs: Sequence[int],
                p_addr: int) -> List[Instr]:
    return [Instr(Op.MADD, f0=w, f1=x, f2=p_addr)
            for w, x in zip(w_addrs, x_addrs)]


def _tree_children(n: int, arity: int = 3) -> Dict[int, List[int]]:
    """Children of node i in a complete `arity`-ary tree over n nodes."""
    return {i: [c for c in range(i * arity + 1, i * arity + 1 + arity)
                if c < n] for i in range(n)}


# ---------------------------------------------------------------------------
# scheme builders
# ---------------------------------------------------------------------------
def _panel(spec: ConvSpec, scheme: Reuse, n_items: int,
           instance: int) -> List[Tuple[int, int]]:
    """Work-item panel [(out_channel, position)] for a scheme."""
    npos_total = spec.oh * spec.ow
    base_pos = (instance * n_items) % max(npos_total, 1)
    if scheme is Reuse.FILTER_REUSE:
        o = instance % spec.out_ch
        return [(o, (base_pos + i) % npos_total) for i in range(n_items)]
    if scheme is Reuse.IFMAP_REUSE:
        pos = base_pos % npos_total
        return [((instance + i) % spec.out_ch, pos) for i in range(n_items)]
    side = int(math.isqrt(n_items))
    assert side * side == n_items, "grid schemes need a square panel"
    items = []
    for ci in range(side):
        for pi in range(side):
            items.append((((instance * side) + ci) % spec.out_ch,
                          (base_pos + pi) % npos_total))
    if scheme in (Reuse.CONV_REUSE, Reuse.ALL_REUSE):
        return items
    # NO_REUSE: same grid panel (counts are panel-independent)
    return items


def build_conv_program(spec: ConvSpec, scheme: Reuse, *,
                       n_pes: int = 64, items_per_block: int = 4,
                       channel: int = 0, instance: int = 0,
                       n_items: Optional[int] = None,
                       repeats: int = 1) -> ExecutionGraph:
    """Generate the ExecutionGraph of one task iteration of a scheme."""
    n_items = n_items or n_pes * items_per_block
    lay = _Layout(spec)
    alloc = _PEAlloc()
    items = _panel(spec, scheme, n_items, instance)
    c = channel
    pe_base = (instance * 17) % n_pes  # decorrelate instances across PEs

    def pe_of(i: int) -> int:
        return (pe_base + i) % n_pes

    if scheme is Reuse.NO_REUSE:
        tasks = [_build_no_reuse(spec, lay, alloc, items, c,
                                 items_per_block, pe_of, instance, repeats)]
    elif scheme is Reuse.FILTER_REUSE:
        tasks = [_build_single_share(spec, lay, alloc, items, c,
                                     items_per_block, pe_of, instance,
                                     share="filter", repeats=repeats)]
    elif scheme is Reuse.IFMAP_REUSE:
        tasks = [_build_single_share(spec, lay, alloc, items, c,
                                     items_per_block, pe_of, instance,
                                     share="ifmap", repeats=repeats)]
    elif scheme is Reuse.CONV_REUSE:
        tasks = _build_grid(spec, lay, alloc, items, c, pe_of, instance,
                            partial_ifmap=True, repeats=repeats)
    else:
        tasks = _build_grid(spec, lay, alloc, items, c, pe_of, instance,
                            partial_ifmap=False, repeats=repeats)
    return ExecutionGraph(name=f"{spec.name}:{scheme.value}:i{instance}",
                          tasks=tasks)


def _build_no_reuse(spec, lay, alloc, items, c, ipb, pe_of, instance,
                    repeats) -> Task:
    blocks = []
    for bi in range(0, len(items), ipb):
        pe = pe_of(bi // ipb)
        ins: List[Instr] = []
        cal: List[Instr] = []
        st: List[Instr] = []
        for (o, pos) in items[bi:bi + ipb]:
            w = alloc.fresh(pe, spec.k)
            x = alloc.fresh(pe, spec.k)
            (p,) = alloc.fresh(pe, 1)
            ins += [make_ld(a, lay.w(o, c, k)) for k, a in enumerate(w)]
            ins += [make_ld(a, off)
                    for a, off in zip(x, lay.patch_offsets(c, pos))]
            ins.append(make_ld(p, lay.p(o, pos)))
            cal += _madd_chain(w, x, p)
            st.append(make_st(p, lay.p(o, pos)))
        blocks.append(ExeBlock(name=f"nr{instance}_b{bi // ipb}",
                               instrs=ins + cal + st, logical_pe=pe))
    return Task(task_id=instance * 10, blocks=blocks, repeats=repeats)


def _build_single_share(spec, lay, alloc, items, c, ipb, pe_of, instance,
                        share: str, repeats: int) -> Task:
    """Filter- or Ifmap-Reuse: one shared datum multicast over a 3-ary
    tree embedded in the compute blocks themselves."""
    n_blocks = len(items) // ipb
    children = _tree_children(n_blocks)
    if share == "filter":
        o0 = items[0][0]
        shared_offs = [lay.w(o0, c, k) for k in range(spec.k)]
    else:
        pos0 = items[0][1]
        shared_offs = lay.patch_offsets(c, pos0)

    # every block keeps the shared datum at the same OPM logical address
    shared_addr: Dict[int, List[int]] = {}
    for b in range(n_blocks):
        pe = pe_of(b)
        addrs, _ = alloc.shared(pe, ("shared", share, instance), spec.k)
        shared_addr[b] = addrs

    blocks = []
    for b in range(n_blocks):
        pe = pe_of(b)
        ins: List[Instr] = []
        cal: List[Instr] = []
        flow: List[Instr] = []
        st: List[Instr] = []
        if b == 0:  # root loads the shared datum
            ins += [make_ld(a, off)
                    for a, off in zip(shared_addr[0], shared_offs)]
        for (o, pos) in items[b * ipb:(b + 1) * ipb]:
            if share == "filter":
                x = alloc.fresh(pe, spec.k)
                ins += [make_ld(a, off)
                        for a, off in zip(x, lay.patch_offsets(c, pos))]
                w = shared_addr[b]
            else:
                w = alloc.fresh(pe, spec.k)
                ins += [make_ld(a, lay.w(o, c, k)) for k, a in enumerate(w)]
                x = shared_addr[b]
            (p,) = alloc.fresh(pe, 1)
            ins.append(make_ld(p, lay.p(o, pos)))
            cal += _madd_chain(w, x, p)
            st.append(make_st(p, lay.p(o, pos)))
        for ch in children[b]:
            flow += [make_copy(src, dst, pe_of(ch))
                     for src, dst in zip(shared_addr[b], shared_addr[ch])]
        blocks.append(ExeBlock(
            name=f"{share[0]}r{instance}_b{b}", instrs=ins + cal + flow + st,
            logical_pe=pe,
            successors=[f"{share[0]}r{instance}_b{ch}" for ch in children[b]]))
    return Task(task_id=instance * 10 + 1, blocks=blocks, repeats=repeats)


def _build_grid(spec, lay, alloc, items, c, pe_of, instance,
                partial_ifmap: bool, repeats: int) -> List[Task]:
    """Conv-Reuse (partial_ifmap=True) / All-Reuse grid schemes with a
    Task-Prepare (paper Fig 10).

    Grid: side x side items, rows = channel groups, cols = position chains.
    Placement: item (ci, pi) -> PE (pi % 16) * 4 + (ci % 4) — channel
    groups span 16 PEs, position groups span 4, so fully-shared multicasts
    are copy-once-per-PE (Inter-ExeBlock reuse on co-resident blocks).
    """
    side = int(math.isqrt(len(items)))
    tag = "cr" if partial_ifmap else "ar"
    t_prep_blocks: List[ExeBlock] = []
    t_main_blocks: List[ExeBlock] = []

    def item_pe(ci: int, pi: int) -> int:
        return pe_of((pi % 16) * 4 + (ci % 4))

    # --- weight planes: one loader per channel group, multicast to the
    # distinct PEs of the group (shared at the same logical address).
    w_addr: Dict[Tuple[int, int], List[int]] = {}   # (ci, pe) -> addrs
    for ci in range(side):
        o = items[ci * side][0]
        group_pes = []
        for pi in range(side):
            pe = item_pe(ci, pi)
            if pe not in group_pes:
                group_pes.append(pe)
        loader_pe = group_pes[0]
        addrs0, first = alloc.shared(loader_pe, ("w", ci, instance), spec.k)
        w_addr[(ci, loader_pe)] = addrs0
        ins = [make_ld(a, lay.w(o, c, k)) for k, a in enumerate(addrs0)] \
            if first else []
        flow: List[Instr] = []
        for pe in group_pes[1:]:
            dst, fresh = alloc.shared(pe, ("w", ci, instance), spec.k)
            w_addr[(ci, pe)] = dst
            if fresh:
                flow += [make_copy(s, d, pe) for s, d in zip(addrs0, dst)]
        t_prep_blocks.append(ExeBlock(name=f"{tag}{instance}_wload{ci}",
                                      instrs=ins + flow,
                                      logical_pe=loader_pe))

    # --- ifmap: All-Reuse shares whole patches across channel groups;
    # Conv-Reuse loads the first patch per (channel-group, chain) and the
    # kh new rows for each subsequent position (sliding-window overlap).
    x_addr: Dict[Tuple[int, int, int], List[int]] = {}  # (ci,pi,·)->addrs
    if not partial_ifmap:
        for pi in range(side):
            pos = items[pi][1]
            group_pes = []
            for ci in range(side):
                pe = item_pe(ci, pi)
                if pe not in group_pes:
                    group_pes.append(pe)
            loader_pe = group_pes[0]
            addrs0, first = alloc.shared(loader_pe, ("x", pi, instance),
                                         spec.k)
            ins = [make_ld(a, off) for a, off in
                   zip(addrs0, lay.patch_offsets(c, items[pi][1]))] \
                if first else []
            flow = []
            for pe in group_pes[1:]:
                dst, fresh = alloc.shared(pe, ("x", pi, instance), spec.k)
                if fresh:
                    flow += [make_copy(s, d, pe) for s, d in zip(addrs0, dst)]
            for ci in range(side):
                pe = item_pe(ci, pi)
                x_addr[(ci, pi, 0)], _ = alloc.shared(
                    pe, ("x", pi, instance), spec.k)
            t_prep_blocks.append(ExeBlock(name=f"{tag}{instance}_xload{pi}",
                                          instrs=ins + flow,
                                          logical_pe=loader_pe))

    # --- main task: one block per work item
    for ci in range(side):
        for pi in range(side):
            o, pos = items[ci * side + pi]
            pe = item_pe(ci, pi)
            ins: List[Instr] = []
            flow: List[Instr] = []
            succ: List[str] = []
            w = w_addr[(ci, pe)]
            if partial_ifmap:
                # chain along positions: first block loads the full patch,
                # later blocks receive the kh*(kw - stride... ) overlap rows
                # from the predecessor and load only the new columns.
                addrs, fresh = alloc.shared(pe, ("xc", ci, pi, instance),
                                            spec.k)
                if pi == 0:
                    if fresh:
                        ins += [make_ld(a, off) for a, off in
                                zip(addrs, lay.patch_offsets(c, pos))]
                else:
                    # overlap: columns shift by `stride`; new cols per row
                    new_per_row = min(spec.stride, spec.kw)
                    for dy in range(spec.kh):
                        row_offs = lay.patch_row_offsets(c, pos, dy)
                        row_addrs = addrs[dy * spec.kw:(dy + 1) * spec.kw]
                        ins += [make_ld(a, off) for a, off in
                                zip(row_addrs[-new_per_row:],
                                    row_offs[-new_per_row:])]
                x = addrs
                if pi + 1 < side:
                    nxt_pe = item_pe(ci, pi + 1)
                    nxt, _ = alloc.shared(nxt_pe, ("xc", ci, pi + 1,
                                                   instance), spec.k)
                    overlap = spec.k - spec.kh * min(spec.stride, spec.kw)
                    # forward the overlapping entries (shifted by stride cols)
                    for dy in range(spec.kh):
                        for dx in range(spec.kw - spec.stride):
                            src = addrs[dy * spec.kw + dx + spec.stride]
                            dst = nxt[dy * spec.kw + dx]
                            flow.append(make_copy(src, dst, nxt_pe))
                    del overlap
                    succ.append(f"{tag}{instance}_m{ci}_{pi + 1}")
            else:
                x = x_addr[(ci, pi, 0)]
            (p,) = alloc.fresh(pe, 1)
            ins.append(make_ld(p, lay.p(o, pos)))
            cal = _madd_chain(w, x, p)
            st = [make_st(p, lay.p(o, pos))]
            t_main_blocks.append(ExeBlock(
                name=f"{tag}{instance}_m{ci}_{pi}",
                instrs=ins + cal + flow + st, logical_pe=pe,
                successors=succ))

    prep = Task(task_id=instance * 10 + 2, blocks=t_prep_blocks)
    main = Task(task_id=instance * 10 + 3, blocks=t_main_blocks,
                repeats=repeats)
    return [prep, main]


# ---------------------------------------------------------------------------
# reference + DRAM seeding for functional validation
# ---------------------------------------------------------------------------
def seed_dram(state, spec: ConvSpec, weights: np.ndarray, ifmap: np.ndarray,
              psums: Optional[np.ndarray] = None) -> None:
    """Lay (out_ch,in_ch,kh,kw) weights, (in_ch,ih,iw,batch) ifmap and
    optional (out_ch,oh*ow,batch) initial psums into interpreter DRAM."""
    lay = _Layout(spec)
    for o in range(spec.out_ch):
        for c in range(spec.in_ch):
            for k in range(spec.k):
                dy, dx = divmod(k, spec.kw)
                state.dram_write(lay.w(o, c, k),
                                 np.broadcast_to(weights[o, c, dy, dx],
                                                 (spec.batch,)))
    for c in range(spec.in_ch):
        for y in range(spec.ih):
            for xx in range(spec.iw):
                state.dram_write(lay.x(c, y, xx), ifmap[c, y, xx])
    if psums is not None:
        for o in range(spec.out_ch):
            for pos in range(spec.oh * spec.ow):
                state.dram_write(lay.p(o, pos), psums[o, pos])


def read_psums(state, spec: ConvSpec,
               items: Sequence[Tuple[int, int]]) -> np.ndarray:
    lay = _Layout(spec)
    return np.stack([state.dram_read(lay.p(o, pos)) for o, pos in items])


def conv_reference(spec: ConvSpec, weights: np.ndarray, ifmap: np.ndarray,
                   channel: int,
                   items: Sequence[Tuple[int, int]],
                   psums0: Optional[np.ndarray] = None) -> np.ndarray:
    """Pure-numpy oracle: partial sums over one input channel."""
    out = []
    for o, pos in items:
        py, px = divmod(pos, spec.ow)
        acc = np.zeros(spec.batch, np.float32) if psums0 is None \
            else psums0[o, pos].astype(np.float32).copy()
        for dy in range(spec.kh):
            for dx in range(spec.kw):
                acc += (weights[o, channel, dy, dx]
                        * ifmap[channel, py * spec.stride + dy,
                                px * spec.stride + dx])
        out.append(acc)
    return np.stack(out)


def panel_items(spec: ConvSpec, scheme: Reuse, *, n_items: int = 256,
                instance: int = 0) -> List[Tuple[int, int]]:
    return _panel(spec, scheme, n_items, instance)
