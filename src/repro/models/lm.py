"""Unified decoder-only LM covering the dense / MoE / hybrid / SSM / VLM
architectures of the assigned pool.

A model is a stack of blocks; each block is `mix` (attention, local
attention, RG-LRU or RWKV time-mix) + `ffn` (SwiGLU / GELU MLP, MoE, or
RWKV channel-mix), pre-normed with residual adds.  Homogeneous stacks
are `lax.scan`'d over stacked parameters (compile-time O(1) in depth —
mandatory for the 80-layer config under 512-way SPMD); heterogeneous
stacks (recurrentgemma's 1:2 pattern, DeepSeek's leading dense layer)
unroll.

Three entry points, matching the assigned input shapes:

* ``apply``       — logits over a full sequence (training fwd).
* ``prefill``     — same math + returns a decode cache.
* ``decode_step`` — one token against the cache (serve_step).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import constrain
from .base import ParamSpec, init_params, abstract_params
from . import components as C
from . import rglru as R
from . import rwkv6 as W

__all__ = ["DecoderLM"]


def _stack_specs(spec_tree, n: int):
    """Prefix every leaf with a stacked ("layers",) axis."""
    return jax.tree.map(
        lambda ps: ParamSpec((n,) + ps.shape, ("layers",) + ps.axes,
                             ps.dtype, ps.init),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


class DecoderLM:
    def __init__(self, cfg):
        self.cfg = cfg
        kinds = cfg.layer_kinds
        first_dense = cfg.moe.first_dense if cfg.moe else 0
        # scan when every layer is structurally identical
        self.scanned = len(set(kinds)) == 1 and first_dense in (0,)
        self.first_dense = first_dense
        if first_dense:
            self.scanned = len(set(kinds[first_dense:])) == 1
        self.kinds = kinds

    # ------------------------------------------------------------------
    # parameter specs
    # ------------------------------------------------------------------
    def _block_specs(self, kind: str, use_moe: bool,
                     dense_ff: Optional[int] = None) -> dict:
        cfg = self.cfg
        s: Dict[str, Any] = {"ln1": C.norm_specs(cfg.d_model, cfg.norm_kind)}
        if kind in ("attn", "local_attn"):
            s["mix"] = C.attn_specs(cfg)
        elif kind == "rglru":
            s["mix"] = R.rglru_block_specs(cfg)
        elif kind == "rwkv":
            s["mix"] = W.rwkv_time_specs(cfg)
        else:
            raise ValueError(kind)
        s["ln2"] = C.norm_specs(cfg.d_model, cfg.norm_kind)
        if kind == "rwkv":
            s["ffn"] = W.rwkv_ffn_specs(cfg)
        elif use_moe:
            s["ffn"] = C.moe_specs(cfg)
        else:
            s["ffn"] = C.mlp_specs(cfg, dense_ff)
        return s

    def _layer_uses_moe(self, i: int) -> bool:
        return self.cfg.moe is not None and i >= self.first_dense

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs = self._param_specs_f32()
        from .base import with_param_dtype
        return with_param_dtype(specs, cfg.param_dtype)

    def _param_specs_f32(self) -> dict:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": C.embed_specs(cfg),
            "final_norm": C.norm_specs(cfg.d_model, cfg.norm_kind),
        }
        if self.scanned:
            n = cfg.n_layers - self.first_dense
            body = self._block_specs(self.kinds[-1],
                                     cfg.moe is not None)
            specs["layers"] = _stack_specs(body, n)
            for i in range(self.first_dense):
                specs[f"dense_layer_{i}"] = self._block_specs(
                    self.kinds[i], False, cfg.moe.dense_d_ff)
        else:
            for i, kind in enumerate(self.kinds):
                specs[f"layer_{i:02d}"] = self._block_specs(
                    kind, self._layer_uses_moe(i))
        return specs

    def init(self, rng: jax.Array):
        return init_params(self.param_specs(), rng)

    def abstract(self):
        return abstract_params(self.param_specs())

    # ------------------------------------------------------------------
    # block application
    # ------------------------------------------------------------------
    def _apply_block(self, kind: str, use_moe: bool, p, x, *,
                     positions, mrope_positions, cache, cache_pos, train):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = C.apply_norm(p["ln1"], x, cfg.norm_kind, cfg.norm_eps)
        new_cache: Dict[str, Any] = {}
        if kind in ("attn", "local_attn"):
            window = cfg.local_window if kind == "local_attn" else None
            mix, kv = C.attention_block(
                p["mix"], h, cfg, positions=positions, window=window,
                mrope_positions=mrope_positions,
                cache=None if cache is None else cache["kv"],
                cache_pos=cache_pos)
            if window is not None and cache is None:       # prefill->ring
                kv = {"k": kv["k"][:, -window:], "v": kv["v"][:, -window:]}
            new_cache["kv"] = kv
        elif kind == "rglru":
            mix, rec = R.rglru_block(
                p["mix"], h, cfg,
                state=None if cache is None else cache["rec"])
            new_cache["rec"] = rec
        else:  # rwkv
            mix, att = W.rwkv_time_block(
                p["mix"], h, cfg,
                state=None if cache is None else cache["att"])
            new_cache["att"] = att
        x = x + mix
        h2 = C.apply_norm(p["ln2"], x, cfg.norm_kind, cfg.norm_eps)
        if kind == "rwkv":
            f, ffn = W.rwkv_channel_block(
                p["ffn"], h2, cfg,
                state=None if cache is None else cache["ffn"])
            new_cache["ffn"] = ffn
        elif use_moe:
            f, aux = C.moe_block(p["ffn"], h2, cfg)
        else:
            f = C.mlp_block(p["ffn"], h2, cfg)
        x = x + f
        x = constrain(x, ("batch", "seq", "act_embed"))
        return x, aux, new_cache

    # ------------------------------------------------------------------
    # forward entry points
    # ------------------------------------------------------------------
    def _embed_inputs(self, params, batch, dtype):
        cfg = self.cfg
        x = C.embed_tokens(params["embed"], batch["tokens"], cfg, dtype)
        if cfg.n_patches and "patch_embeds" in batch:
            # VLM stub frontend: precomputed patch embeddings replace the
            # leading placeholder tokens (brief: frontend is a stub).
            pe = batch["patch_embeds"].astype(dtype)
            x = lax.dynamic_update_slice(x, pe, (0, 0, 0))
        return x

    def _positions(self, batch):
        B, S = batch["tokens"].shape
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                   (B, S))
        return pos

    def apply(self, params, batch, *, train: bool = True,
              want_cache: bool = False, want_hidden: bool = False):
        """Full-sequence forward.  Returns (logits, aux_dict); with
        ``want_hidden`` returns the final-norm hidden states instead of
        logits (the chunked-loss path never materializes (B,S,V))."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        x = self._embed_inputs(params, batch, dtype)
        positions = self._positions(batch)
        mrope = batch.get("mrope_positions")
        aux_total = jnp.zeros((), jnp.float32)
        caches: Dict[str, Any] = {}

        # leading unscanned dense layers (DeepSeek pattern)
        for i in range(self.first_dense):
            blk = functools.partial(
                self._apply_block, self.kinds[i], False,
                positions=positions, mrope_positions=mrope,
                cache=None, cache_pos=None, train=train)
            if train and cfg.remat == "full":
                blk = jax.checkpoint(blk)
            x, aux, c = blk(params[f"dense_layer_{i}"], x)
            aux_total += aux
            caches[f"dense_layer_{i}"] = c

        if self.scanned:
            kind = self.kinds[-1]
            use_moe = cfg.moe is not None

            def body(x, lp):
                y, aux, c = self._apply_block(
                    kind, use_moe, lp, x, positions=positions,
                    mrope_positions=mrope, cache=None, cache_pos=None,
                    train=train)
                if not want_cache:
                    c = None
                return y, (aux, c)

            if train and cfg.remat == "full":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            x, (auxs, cs) = lax.scan(body, x, params["layers"])
            aux_total += auxs.sum()
            if want_cache:
                caches["layers"] = cs
        else:
            for i in range(self.first_dense, cfg.n_layers):
                blk = functools.partial(
                    self._apply_block, self.kinds[i], self._layer_uses_moe(i),
                    positions=positions, mrope_positions=mrope,
                    cache=None, cache_pos=None, train=train)
                if train and cfg.remat == "full":
                    blk = jax.checkpoint(blk)
                x, aux, c = blk(params[f"layer_{i:02d}"], x)
                aux_total += aux
                caches[f"layer_{i:02d}"] = c

        x = C.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        out_aux = {"moe_aux": aux_total}
        if want_hidden:
            return x, out_aux
        logits = C.unembed(params["embed"], x, cfg)
        if want_cache:
            caches["pos"] = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
            return logits, out_aux, caches
        return logits, out_aux

    def prefill(self, params, batch, *, max_len: Optional[int] = None):
        """Forward + decode cache (the ``prefill_*`` shapes).  Returns
        (last-token logits, cache).  ``max_len`` > prompt length pads
        the full-attention KV caches with decode headroom (ring-buffer
        and recurrent states are fixed-size and need none)."""
        logits, _, cache = self.apply(params, batch, train=False,
                                      want_cache=True)
        S = batch["tokens"].shape[1]
        if max_len is not None and max_len > S:
            cache = self._pad_cache(cache, max_len - S)
        return logits[:, -1], cache

    def _pad_cache(self, cache, extra: int):
        cfg = self.cfg

        def pad_kv(kv, axis):
            pad = [(0, 0)] * kv["k"].ndim
            pad[axis] = (0, extra)
            return {n: jnp.pad(kv[n], pad) for n in ("k", "v")}

        out = dict(cache)
        if self.scanned and self.kinds[-1] == "attn":
            out["layers"] = dict(cache["layers"])
            out["layers"]["kv"] = pad_kv(cache["layers"]["kv"], axis=2)
        elif not self.scanned:
            for i in range(self.first_dense, cfg.n_layers):
                name = f"layer_{i:02d}"
                if self.kinds[i] == "attn":
                    out[name] = dict(cache[name])
                    out[name]["kv"] = pad_kv(cache[name]["kv"], axis=1)
        for i in range(self.first_dense):         # leading dense layers
            name = f"dense_layer_{i}"
            if self.kinds[i] == "attn":
                out[name] = dict(cache[name])
                out[name]["kv"] = pad_kv(cache[name]["kv"], axis=1)
        return out

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _block_cache_specs(self, kind: str, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        if kind == "attn":
            shp = (batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
            ax = ("batch", "kv_seq", "act_heads", None)
            return {"kv": {"k": ParamSpec(shp, ax, jnp.bfloat16),
                           "v": ParamSpec(shp, ax, jnp.bfloat16)}}
        if kind == "local_attn":
            w = min(cfg.local_window, seq_len)
            shp = (batch, w, cfg.n_kv_heads, cfg.head_dim)
            ax = ("batch", "kv_seq", "act_heads", None)
            return {"kv": {"k": ParamSpec(shp, ax, jnp.bfloat16),
                           "v": ParamSpec(shp, ax, jnp.bfloat16)}}
        if kind == "rglru":
            return {"rec": R.rglru_state_specs(cfg, batch)}
        if kind == "rwkv":
            s = W.rwkv_state_specs(cfg, batch)
            return {
                "att": {"shift": s["att_shift"], "wkv": s["wkv"]},
                "ffn": {"shift": s["ffn_shift"]},
            }
        raise ValueError(kind)

    def cache_specs(self, batch: int, seq_len: int) -> dict:
        """ParamSpec tree for a decode cache of capacity ``seq_len``."""
        cfg = self.cfg
        specs: Dict[str, Any] = {}
        if self.scanned:
            n = cfg.n_layers - self.first_dense
            specs["layers"] = _stack_specs(
                self._block_cache_specs(self.kinds[-1], batch, seq_len), n)
            for i in range(self.first_dense):
                specs[f"dense_layer_{i}"] = self._block_cache_specs(
                    self.kinds[i], batch, seq_len)
        else:
            for i, kind in enumerate(self.kinds):
                specs[f"layer_{i:02d}"] = self._block_cache_specs(
                    kind, batch, seq_len)
        specs["pos"] = ParamSpec((), (), jnp.int32)
        return specs

    def init_cache(self, batch: int, seq_len: int):
        return jax.tree.map(
            lambda ps: jnp.zeros(ps.shape, ps.dtype),
            self.cache_specs(batch, seq_len),
            is_leaf=lambda x: isinstance(x, ParamSpec))

    def decode_step(self, params, cache, tokens):
        """One decode step.  tokens: (B, 1).  Returns (logits, new_cache)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        pos = cache["pos"]                                  # scalar
        B = tokens.shape[0]
        batch = {"tokens": tokens,
                 "positions": jnp.full((B, 1), pos, jnp.int32)}
        if cfg.rope_kind == "mrope":
            # text-only decode: all three m-rope ids advance with t
            batch["mrope_positions"] = jnp.full((B, 3, 1), pos, jnp.int32)
        x = self._embed_inputs(params, batch, dtype)
        positions = batch["positions"]
        mrope = batch.get("mrope_positions")
        new_cache: Dict[str, Any] = {"pos": pos + 1}

        for i in range(self.first_dense):
            x, _, c = self._apply_block(
                self.kinds[i], False, params[f"dense_layer_{i}"], x,
                positions=positions, mrope_positions=mrope,
                cache=cache[f"dense_layer_{i}"], cache_pos=pos, train=False)
            new_cache[f"dense_layer_{i}"] = c

        if self.scanned:
            kind = self.kinds[-1]
            use_moe = cfg.moe is not None

            def body(x, inp):
                lp, lc = inp
                y, _, c = self._apply_block(
                    kind, use_moe, lp, x, positions=positions,
                    mrope_positions=mrope, cache=lc, cache_pos=pos,
                    train=False)
                return y, c
            x, cs = lax.scan(body, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = cs
        else:
            for i in range(self.first_dense, cfg.n_layers):
                x, _, c = self._apply_block(
                    self.kinds[i], self._layer_uses_moe(i),
                    params[f"layer_{i:02d}"], x, positions=positions,
                    mrope_positions=mrope, cache=cache[f"layer_{i:02d}"],
                    cache_pos=pos, train=False)
                new_cache[f"layer_{i:02d}"] = c

        x = C.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        logits = C.unembed(params["embed"], x, cfg)
        return logits[:, 0], new_cache

    # ------------------------------------------------------------------
    # paged decode (continuous batching)
    # ------------------------------------------------------------------
    def supports_paged_decode(self) -> bool:
        """Paged decode covers scanned full-attention stacks (the dense
        GQA family).  Ring-buffer and recurrent-state families have
        fixed-size caches — paging buys them nothing."""
        return (self.scanned and self.first_dense == 0
                and set(self.kinds) == {"attn"}
                and self.cfg.rope_kind != "mrope")

    def paged_state_specs(self, batch: int, *, n_pages: int,
                          page_size: int, max_pages_per_seq: int) -> dict:
        cfg = self.cfg
        shp = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads,
               cfg.head_dim)
        ax = ("layers", None, "kv_seq", "act_heads", None)
        return {
            "k_pages": ParamSpec(shp, ax, jnp.bfloat16),
            "v_pages": ParamSpec(shp, ax, jnp.bfloat16),
            "page_tables": ParamSpec((batch, max_pages_per_seq),
                                     ("batch", None), jnp.int32),
            "lengths": ParamSpec((batch,), ("batch",), jnp.int32),
        }

    def prefill_chunk_paged(self, params, state, tokens, table_rows,
                            starts, n_valid, tp_axis=None):
        """Ingest one prompt chunk each for up to B requests into the
        paged KV cache (batched chunked prefill) in one dispatch.

        ``tokens``: (B, C) — row b holds its request's next C prompt
        tokens at absolute positions ``starts[b] + t``; tokens with
        t >= ``n_valid[b]`` are padding and rows with
        ``n_valid[b] == 0`` are inactive (their K/V writes land on the
        null page).  ``table_rows``: (B, nb) int32 — each request's
        page table truncated to the dispatch's context bucket, null
        beyond a row's own pages.  ``starts`` / ``n_valid``: (B,)
        traced int32, so one compile serves every mix of chunks in the
        bucket — which requests co-ingest can never change numerics.
        Returns (per-row last-valid-token logits (B, V), new page
        state); a row's logits are only meaningful on the chunk that
        completes its prompt.

        Token-exactness: the flash partition is anchored at absolute
        position 0, the K/V gathered back from pages carry the same
        bf16 bits whole-prompt prefill would have produced (compute
        dtype == page dtype), and every other op is per-(row, token) —
        so any chunking of a prompt, dispatched alone or co-batched,
        reproduces ``prefill``'s last-token logits and cache
        bit-for-bit (components.paged_chunk_attention_block).

        ``tp_axis``: mesh axis name when running as the per-shard body
        of a tensor-parallel ``shard_map`` program (serve/parallel.py;
        ``self`` is then the shard-local model view).
        """
        assert self.supports_paged_decode()
        cfg = self.cfg
        assert not (tp_axis is not None and cfg.moe is not None)
        dtype = jnp.dtype(cfg.compute_dtype)
        n = tokens.shape[1]
        positions = starts[:, None] + jnp.arange(n, dtype=jnp.int32)[None]
        x = self._embed_inputs(
            params, {"tokens": tokens, "positions": positions}, dtype)
        use_moe = cfg.moe is not None

        def body(x, inp):
            lp, kp, vp = inp
            h = C.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            mix, k, v = C.paged_chunk_attention_block(
                lp["mix"], h, cfg, positions=positions, starts=starts,
                n_valid=n_valid, k_pages=kp, v_pages=vp,
                table_rows=table_rows, tp_axis=tp_axis)
            x = x + mix
            h2 = C.apply_norm(lp["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            if use_moe:
                f, _ = C.moe_block(lp["ffn"], h2, cfg)
            else:
                f = C.mlp_block(lp["ffn"], h2, cfg, tp_axis=tp_axis)
            return x + f, (k, v)

        x, (ks, vs) = lax.scan(
            body, x, (params["layers"], state["k_pages"],
                      state["v_pages"]))
        # persist every row's chunk K/V for every layer in one stacked
        # scatter (tokens t >= n_valid[b] are routed to null page 0;
        # write-target pages are private per row — COW at admission —
        # so co-ingested rows can never scatter into each other)
        ps_ = state["k_pages"].shape[2]
        pid, slot = C.chunk_scatter_targets(starts, n_valid, table_rows,
                                            n, ps_)
        k_pages = state["k_pages"].at[:, pid, slot].set(
            ks.astype(state["k_pages"].dtype))
        v_pages = state["v_pages"].at[:, pid, slot].set(
            vs.astype(state["v_pages"].dtype))
        x = C.apply_norm(params["final_norm"], x, cfg.norm_kind,
                         cfg.norm_eps)
        last = jnp.take_along_axis(
            x, jnp.maximum(n_valid - 1, 0)[:, None, None], axis=1)
        logits = C.unembed(params["embed"], last, cfg)
        return logits[:, 0], {"k_pages": k_pages, "v_pages": v_pages}

    def decode_step_paged(self, params, state, tokens, tp_axis=None):
        """One continuous-batching decode step against a paged KV cache.

        ``state``: {k_pages, v_pages: (L, P, ps, KVH, Dh); page_tables:
        (B, n) int32; lengths: (B,) int32}.  ``tokens``: (B, 1).  Each
        sequence decodes at its own position ``lengths[b]`` (no
        lockstep).  Returns (logits (B, V), new state) with lengths
        advanced; callers that mask inactive slots (the serve engine)
        own the authoritative lengths host-side.

        ``tp_axis``: mesh axis name when running as the per-shard body
        of a tensor-parallel ``shard_map`` program (serve/parallel.py).
        """
        assert self.supports_paged_decode()
        cfg = self.cfg
        assert not (tp_axis is not None and cfg.moe is not None)
        dtype = jnp.dtype(cfg.compute_dtype)
        lengths = state["lengths"]
        tables = state["page_tables"]
        positions = lengths[:, None].astype(jnp.int32)
        x = self._embed_inputs(
            params, {"tokens": tokens, "positions": positions}, dtype)
        use_moe = cfg.moe is not None

        def body(x, inp):
            lp, kp, vp = inp
            h = C.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            mix, kp, vp = C.paged_attention_block(
                lp["mix"], h, cfg, positions=positions, k_pages=kp,
                v_pages=vp, page_table=tables, lengths=lengths,
                tp_axis=tp_axis)
            x = x + mix
            h2 = C.apply_norm(lp["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            if use_moe:
                f, _ = C.moe_block(lp["ffn"], h2, cfg)
            else:
                f = C.mlp_block(lp["ffn"], h2, cfg, tp_axis=tp_axis)
            return x + f, (kp, vp)

        x, (k_pages, v_pages) = lax.scan(
            body, x, (params["layers"], state["k_pages"],
                      state["v_pages"]))
        x = C.apply_norm(params["final_norm"], x, cfg.norm_kind,
                         cfg.norm_eps)
        logits = C.unembed(params["embed"], x, cfg)
        return logits[:, 0], {"k_pages": k_pages, "v_pages": v_pages,
                              "page_tables": tables,
                              "lengths": lengths + 1}

    def verify_step_paged(self, params, state, tokens, tp_axis=None):
        """Score T tokens per request in one batched pass against the
        paged KV cache (speculative-decode verification).

        ``tokens``: (B, T) — row b's token 0 is its last confirmed
        token, tokens 1..T-1 a draft continuation; token t sits at the
        per-request absolute position ``lengths[b] + t``.  All T
        tokens' K/V are persisted into pages and each query attends
        causally up to its own position, so ``logits[:, t]`` is
        bit-identical to what ``decode_step_paged`` would return after
        sequentially consuming tokens 0..t (same per-token projections,
        same gathered-buffer softmax shape — docs/speculative.md spells
        out the argument).  T = 1 degenerates to exactly one decode
        step.

        Returns (logits (B, T, V), new state).  ``lengths`` is returned
        *unadvanced*: how many of the T positions become real history
        depends on host-side acceptance, and the caller (serve
        scheduler) owns the authoritative lengths — rejected positions
        hold stale page writes that masking hides, like any slot past
        ``lengths``.
        """
        assert self.supports_paged_decode()
        cfg = self.cfg
        assert not (tp_axis is not None and cfg.moe is not None)
        dtype = jnp.dtype(cfg.compute_dtype)
        lengths = state["lengths"]
        tables = state["page_tables"]
        B, T = tokens.shape
        positions = (lengths[:, None]
                     + jnp.arange(T, dtype=jnp.int32)[None, :])
        x = self._embed_inputs(
            params, {"tokens": tokens, "positions": positions}, dtype)
        use_moe = cfg.moe is not None

        def body(x, inp):
            lp, kp, vp = inp
            h = C.apply_norm(lp["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            mix, kp, vp = C.paged_verify_attention_block(
                lp["mix"], h, cfg, positions=positions, k_pages=kp,
                v_pages=vp, page_table=tables, lengths=lengths,
                tp_axis=tp_axis)
            x = x + mix
            h2 = C.apply_norm(lp["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            if use_moe:
                f, _ = C.moe_block(lp["ffn"], h2, cfg)
            else:
                f = C.mlp_block(lp["ffn"], h2, cfg, tp_axis=tp_axis)
            return x + f, (kp, vp)

        x, (k_pages, v_pages) = lax.scan(
            body, x, (params["layers"], state["k_pages"],
                      state["v_pages"]))
        x = C.apply_norm(params["final_norm"], x, cfg.norm_kind,
                         cfg.norm_eps)
        logits = C.unembed(params["embed"], x, cfg)
        return logits, {"k_pages": k_pages, "v_pages": v_pages,
                        "page_tables": tables, "lengths": lengths}

    def fused_step_paged(self, params, state, d_tokens, p_tokens,
                         p_table_rows, p_starts, p_n_valid,
                         tp_axis=None):
        """One fused engine step: decode/verify every DECODING slot AND
        ingest one prompt chunk for every PREFILLING request in a
        single program dispatch (the steady-state step of the serve
        engine collapses from two launches to one).

        ``d_tokens``: (B, T) — the decode/verify rows, exactly as
        ``decode_step_paged`` (T == 1) / ``verify_step_paged`` (T > 1)
        would receive them, positioned by ``state["lengths"]``.
        ``p_tokens`` / ``p_table_rows`` / ``p_starts`` / ``p_n_valid``:
        the chunked-prefill rows, exactly as ``prefill_chunk_paged``
        would receive them (inactive rows null-routed).  Returns
        ``((d_logits (B, T, V), p_logits (Bp, V)), new state)`` with
        ``lengths`` unadvanced (the host owns authoritative lengths).

        Token-exactness vs the two sequential dispatches rests on page
        **write/read disjointness**: prefill rows scatter only into
        their own private pages (copy-on-write at admission; shared
        trie pages are read-only), decode rows write only into pages
        ``ensure_headroom`` privatized for them, decode gathers only
        active-slot tables (which never contain a prefill row's private
        pages) and prefill gathers only its own table prefix (which
        never contains a decode write target).  Both groups therefore
        read the *incoming* pages — exactly what each would see
        dispatched separately in either order — and their page scatters
        land on disjoint (page, slot) targets, so the combined update
        commutes.  Inside one step every other op is row-independent
        (components.paged_chunk_attention_block /
        paged_verify_attention_block), so each row is bit-identical to
        its unfused counterpart.
        """
        assert self.supports_paged_decode()
        cfg = self.cfg
        assert not (tp_axis is not None and cfg.moe is not None)
        dtype = jnp.dtype(cfg.compute_dtype)
        lengths = state["lengths"]
        tables = state["page_tables"]
        B, T = d_tokens.shape
        Cn = p_tokens.shape[1]
        d_positions = (lengths[:, None]
                       + jnp.arange(T, dtype=jnp.int32)[None, :])
        p_positions = (p_starts[:, None]
                       + jnp.arange(Cn, dtype=jnp.int32)[None])
        xd = self._embed_inputs(
            params, {"tokens": d_tokens, "positions": d_positions},
            dtype)
        xp = self._embed_inputs(
            params, {"tokens": p_tokens, "positions": p_positions},
            dtype)
        use_moe = cfg.moe is not None

        def body(carry, inp):
            xd, xp = carry
            lp, kp, vp = inp
            # prefill attention first, reading the incoming pages
            # (decode's writes are not in any prefill table — see
            # disjointness above — so the order is unobservable);
            # its K/V persists in one stacked scatter after the scan
            hp = C.apply_norm(lp["ln1"], xp, cfg.norm_kind, cfg.norm_eps)
            mixp, k, v = C.paged_chunk_attention_block(
                lp["mix"], hp, cfg, positions=p_positions,
                starts=p_starts, n_valid=p_n_valid, k_pages=kp,
                v_pages=vp, table_rows=p_table_rows, tp_axis=tp_axis)
            xp = xp + mixp
            hp2 = C.apply_norm(lp["ln2"], xp, cfg.norm_kind,
                               cfg.norm_eps)
            if use_moe:
                fp, _ = C.moe_block(lp["ffn"], hp2, cfg)
            else:
                fp = C.mlp_block(lp["ffn"], hp2, cfg, tp_axis=tp_axis)
            xp = xp + fp
            # decode/verify rows: T == 1 verification IS the decode
            # step bit for bit (paged_verify_attention_block), so one
            # body serves both widths
            hd = C.apply_norm(lp["ln1"], xd, cfg.norm_kind, cfg.norm_eps)
            mixd, kp, vp = C.paged_verify_attention_block(
                lp["mix"], hd, cfg, positions=d_positions, k_pages=kp,
                v_pages=vp, page_table=tables, lengths=lengths,
                tp_axis=tp_axis)
            xd = xd + mixd
            hd2 = C.apply_norm(lp["ln2"], xd, cfg.norm_kind,
                               cfg.norm_eps)
            if use_moe:
                fd, _ = C.moe_block(lp["ffn"], hd2, cfg)
            else:
                fd = C.mlp_block(lp["ffn"], hd2, cfg, tp_axis=tp_axis)
            xd = xd + fd
            return (xd, xp), (kp, vp, k, v)

        (xd, xp), (k_pages, v_pages, ks, vs) = lax.scan(
            body, (xd, xp), (params["layers"], state["k_pages"],
                             state["v_pages"]))
        # persist the prefill chunks' K/V into the decode-updated pages
        # (disjoint write targets, so this commutes with the decode
        # writes already applied in the scan)
        ps_ = state["k_pages"].shape[2]
        pid, slot = C.chunk_scatter_targets(p_starts, p_n_valid,
                                            p_table_rows, Cn, ps_)
        k_pages = k_pages.at[:, pid, slot].set(ks.astype(k_pages.dtype))
        v_pages = v_pages.at[:, pid, slot].set(vs.astype(v_pages.dtype))
        xd = C.apply_norm(params["final_norm"], xd, cfg.norm_kind,
                          cfg.norm_eps)
        d_logits = C.unembed(params["embed"], xd, cfg)
        xp = C.apply_norm(params["final_norm"], xp, cfg.norm_kind,
                          cfg.norm_eps)
        last = jnp.take_along_axis(
            xp, jnp.maximum(p_n_valid - 1, 0)[:, None, None], axis=1)
        p_logits = C.unembed(params["embed"], last, cfg)[:, 0]
        return (d_logits, p_logits), {
            "k_pages": k_pages, "v_pages": v_pages,
            "page_tables": tables, "lengths": lengths}
