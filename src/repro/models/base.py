"""Parameter-spec machinery shared by the model zoo.

A model's parameters are declared once as a pytree of :class:`ParamSpec`
(shape + dtype + logical axes + initializer).  From that single source
of truth we derive:

* ``init_params``     — concrete initialization (PRNG-splitting per leaf),
* ``abstract_params`` — ``ShapeDtypeStruct`` stand-ins for the dry-run,
* sharding trees      — via :func:`repro.sharding.tree_shardings`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = ["ParamSpec", "init_params", "abstract_params", "normal", "zeros",
           "ones", "const"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                     # logical dim names, same rank as shape
    dtype: jnp.dtype = jnp.float32
    init: Optional[Callable] = None  # (key, shape, dtype) -> array

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"rank mismatch: {self.shape} vs {self.axes}")


def normal(stddev: float) -> Callable:
    def f(key, shape, dtype):
        return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    return f


def fan_in(shape: Sequence[int]) -> Callable:
    """Truncated-normal with 1/sqrt(fan_in) scale (fan_in = dim 0 … or
    dims up to the last for stacked expert weights)."""
    fi = math.prod(shape[:-1]) if len(shape) > 1 else shape[0]
    return normal(1.0 / math.sqrt(max(fi, 1)))


def zeros(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype):
    return jnp.ones(shape, dtype)


def const(v: float) -> Callable:
    def f(key, shape, dtype):
        return jnp.full(shape, v, dtype)
    return f


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree, rng: jax.Array):
    """Initialize a ParamSpec tree.  Splits the key deterministically per
    leaf path so layer stacking / reordering keeps leaves reproducible."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, ps in zip(keys, leaves):
        init = ps.init
        if init is None:
            init = fan_in(ps.shape)
        out.append(init(k, ps.shape, ps.dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, ps.dtype),
        spec_tree, is_leaf=_is_spec)


def with_param_dtype(spec_tree, dtype):
    """Retarget >=2D f32 params to ``dtype`` (bf16 storage + gathers;
    1D norms/biases stay f32)."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float32:
        return spec_tree

    def retag(ps):
        if ps.dtype == jnp.float32 and len(ps.shape) >= 2:
            return dataclasses.replace(ps, dtype=dtype)
        return ps
    return jax.tree.map(retag, spec_tree, is_leaf=_is_spec)
