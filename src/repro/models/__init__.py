from .base import ParamSpec, init_params, abstract_params  # noqa: F401
from .lm import DecoderLM  # noqa: F401
from .whisper import WhisperModel  # noqa: F401


def build_model(cfg):
    """Dispatch a ModelConfig to its model class."""
    if cfg.is_encoder_decoder:
        return WhisperModel(cfg)
    return DecoderLM(cfg)
