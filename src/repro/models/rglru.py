"""Griffin recurrent block: causal conv1d + RG-LRU (arXiv:2402.19427).

The RG-LRU is a gated linear recurrence

    r_t = sigmoid(x_t Wr + br)          (recurrence gate)
    i_t = sigmoid(x_t Wi + bi)          (input gate)
    log a_t = -c * softplus(L) * r_t    (c = 8, L learned)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

— a diagonal linear SSM, so training uses an O(log S) associative scan
and decode is a single fused multiply-add per step (state = h only).
This is the key sub-quadratic path for the ``long_500k`` shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import constrain
from .base import ParamSpec, zeros, normal

C_FACTOR = 8.0
CONV_WIDTH = 4


def rglru_block_specs(cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "w_gate": ParamSpec((d, w), ("embed", "ff")),
        "w_in": ParamSpec((d, w), ("embed", "ff")),
        "conv_w": ParamSpec((CONV_WIDTH, w), ("conv", "ff"),
                            init=normal(0.1)),
        "conv_b": ParamSpec((w,), ("stats",), init=zeros),
        "wr": ParamSpec((w, w), ("ff", None)),
        "br": ParamSpec((w,), ("stats",), init=zeros),
        "wi": ParamSpec((w, w), ("ff", None)),
        "bi": ParamSpec((w,), ("stats",), init=zeros),
        # Lambda parameterized so a = sigmoid(L)^c spreads over (0.9, 0.999)
        "lam": ParamSpec((w,), ("stats",),
                         init=lambda k, s, d_: jax.random.uniform(
                             k, s, jnp.float32, 0.38, 0.8).astype(d_)),
        "w_out": ParamSpec((w, d), ("ff", "embed")),
    }


def _gates(p, x):
    """x: (..., W) f32 -> (log_a, gated_x) both f32."""
    r = jax.nn.sigmoid(x @ p["wr"].astype(jnp.float32)
                       + p["br"].astype(jnp.float32))
    i = jax.nn.sigmoid(x @ p["wi"].astype(jnp.float32)
                       + p["bi"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    gx = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * x)
    return log_a, gx


def rglru_scan(p, x):
    """x: (B,S,W) -> (B,S,W) via associative scan (training/prefill)."""
    xf = x.astype(jnp.float32)
    log_a, gx = _gates(p, xf)
    a = jnp.exp(log_a)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, h = lax.associative_scan(combine, (a, gx), axis=1)
    return h.astype(x.dtype), h[:, -1]                     # (out, final f32)


def rglru_step(p, x, h_prev):
    """x: (B,1,W); h_prev: (B,W) f32 -> (out (B,1,W), h (B,W))."""
    xf = x[:, 0].astype(jnp.float32)
    log_a, gx = _gates(p, xf)
    h = jnp.exp(log_a) * h_prev + gx
    return h[:, None].astype(x.dtype), h


def causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv, width CONV_WIDTH.

    x: (B,S,W).  With ``state`` (B, CONV_WIDTH-1, W) runs one decode step
    (S == 1) returning (y, new_state).
    """
    if state is not None:
        buf = jnp.concatenate([state, x], axis=1)          # (B,4,W)
        y = jnp.einsum("bkw,kw->bw", buf.astype(jnp.float32),
                       w.astype(jnp.float32)) + b.astype(jnp.float32)
        return y[:, None].astype(x.dtype), buf[:, 1:]
    pad = jnp.pad(x, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    frames = jnp.stack(
        [pad[:, i:i + x.shape[1]] for i in range(CONV_WIDTH)], axis=2)
    y = jnp.einsum("bskw,kw->bsw", frames.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x.dtype), None


def rglru_block(p, x, cfg, *, state=None):
    """Full Griffin recurrent block.

    x: (B,S,D).  ``state`` (decode): {"conv": (B,3,W), "h": (B,W)}.
    Returns (out (B,S,D), new_state | None).
    """
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_in"].astype(x.dtype)
    u = constrain(u, ("batch", None, "act_ff"))
    if state is None:
        u_raw = u
        u, _ = causal_conv1d(u, p["conv_w"], p["conv_b"])
        h, h_final = rglru_scan(p, u)
        # prefill: expose the final recurrence + conv state (DCE'd in train)
        new_state = {"conv": u_raw[:, -(CONV_WIDTH - 1):],
                     "h": h_final}
    else:
        u, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"],
                                      state=state["conv"])
        h, h_new = rglru_step(p, u, state["h"])
        new_state = {"conv": conv_state, "h": h_new}
    out = (gate * h) @ p["w_out"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "act_embed")), new_state


def rglru_state_specs(cfg, batch: int) -> dict:
    w = cfg.lru_width
    return {
        "conv": ParamSpec((batch, CONV_WIDTH - 1, w), ("batch", None, "ff"),
                          dtype=jnp.bfloat16),
        "h": ParamSpec((batch, w), ("batch", "ff"), dtype=jnp.float32),
    }
