"""RWKV6 "Finch" blocks (arXiv:2404.05892): data-dependent decay WKV.

Time-mixing is a linear recurrence over a matrix state per head

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (S: Dk x Dv)
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

with per-channel, per-step decay w_t = exp(-exp(ww_t)) produced by a
token-shifted low-rank projection of the input (the "data-dependent"
part that distinguishes Finch from RWKV5).

Training/prefill uses the **chunked-parallel** form (chunk = 32): an
O(L^2) intra-chunk matrix plus an O(1)-state inter-chunk scan — this is
the standard sub-quadratic schedule and the reason the 500k-token shape
is feasible.  Exponent safety: ww is clamped to <= 1 so every within-
chunk cumulative exponent is <= 31 * e < 88 (f32 exp range); all other
exponents are <= 0 by construction.  Decode is a single FMA per step.

The decoupled structure (stage the chunk operands, burst the MACs,
carry S) is the paper's LD/CAL/FLOW staging; `kernels/wkv_chunk` is the
Pallas version of the inner chunk kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import constrain
from .base import ParamSpec, normal, zeros, ones

TM_LORA = 32     # token-mix ddlerp low-rank dim
DECAY_LORA = 64
CHUNK = 32
WW_CLAMP = 1.0   # ww <= 1  ->  per-step log-decay >= -e


def rwkv_time_specs(cfg) -> dict:
    d = cfg.d_model
    h, dh = cfg.n_heads, cfg.head_dim
    mu = lambda: ParamSpec((d,), ("stats",),  # noqa: E731
                           init=lambda k, s, dt: jax.random.uniform(
                               k, s, jnp.float32).astype(dt))
    return {
        "mu_x": mu(), "mu_w": mu(), "mu_k": mu(), "mu_v": mu(),
        "mu_r": mu(), "mu_g": mu(),
        "tm_w1": ParamSpec((d, 5 * TM_LORA), ("embed", None),
                           init=normal(1e-3)),
        "tm_w2": ParamSpec((5, TM_LORA, d), (None, None, "embed"),
                           init=normal(1e-3)),
        "w0": ParamSpec((d,), ("stats",),
                        init=lambda k, s, dt: jnp.linspace(
                            -6.0, -0.5, s[0]).astype(dt)),
        "wd1": ParamSpec((d, DECAY_LORA), ("embed", None), init=normal(1e-3)),
        "wd2": ParamSpec((DECAY_LORA, d), (None, "embed"), init=normal(1e-3)),
        "wr": ParamSpec((d, h * dh), ("embed", "q_heads")),
        "wk": ParamSpec((d, h * dh), ("embed", "q_heads")),
        "wv": ParamSpec((d, h * dh), ("embed", "q_heads")),
        "wg": ParamSpec((d, h * dh), ("embed", "q_heads")),
        "wo": ParamSpec((h * dh, d), ("q_heads", "embed")),
        "u": ParamSpec((h, dh), ("act_heads", None), init=normal(0.3)),
        "ln_x_scale": ParamSpec((h, dh), ("act_heads", None), init=ones),
        "ln_x_bias": ParamSpec((h, dh), ("act_heads", None), init=zeros),
    }


def rwkv_ffn_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    mu = lambda: ParamSpec((d,), ("stats",),  # noqa: E731
                           init=lambda k, s, dt: jax.random.uniform(
                               k, s, jnp.float32).astype(dt))
    return {
        "mu_k": mu(), "mu_r": mu(),
        "wk": ParamSpec((d, f), ("embed", "ff")),
        "wv": ParamSpec((f, d), ("ff", "embed")),
        "wr": ParamSpec((d, d), ("embed", None)),
    }


def _shift(x, state):
    """Token shift: returns x_{t-1} (zeros / carried state at t=0)."""
    if state is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return state[:, None] if x.shape[1] == 1 else NotImplemented


def _ddlerp(p, x, xx):
    """Data-dependent token-shift mix -> (xw, xk, xv, xr, xg)."""
    B, S, D = x.shape
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    z = jnp.tanh(xxx @ p["tm_w1"].astype(x.dtype))
    z = z.reshape(B, S, 5, TM_LORA)
    m = jnp.einsum("bsla,lad->bsld", z, p["tm_w2"].astype(x.dtype))
    mus = jnp.stack([p[k].astype(x.dtype)
                     for k in ("mu_w", "mu_k", "mu_v", "mu_r", "mu_g")])
    mixed = x[:, :, None] + xx[:, :, None] * (mus[None, None] + m)
    return tuple(mixed[:, :, i] for i in range(5))


def _decay(p, xw):
    """Per-channel log-decay lw = -exp(ww), ww clamped for f32 safety."""
    ww = (p["w0"].astype(jnp.float32)
          + jnp.tanh(xw.astype(jnp.float32) @ p["wd1"].astype(jnp.float32))
          @ p["wd2"].astype(jnp.float32))
    return -jnp.exp(jnp.minimum(ww, WW_CLAMP))            # (B,S,D) <= 0


def wkv_chunked(r, k, v, lw, u):
    """Chunked-parallel WKV.

    r,k,v: (B,S,H,Dh); lw: (B,S,H,Dh) log-decay (<=0); u: (H,Dh).
    Returns (B,S,H,Dh).  All math f32.
    """
    B, S, H, Dh = r.shape
    L = min(CHUNK, S)
    while S % L:                   # non-multiple-of-32 prompt lengths
        L -= 1
    n = S // L
    f32 = jnp.float32
    rc, kc, vc, wc = (a.astype(f32).reshape(B, n, L, H, Dh)
                      .transpose(1, 0, 3, 2, 4)            # (n,B,H,L,Dh)
                      for a in (r, k, v, lw))

    def chunk(carry, inp):
        S_state = carry                                    # (B,H,Dk,Dv)
        rc_, kc_, vc_, wc_ = inp                           # (B,H,L,Dh)
        cum = jnp.cumsum(wc_, axis=2) - wc_                # exclusive
        total = cum[:, :, -1:] + wc_[:, :, -1:]            # (B,H,1,Dh)
        # safe exponents: <=0 for q_adj / inter; <= L*e for k_adj
        q_adj = rc_ * jnp.exp(cum - total)
        k_adj = kc_ * jnp.exp(total - (cum + wc_))
        A = jnp.einsum("bhid,bhjd->bhij", q_adj, k_adj)
        mask = jnp.tril(jnp.ones((L, L), bool), k=-1)
        A = jnp.where(mask[None, None], A, 0.0)
        diag = jnp.einsum("bhid,bhid->bhi", rc_, kc_ * u[None, :, None])
        o = (jnp.einsum("bhij,bhjd->bhid", A, vc_)
             + diag[..., None] * vc_)
        o = o + jnp.einsum("bhid,bhde->bhie", rc_ * jnp.exp(cum), S_state)
        S_new = (S_state * jnp.exp(total).transpose(0, 1, 3, 2)
                 + jnp.einsum("bhjd,bhje->bhde", k_adj, vc_))
        return S_new, o

    S0 = jnp.zeros((B, H, Dh, Dh), f32)
    S_final, o = lax.scan(chunk, S0, (rc, kc, vc, wc))
    return o.transpose(1, 0, 3, 2, 4).reshape(B, S, H, Dh), S_final


def wkv_step(r, k, v, lw, u, S_state):
    """One decode step.  r,k,v,lw: (B,1,H,Dh); S_state: (B,H,Dk,Dv)."""
    f32 = jnp.float32
    r_, k_, v_, w_ = (a.astype(f32)[:, 0] for a in (r, k, v, lw))
    kv = jnp.einsum("bhd,bhe->bhde", k_, v_)
    o = jnp.einsum("bhd,bhde->bhe",
                   r_, S_state + u[None, :, :, None] * kv)
    S_new = S_state * jnp.exp(w_)[..., None] + kv
    return o[:, None], S_new


def rwkv_time_block(p, x, cfg, *, state=None):
    """Time-mixing block.  state (decode): {"shift": (B,D), "wkv": (B,H,Dh,Dh)}."""
    B, S, D = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    prev = _shift(x, None if state is None else state["shift"])
    xx = prev - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, xx)
    lw = _decay(p, xw).reshape(B, S, H, Dh)
    r = (xr @ p["wr"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(B, S, H, Dh)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(B, S, H, Dh)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    r = constrain(r, ("batch", None, "act_heads", None))
    k = constrain(k, ("batch", None, "act_heads", None))
    v = constrain(v, ("batch", None, "act_heads", None))
    u = p["u"].astype(jnp.float32)
    if state is None:
        o, s_final = wkv_chunked(r, k, v, lw, u)
        new_state = {"shift": x[:, -1], "wkv": s_final}    # prefill carry-out
    else:
        o, wkv_new = wkv_step(r, k, v, lw, u, state["wkv"])
        new_state = {"shift": x[:, -1], "wkv": wkv_new}
    # per-head group norm, gate, out-proj
    o = o.reshape(B, S, H, Dh)
    from .components import group_norm_heads
    o = group_norm_heads(o.astype(jnp.float32), p["ln_x_scale"],
                         p["ln_x_bias"], 64e-5).astype(x.dtype)
    o = (o.reshape(B, S, H * Dh) * g) @ p["wo"].astype(x.dtype)
    return constrain(o, ("batch", "seq", "act_embed")), new_state


def rwkv_channel_block(p, x, cfg, *, state=None):
    """Channel-mixing FFN with token shift and squared-ReLU."""
    prev = _shift(x, None if state is None else state["shift"])
    xx = prev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    kk = constrain(kk, ("batch", None, "act_ff"))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) \
        * (kk @ p["wv"].astype(x.dtype))
    new_state = {"shift": x[:, -1]}
    return constrain(out, ("batch", "seq", "act_embed")), new_state


def rwkv_state_specs(cfg, batch: int) -> dict:
    h, dh, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {
        "att_shift": ParamSpec((batch, d), ("batch", None),
                               dtype=jnp.bfloat16),
        "ffn_shift": ParamSpec((batch, d), ("batch", None),
                               dtype=jnp.bfloat16),
        "wkv": ParamSpec((batch, h, dh, dh), ("batch", "act_heads", None, None),
                         dtype=jnp.float32),
    }
