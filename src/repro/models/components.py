"""Shared neural-net primitives for the model zoo (pure JAX).

Everything here is a *function of (params, inputs, cfg)* — no classes
hold state.  Attention is implemented flash-style (online-softmax over
KV chunks) so training memory is O(S * chunk), which is what lets the
32k-prefill and 4k-train shapes fit the dry-run HBM budget.  The same
decomposition is what the RISC-NN paper calls decoupled LD/CAL staging:
each KV chunk is one "ExeBlock" whose operands are staged (VMEM / here
registers of the scan carry) before the MAC burst.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import constrain
from .base import ParamSpec, normal, zeros, ones, const

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def norm_specs(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamSpec((d,), ("stats",), init=zeros)}
    return {"scale": ParamSpec((d,), ("stats",), init=ones),
            "bias": ParamSpec((d,), ("stats",), init=zeros)}


def apply_norm(p: dict, x, kind: str, eps: float):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"], eps)
    return layer_norm(x, p["scale"], p.get("bias"), eps)


def group_norm_heads(x, scale, bias, eps):
    """GroupNorm with one group per head; x: (B, S, H, Dh)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings (standard / partial / M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(rot_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                            / rot_dim))


def apply_rope(x, positions, *, theta: float = 1e4, fraction: float = 1.0):
    """x: (B, S, H, Dh); positions: (B, S) int32.  ``fraction`` < 1 rotates
    only the leading slice of Dh (StableLM-style partial rotary)."""
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)                        # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), xp], -1)


def apply_mrope(x, positions, *, theta: float, sections: tuple):
    """Multimodal RoPE (Qwen2-VL §3): positions (B, 3, S) carry the
    (temporal, height, width) ids; the Dh/2 frequency slots are split into
    ``sections`` (e.g. 16/24/24), each rotated by its own position id."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)                        # (half,)
    # pick the section-owner position per frequency slot
    owner = jnp.repeat(jnp.arange(3), jnp.array(sections),
                       total_repeat_length=half)          # (half,)
    pos = positions.astype(jnp.float32)                   # (B,3,S)
    ang = jnp.take(pos, owner, axis=1)                    # (B,half,S)
    ang = jnp.moveaxis(ang, 1, -1) * freqs                # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoid_pos_at(pos: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding at dynamic positions; pos: (B,) -> (B, d)."""
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    ang = pos.astype(jnp.float32)[:, None] * div
    pe = jnp.zeros((pos.shape[0], d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


def sinusoid_pos(seq: int, d: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attn_specs(cfg) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": ParamSpec((d, h * hd), ("embed", "q_heads")),
        "wk": ParamSpec((d, kvh * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, kvh * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((h * hd, d), ("q_heads", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((h * hd,), ("stats",), init=zeros)
        p["bk"] = ParamSpec((kvh * hd,), ("stats",), init=zeros)
        p["bv"] = ParamSpec((kvh * hd,), ("stats",), init=zeros)
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((hd,), ("stats",), init=zeros)
        p["k_norm"] = ParamSpec((hd,), ("stats",), init=zeros)
    return p


def _project_qkv(p, x, cfg, positions, mrope_positions=None):
    """x: (B,S,D) -> q (B,S,H,Dh), k/v (B,S,KVH,Dh), RoPE applied."""
    B, S, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kvh, hd)
    v = v.reshape(B, S, kvh, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_kind == "mrope":
        q = apply_mrope(q, mrope_positions, theta=cfg.rope_theta,
                        sections=cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, theta=cfg.rope_theta,
                        sections=cfg.mrope_sections)
    elif cfg.rope_kind == "rope":
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    # rope_kind == "none": positions handled by additive embeddings.
    # seq deliberately unnamed: under SP rules the residual stream is
    # seq-sharded but attention internals run gathered-seq/sharded-heads
    # (Megatron-SP boundary).
    q = constrain(q, ("batch", None, "act_heads", None))
    k = constrain(k, ("batch", None, "act_heads", None))
    v = constrain(v, ("batch", None, "act_heads", None))
    return q, k, v


def flash_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    kv_chunk: int = 1024, q_offset=0):
    """Online-softmax attention over KV chunks (pure jnp).

    q: (B,Sq,H,Dh); k/v: (B,Skv,KVH,Dh) with H = KVH * G (GQA grouping is
    kept factored — KV is never materialized per Q head).  Memory is
    O(Sq * kv_chunk) per head instead of O(Sq * Skv).

    ``q_offset`` is the absolute position of q[0] (decode / chunked use);
    a (B,)-shaped ``q_offset`` gives every batch row its own offset (the
    batched chunked-prefill shape, where co-ingested requests sit at
    different prompt depths) — masking is then per (row, q, k) but the
    arithmetic is unchanged, so a row's output depends only on its own
    offset and buffer.  Returns (B,Sq,H,Dh).

    The KV chunk partition is *anchored at absolute position 0 with a
    fixed chunk size*: a ragged Skv is padded up to a multiple of
    ``kv_chunk`` and the padded lanes are masked, rather than shrinking
    the chunk size to a divisor of Skv.  Fully-masked lanes are exact
    no-ops for the online-softmax recurrence (max against -1e30 cannot
    win, exp underflows to +0.0, and x+0.0 == x bitwise), so attention
    over a *longer* buffer with the same leading keys produces
    bit-identical outputs.  The serve engine's chunked prefill
    (models/lm.py:prefill_chunk_paged) leans on exactly this: it runs
    the same partition over a gathered page buffer and stays token-exact
    against whole-prompt prefill.
    """
    B, Sq, H, Dh = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qh = q.reshape(B, Sq, KVH, G, Dh)          # keep storage dtype
    pad = -Skv % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Skv + pad) // kv_chunk
    q_offset = jnp.asarray(q_offset)
    # (Sq,) for a shared offset, (B, Sq) for per-row offsets
    q_pos = (q_offset[:, None] if q_offset.ndim else q_offset) \
        + jnp.arange(Sq)
    scale = 1.0 / math.sqrt(Dh)

    kc = k.reshape(B, n_chunks, kv_chunk, KVH, Dh)
    vc = v.reshape(B, n_chunks, kv_chunk, KVH, Dh)

    def body(carry, inputs):
        m, l, acc = carry
        ci, ks, vs = inputs
        # bf16 operands, f32 MXU accumulation: upcasting K/V chunks
        # would double the LD-stage traffic (§Perf iteration log)
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qh, ks,
                       preferred_element_type=jnp.float32) * scale
        k_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        mask = (k_pos < Skv) & jnp.ones((Sq, 1), bool)
        if causal:
            mask = mask & (k_pos <= q_pos[..., None])
        if window is not None:
            mask = mask & (k_pos > q_pos[..., None] - window)
        # (Sq, j) shared mask vs (B, Sq, j) per-row mask
        bmask = mask[:, None, None] if mask.ndim == 3 \
            else mask[None, None, None]
        s = jnp.where(bmask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqj,bjkd->bkgqd", p.astype(vs.dtype), vs,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, Sq, Dh), jnp.float32)
    (m, l, acc), _ = lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dh)  # (B,Sq,KVH,G,Dh)->
    return out.astype(q.dtype)


def local_attention(q, k, v, *, window: int, q_block: int = 256):
    """Banded causal attention: each chunk of ``window`` queries attends
    to its own chunk (causal) and the previous chunk — O(S*W) exactly,
    the sub-quadratic path required for long-context shapes.

    Queries are processed in ``q_block`` sub-blocks through ``lax.map``
    so the live score tensor is (…, q_block, 2W), not (…, W, 2W) —
    1/8th the peak memory at the default block size."""
    B, S, H, Dh = q.shape
    KVH = k.shape[2]
    G = H // KVH
    assert S % window == 0, (S, window)
    n = S // window
    q_block = min(q_block, window)
    nsq = window // q_block
    qh = q.reshape(B, n, window, KVH, G, Dh)
    kc = k.reshape(B, n, window, KVH, Dh)
    vc = v.reshape(B, n, window, KVH, Dh)
    scale = 1.0 / math.sqrt(Dh)
    # previous chunk (zero-padded for chunk 0)
    kp = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vp = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([kp, kc], axis=2)                 # (B,n,2W,KVH,Dh)
    v2 = jnp.concatenate([vp, vc], axis=2)
    jpos = jnp.arange(2 * window) - window                 # rel. to chunk
    has_prev = (jnp.arange(n) > 0)                         # chunk0: no prev

    def one_block(sq_i):
        qs = lax.dynamic_slice_in_dim(qh, sq_i * q_block, q_block, axis=2)
        s = jnp.einsum("bnqkgd,bnjkd->bnkgqj", qs, k2,
                       preferred_element_type=jnp.float32) * scale
        qpos = sq_i * q_block + jnp.arange(q_block)
        mask = (jpos[None, :] <= qpos[:, None]) & \
               (jpos[None, :] > qpos[:, None] - window)    # (qb,2W)
        mask = mask[None] & (has_prev[:, None, None]
                             | (jpos >= 0)[None, None])    # (n,qb,2W)
        s = jnp.where(mask[None, :, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bnkgqj,bnjkd->bnqkgd", p.astype(v2.dtype), v2,
                          preferred_element_type=jnp.float32)

    outs = lax.map(one_block, jnp.arange(nsq))             # (nsq,B,n,qb,...)
    out = jnp.moveaxis(outs, 0, 2)                         # (B,n,nsq,qb,...)
    return out.reshape(B, S, H, Dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int]):
    """Single-token attention against a cache.

    q: (B,1,H,Dh); caches: (B,S_max,KVH,Dh); ``pos``: scalar int — the
    number of tokens already in the cache (batched decode advances in
    lockstep, which keeps the cache update a dynamic_update_slice that
    GSPMD partitions cleanly instead of a scatter it replicates)."""
    B, _, H, Dh = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qh = q.reshape(B, KVH, G, Dh)
    # keep K/V in their bf16 storage dtype; accumulate in f32 on the MXU
    # (upcasting the cache would double its HBM traffic — measured in
    # EXPERIMENTS.md §Perf, stablelm decode iteration)
    s = jnp.einsum("bkgd,bjkd->bkgj", qh, k_cache,
                   preferred_element_type=jnp.float32)     # (B,KVH,G,S)
    s = s * (1.0 / math.sqrt(Dh))
    idx = jnp.arange(S)                                    # (S,)
    valid = idx < pos
    if window is not None:
        valid &= idx >= (pos - window)
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgj,bjkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def update_kv_cache(cache, new, slot):
    """Write one token into a (B, S, KVH, Dh) cache at scalar ``slot``.

    When the cache's sequence dim is sharded (flash-decoding layout,
    ``kv_seq -> model``), a plain dynamic_update_slice at a dynamic
    index forces GSPMD to rematerialize the whole buffer (measured: the
    dominant decode cost).  Instead each seq-shard computes its local
    offset and only the owning shard writes — a shard-local ring write
    with zero collective traffic.
    """
    from ..sharding.rules import _current_mesh, active_rules, logical_spec
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                        (0, slot, 0, 0))
    # the decode cache's canonical logical layout (see cache_specs)
    full = logical_spec(("batch", "kv_seq", "act_heads", None),
                        cache.shape, mesh, active_rules())
    entries = tuple(full) + (None,) * (4 - len(tuple(full)))
    batch_axes, seq_axis = entries[0], entries[1]
    if not isinstance(seq_axis, str) or seq_axis not in mesh.axis_names:
        return lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                        (0, slot, 0, 0))

    from jax.sharding import PartitionSpec as P
    n_shards = mesh.shape[seq_axis]
    s_loc = cache.shape[1] // n_shards
    spec = P(*entries)
    new_spec = P(batch_axes, *([None] * (new.ndim - 1)))

    def local(c, n, p):
        my = lax.axis_index(seq_axis)
        off = p - my * s_loc
        in_range = jnp.logical_and(off >= 0, off < s_loc)

        def write(c):
            return lax.dynamic_update_slice(
                c, n.astype(c.dtype), (0, jnp.clip(off, 0, s_loc - 1),
                                       0, 0))
        return lax.cond(in_range, write, lambda c: c, c)

    from ..sharding.compat import shard_map_compat
    return shard_map_compat(local, mesh=mesh, in_specs=(spec, new_spec, P()),
                            out_specs=spec, check_vma=False)(
                                cache, new, slot)


def _gqa_expand_factor(cfg) -> int:
    """Expand K/V heads to the full Q-head count when the mesh's model
    axis divides H but not KVH.

    Measured motivation (EXPERIMENTS.md §Perf, qwen1.5-110b): with
    KVH=8 on a 16-way model axis GSPMD cannot reshard the 8-way KV
    layout and falls back to "involuntary full rematerialization" —
    replicate + repartition — per layer per microbatch.  Repeating KV
    G-fold makes every head tensor cleanly 16-way shardable; the
    repeated copies are *sharded*, so per-device KV bytes actually
    shrink versus the replicated fallback.
    """
    from ..sharding.rules import _current_mesh
    mesh = _current_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return 1
    m = mesh.shape["model"]
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    if m > 1 and h % m == 0 and kvh % m and kvh < h:
        return h // kvh
    return 1


def attention_block(p, x, cfg, *, positions, causal=True,
                    window=None, mrope_positions=None,
                    cache=None, cache_pos=None):
    """Full attention sub-layer.  With ``cache`` given, runs one decode
    step (x: (B,1,D)) updating the cache in place (functionally)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, mrope_positions)
    k0, v0 = k, v                    # pre-expansion (cache layout)
    if cache is None:
        g = _gqa_expand_factor(cfg)
        if g > 1:
            k = constrain(jnp.repeat(k, g, axis=2),
                          ("batch", None, "act_heads", None))
            v = constrain(jnp.repeat(v, g, axis=2),
                          ("batch", None, "act_heads", None))
    if cache is not None:
        k_cache, v_cache = cache["k"], cache["v"]
        Smax = k_cache.shape[1]
        ring = window is not None and Smax == window
        slot = (cache_pos % window) if ring else cache_pos   # scalar
        k_cache = update_kv_cache(k_cache, k, slot)
        v_cache = update_kv_cache(v_cache, v, slot)
        if ring:
            # a full ring holds exactly the last `window` tokens: all
            # written slots are attendable, none is out-of-window.
            out = decode_attention(q, k_cache, v_cache,
                                   jnp.minimum(cache_pos + 1, window),
                                   window=None)
        else:
            out = decode_attention(q, k_cache, v_cache, cache_pos + 1,
                                   window=window)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        if window is not None:
            out = local_attention(q, k, v, window=window)
        elif causal:
            out = flash_attention(q, k, v, causal=True,
                                  kv_chunk=cfg.attn_kv_chunk)
        else:
            out = flash_attention(q, k, v, causal=False,
                                  kv_chunk=cfg.attn_kv_chunk)
        # prefill: expose this layer's K/V so the caller can build a
        # decode cache (DCE'd when unused, e.g. during training);
        # stored in the *unexpanded* GQA layout.
        new_cache = {"k": k0, "v": v0}
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    out = out @ p["wo"].astype(out.dtype)
    return constrain(out, ("batch", "seq", "act_embed")), new_cache


def _tp_gather_heads(x, tp_axis, axis: int):
    """Re-assemble a head-sharded activation inside a ``shard_map``
    tensor-parallel program (serve/parallel.py): an all-gather is a
    pure concatenation in mesh-axis order — no cross-shard *reduction*
    ever runs, which is what keeps the sharded program bit-identical
    to the single-device one (shard i computes exactly the slice of
    every op the single device would have computed for its heads).
    ``tp_axis=None`` (the single-device path) is a no-op."""
    if tp_axis is None:
        return x
    return lax.all_gather(x, tp_axis, axis=axis, tiled=True)


def chunk_scatter_targets(starts, n_valid, table_rows, n_tokens,
                          page_size):
    """Pure masking math for the chunked-prefill KV scatter: map token
    t of row b (absolute position ``starts[b] + t``) to its
    (page id, slot) write target.

    Padding tokens (``t >= n_valid[b]``) and positions whose page index
    falls past the row's table width are routed to the null page (page
    0, see serve/kv_cache.py), so a fixed-shape scatter never touches
    live data for lanes the scheduler didn't fill.  Returns
    (pid, slot), both (B, n_tokens) int32.
    """
    t = jnp.arange(n_tokens)[None, :]                      # (1, T)
    abs_pos = starts[:, None] + t                          # (B, T)
    nb = table_rows.shape[1]
    idx = jnp.minimum(abs_pos // page_size, nb - 1)
    pid = jnp.where(t < n_valid[:, None],
                    jnp.take_along_axis(table_rows, idx, axis=1), 0)
    slot = abs_pos % page_size
    return pid.astype(jnp.int32), slot.astype(jnp.int32)


def verify_scatter_targets(lengths, page_table, n_tokens, page_size):
    """Pure masking math for the decode/verify KV scatter: token t of
    row b sits at absolute position ``lengths[b] + t`` and writes to
    ``page_table[b, pos // page_size]`` at slot ``pos % page_size``.

    A position past the end of the page table must land on the null
    page — the default clamping gather would alias it onto the row's
    *last* live page and corrupt confirmed history.  Inactive rows
    carry all-zero tables, so their writes also fall on the null page.
    Returns (pid, slot), both (B, n_tokens) int32.
    """
    B = lengths.shape[0]
    nb = page_table.shape[1]
    abs_pos = lengths[:, None] + jnp.arange(n_tokens)[None, :]  # (B, T)
    bidx = jnp.arange(B)[:, None]
    idx = abs_pos // page_size
    pid = jnp.where(idx < nb,
                    page_table[bidx, jnp.minimum(idx, nb - 1)], 0)
    slot = abs_pos % page_size
    return pid.astype(jnp.int32), slot.astype(jnp.int32)


def paged_attention_block(p, x, cfg, *, positions, k_pages, v_pages,
                          page_table, lengths, tp_axis=None):
    """Paged decode attention sub-layer (continuous batching).

    x: (B, 1, D) with *per-request* positions (B, 1) — unlike
    ``attention_block``'s lockstep scalar ``cache_pos``, every sequence
    in the batch sits at its own depth.  The new token's K/V is written
    into its page slot (``page_table[b, len_b // ps]`` at offset
    ``len_b % ps``) and attention runs over the gathered pages.

    Inactive batch slots carry an all-zero page table, so their writes
    land on the reserved null page (see serve/kv_cache.py) and never
    corrupt live data.  Returns (out, k_pages, v_pages).

    Under tensor parallelism (``tp_axis`` set, see serve/parallel.py)
    ``cfg`` is the *local* per-shard view: q/k/v carry this shard's
    heads, the page buffers hold this shard's KV-head slice, and the
    heads are re-gathered (concatenation, never reduction) before the
    replicated output projection.
    """
    from ..kernels.paged_attention.ref import paged_attention_ref
    B, S, D = x.shape
    assert S == 1, "paged path is decode-only"
    q, k, v = _project_qkv(p, x, cfg, positions)
    ps = k_pages.shape[1]
    bidx = jnp.arange(B)
    pidx = page_table[bidx, lengths // ps]            # (B,)
    slot = lengths % ps
    k_pages = k_pages.at[pidx, slot].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[pidx, slot].set(v[:, 0].astype(v_pages.dtype))
    out = paged_attention_ref(q[:, 0], k_pages, v_pages, page_table,
                              lengths + 1)
    out = _tp_gather_heads(out, tp_axis, axis=1)       # (B, H, Dh)
    out = out.reshape(B, 1, -1)
    out = out @ p["wo"].astype(out.dtype)
    return out, k_pages, v_pages


def paged_verify_attention_block(p, x, cfg, *, positions, k_pages,
                                 v_pages, page_table, lengths,
                                 tp_axis=None):
    """Speculative-verification attention sub-layer (paged decode with a
    query-time axis).

    x: (B, T, D) — token 0 of row b is the request's last confirmed
    token, tokens 1..T-1 its draft continuation, token t sitting at
    absolute position ``lengths[b] + t`` (per-request positions, like
    ``paged_attention_block``).  All T tokens' K/V are written into
    their page slots first — the caller guarantees every written page
    is private (copy-on-write / headroom happen host-side *before* the
    program runs; see serve/kv_cache.ensure_headroom) or is the null
    page for positions the row will never confirm — then attention runs
    over the gathered pages with per-(row, t) causal masking, so query
    t sees exactly the context the single-token decode step at its
    position would have seen.  Verifying T = 1 tokens *is* the decode
    step, bit for bit.

    Returns (out, k_pages, v_pages).
    """
    from ..kernels.paged_attention.ref import paged_verify_attention_ref
    B, T, D = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    ps = k_pages.shape[1]
    pidx, slot = verify_scatter_targets(lengths, page_table, T, ps)
    k_pages = k_pages.at[pidx, slot].set(k.astype(k_pages.dtype))
    v_pages = v_pages.at[pidx, slot].set(v.astype(v_pages.dtype))
    out = paged_verify_attention_ref(q, k_pages, v_pages, page_table,
                                     lengths)
    out = _tp_gather_heads(out, tp_axis, axis=2)       # (B, T, H, Dh)
    out = out.reshape(B, T, -1)
    out = out @ p["wo"].astype(out.dtype)
    return out, k_pages, v_pages


def paged_chunk_attention_block(p, x, cfg, *, positions, starts, n_valid,
                                k_pages, v_pages, table_rows,
                                tp_axis=None):
    """Batched chunked-prefill attention sub-layer over a paged KV
    cache: one chunk each for up to B co-ingesting requests.

    x: (B, C, D) — row b is one request's next prompt chunk, token t
    sitting at absolute position ``starts[b] + t``; tokens with
    t >= ``n_valid[b]`` are padding, and rows with ``n_valid[b] == 0``
    are wholly inactive (fixed (B, C) shape -> one jit compile per
    context bucket regardless of how many requests co-ingest).
    ``table_rows``: (B, nb) int32 — each request's page table truncated
    to the dispatch's shared context bucket, covering every position
    < starts[b] + n_valid[b]; inactive rows and entries past a row's
    own allocation carry the null page.

    Per row, earlier chunks' context is gathered from pages into a
    contiguous (nb * ps) buffer and the current chunk's K/V is overlaid
    at the row's absolute offset (vmapped dynamic_update_slice — pure
    data movement; the buffer is padded by C lanes so the last, partial
    chunk never clamps; overlaid padding tokens land past ``n_valid``
    where causal masking hides them).  Attention then runs through
    ``flash_attention`` with per-row ``q_offset``.  Every op here is
    row-independent (matmuls contract over feature dims, masks and the
    softmax recurrence are per row), the flash partition stays anchored
    at absolute position 0, and fully-masked lanes are exact no-ops —
    so each row is bit-identical to whole-prompt prefill attention *and*
    to the same chunk dispatched alone, whatever else shares the batch.
    The serve engine's token-parity guarantee rests on both.

    Returns (out, k, v); *the caller owns page persistence* — one
    stacked scatter after the layer scan is far cheaper than per-layer
    scatters here (see DecoderLM.prefill_chunk_paged).
    """
    B, C, D = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    kc = k_pages[table_rows].reshape(B, -1, *k_pages.shape[2:])
    vc = v_pages[table_rows].reshape(B, -1, *v_pages.shape[2:])
    kc = jnp.pad(kc, ((0, 0), (0, C), (0, 0), (0, 0)))
    vc = jnp.pad(vc, ((0, 0), (0, C), (0, 0), (0, 0)))
    overlay = jax.vmap(
        lambda buf, new, s: lax.dynamic_update_slice(buf, new, (s, 0, 0)))
    kc = overlay(kc, k.astype(kc.dtype), starts)
    vc = overlay(vc, v.astype(vc.dtype), starts)
    out = flash_attention(q, kc, vc, causal=True,
                          kv_chunk=cfg.attn_kv_chunk, q_offset=starts)
    out = _tp_gather_heads(out, tp_axis, axis=2)       # (B, C, H, Dh)
    out = out.reshape(B, C, -1)
    out = out @ p["wo"].astype(out.dtype)
    return out, k, v


def cross_attention_block(p, x, enc_kv, cfg):
    """Decoder cross-attention; ``enc_kv`` = (k, v) precomputed from the
    encoder output: (B, Senc, KVH, Dh) each."""
    B, S, D = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, h, hd)
    k, v = enc_kv
    out = flash_attention(q, k, v, causal=False,
                          kv_chunk=min(cfg.attn_kv_chunk, k.shape[1]))
    out = out.reshape(B, S, h * hd) @ p["wo"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "act_embed"))


def encode_cross_kv(p, enc_out, cfg):
    B, Senc, _ = enc_out.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(B, Senc, kvh, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(B, Senc, kvh, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.mlp_kind == "gelu":
        return {
            "w1": ParamSpec((d, f), ("embed", "ff")),
            "b1": ParamSpec((f,), ("stats",), init=zeros),
            "w2": ParamSpec((f, d), ("ff", "embed")),
            "b2": ParamSpec((d,), ("stats",), init=zeros),
        }
    return {
        "wg": ParamSpec((d, f), ("embed", "ff")),
        "wu": ParamSpec((d, f), ("embed", "ff")),
        "wd": ParamSpec((f, d), ("ff", "embed")),
    }


def mlp_block(p, x, cfg, tp_axis=None):
    """Dense FFN.  Under tensor parallelism (``tp_axis`` set, see
    serve/parallel.py) the up projections are sharded over the hidden
    dim and the hidden activation is re-gathered (concatenation, no
    reduction) before the replicated down projection — the same
    bitwise-preserving split as the attention head gather."""
    if cfg.mlp_kind == "gelu":
        h = x @ p["w1"].astype(x.dtype) + p["b1"].astype(x.dtype)
        h = jax.nn.gelu(h)
        h = constrain(h, ("batch", None, "act_ff"))
        h = _tp_gather_heads(h, tp_axis, axis=2)
        return h @ p["w2"].astype(x.dtype) + p["b2"].astype(x.dtype)
    g = jax.nn.silu(x @ p["wg"].astype(x.dtype))
    u = x @ p["wu"].astype(x.dtype)
    h = constrain(g * u, ("batch", None, "act_ff"))
    h = _tp_gather_heads(h, tp_axis, axis=2)
    out = h @ p["wd"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_specs(cfg) -> dict:
    m = cfg.moe
    d, fe = cfg.d_model, m.d_expert_ff
    e = m.n_experts
    p = {
        "router": ParamSpec((d, e), ("embed", None), init=normal(0.02)),
        "wg": ParamSpec((e, d, fe), ("expert", "embed", "expert_ff")),
        "wu": ParamSpec((e, d, fe), ("expert", "embed", "expert_ff")),
        "wd": ParamSpec((e, fe, d), ("expert", "expert_ff", "embed")),
    }
    if m.n_shared:
        fs = m.d_expert_ff * m.n_shared
        p["shared"] = {
            "wg": ParamSpec((d, fs), ("embed", "ff")),
            "wu": ParamSpec((d, fs), ("embed", "ff")),
            "wd": ParamSpec((fs, d), ("ff", "embed")),
        }
    return p


def _moe_compute(p, x, cfg, ep_size: int, ep_index):
    """Local MoE shard: route this token shard, dispatch only to the
    ``E/ep_size`` experts this shard owns, run their FFNs, and return the
    *partial* output (summed over expert shards by the caller).

    RISC-NN mapping: expert routing is *task-level sparsity* — the router
    output is the "sparse vector" and the (E_loc, C) dispatch table is the
    compacted jump table (Sparse PC Inc): work that is not routed is never
    materialized, exactly like skipped CAL instructions (paper §5.4).
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    e_loc = E // ep_size
    C = int(T * K / E * m.capacity_factor)
    C = max(1, min(C, T))
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))            # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, K)                        # (T,K)
    if m.normalize_router:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    eid = topi.reshape(-1)                                  # (T*K,)
    gate = topw.reshape(-1)
    tok = jnp.arange(T * K) // K
    # dispatch table for the experts THIS shard owns
    lid = eid - ep_index * e_loc
    mine = (lid >= 0) & (lid < e_loc)
    lid_c = jnp.where(mine, lid, 0)
    onehot = jax.nn.one_hot(lid_c, e_loc, dtype=jnp.int32) \
        * mine[:, None].astype(jnp.int32)                   # (T*K,E_loc)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos, lid_c[:, None], axis=1)[:, 0]
    pos = jnp.where(mine & (pos < C), pos, C)               # OOB -> dropped

    x_e = jnp.zeros((e_loc, C, D), x.dtype)
    x_e = x_e.at[lid_c, pos].set(xf[tok], mode="drop")

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_e, p["wg"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", x_e, p["wu"].astype(x.dtype))
    y_e = jnp.einsum("ecf,efd->ecd", g * u, p["wd"].astype(x.dtype))

    y_tok = y_e.at[lid_c, pos].get(mode="fill", fill_value=0)  # (T*K,D)
    y = jnp.zeros((T, D), x.dtype)
    y = y.at[tok].add(y_tok * gate[:, None].astype(x.dtype), mode="drop")

    if m.n_shared:
        # shared expert(s): dense FFN, tensor-parallel over the hidden dim
        sp = p["shared"]
        sg = jax.nn.silu(xf @ sp["wg"].astype(x.dtype))
        su = xf @ sp["wu"].astype(x.dtype)
        y = y + (sg * su) @ sp["wd"].astype(x.dtype)

    # load-balance aux loss (Switch-style) over this token shard
    me = probs.mean(axis=0)                                 # (E,)
    ce = jax.nn.one_hot(topi[:, 0], E).mean(axis=0)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux


def moe_block(p, x, cfg):
    """Expert-parallel MoE via shard_map.

    GSPMD partitions the global scatter/gather dispatch by replicating
    the (T, D) token tensor ("involuntary full rematerialization"),
    which is both the memory and the collective bottleneck at 1M-token
    batches.  shard_map makes the efficient schedule explicit instead:
    the residual stream is already batch-sharded and model-replicated,
    so every (data, model) device routes *its own* token shard to *its
    own* experts — dispatch is entirely local, and the only collective
    is the same psum-over-model the dense FFN pays.
    """
    from ..sharding.rules import _current_mesh
    mesh = _current_mesh()
    m = cfg.moe
    usable = (mesh is not None and not mesh.empty
              and "model" in mesh.axis_names
              and m.n_experts % mesh.shape["model"] == 0)
    if not usable:
        y, aux = _moe_compute(p, x, cfg, 1, 0)
        return constrain(y, ("batch", "seq", "act_embed")), aux

    from jax.sharding import PartitionSpec as P
    ep = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    xspec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None, None) \
        if dp_axes else P(None, None, None)
    pspec = {
        "router": P(None, None),
        "wg": P("model", None, None),
        "wu": P("model", None, None),
        "wd": P("model", None, None),
    }
    if m.n_shared:
        pspec["shared"] = {"wg": P(None, "model"), "wu": P(None, "model"),
                           "wd": P("model", None)}

    def local(pl, xl):
        y_part, aux = _moe_compute(pl, xl, cfg, ep,
                                   lax.axis_index("model"))
        y = lax.psum(y_part, "model")
        if dp_axes:
            aux = lax.pmean(aux, dp_axes)
        aux = lax.pmean(aux, "model")   # identical per model shard
        return y, aux

    from ..sharding.compat import shard_map_compat
    y, aux = shard_map_compat(local, mesh=mesh, in_specs=(pspec, xspec),
                              out_specs=(xspec, P()),
                              check_vma=False)(p, x)
    return constrain(y, ("batch", "seq", "act_embed")), aux


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg) -> dict:
    p = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          init=normal(0.02))}
    if not cfg.tie_embeddings:
        p["head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                              ("embed", "vocab"))
    return p


def embed_tokens(p, tokens, cfg, dtype):
    x = jnp.take(p["tok"], tokens, axis=0).astype(dtype)
    if cfg.scale_embeddings:
        x = x * math.sqrt(cfg.d_model)
    return constrain(x, ("batch", "seq", "act_embed"))


def unembed(p, x, cfg):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return constrain(logits, ("batch", "seq", "act_vocab"))
