"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the brief, the conv/mel frontend is a **stub**: ``input_specs``
supplies precomputed frame embeddings (B, n_frames, d_model).  The
backbone is faithful otherwise: sinusoidal positions on the encoder,
bidirectional encoder self-attention, causal decoder self-attention +
cross-attention, pre-LayerNorm, GELU MLPs, tied unembedding.

Deviation (documented in DESIGN.md): decoder positions are sinusoidal
rather than a 448-entry learned table so the assigned 4k/32k decoder
lengths are well-defined.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding import constrain
from .base import ParamSpec, init_params, abstract_params
from . import components as C

__all__ = ["WhisperModel"]


class WhisperModel:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- specs ----------------------------------------------------------
    def _enc_layer(self) -> dict:
        cfg = self.cfg
        return {
            "ln1": C.norm_specs(cfg.d_model, cfg.norm_kind),
            "attn": C.attn_specs(cfg),
            "ln2": C.norm_specs(cfg.d_model, cfg.norm_kind),
            "mlp": C.mlp_specs(cfg),
        }

    def _dec_layer(self) -> dict:
        cfg = self.cfg
        return {
            "ln1": C.norm_specs(cfg.d_model, cfg.norm_kind),
            "self_attn": C.attn_specs(cfg),
            "ln_x": C.norm_specs(cfg.d_model, cfg.norm_kind),
            "cross_attn": C.attn_specs(cfg),
            "ln2": C.norm_specs(cfg.d_model, cfg.norm_kind),
            "mlp": C.mlp_specs(cfg),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": C.embed_specs(cfg),
            "enc_final_norm": C.norm_specs(cfg.d_model, cfg.norm_kind),
            "final_norm": C.norm_specs(cfg.d_model, cfg.norm_kind),
        }
        for i in range(cfg.n_encoder_layers):
            specs[f"enc_{i:02d}"] = self._enc_layer()
        for i in range(cfg.n_layers):
            specs[f"dec_{i:02d}"] = self._dec_layer()
        from .base import with_param_dtype
        return with_param_dtype(specs, cfg.param_dtype)

    def init(self, rng):
        return init_params(self.param_specs(), rng)

    def abstract(self):
        return abstract_params(self.param_specs())

    # -- encoder ----------------------------------------------------------
    def encode(self, params, frames):
        """frames: (B, F, D) stub embeddings -> encoder states (B, F, D)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        B, F, D = frames.shape
        x = frames.astype(dtype) + C.sinusoid_pos(F, D).astype(dtype)[None]
        x = constrain(x, ("batch", "seq", "act_embed"))
        pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
        for i in range(cfg.n_encoder_layers):
            p = params[f"enc_{i:02d}"]
            h = C.apply_norm(p["ln1"], x, cfg.norm_kind, cfg.norm_eps)
            a, _ = C.attention_block(p["attn"], h, cfg, positions=pos,
                                     causal=False)
            x = x + a
            h = C.apply_norm(p["ln2"], x, cfg.norm_kind, cfg.norm_eps)
            x = x + C.mlp_block(p["mlp"], h, cfg)
        return C.apply_norm(params["enc_final_norm"], x, cfg.norm_kind,
                            cfg.norm_eps)

    def cross_kv(self, params, enc_out):
        return {f"dec_{i:02d}": C.encode_cross_kv(
                    params[f"dec_{i:02d}"]["cross_attn"], enc_out, self.cfg)
                for i in range(self.cfg.n_layers)}

    # -- decoder ----------------------------------------------------------
    def _decoder(self, params, x, positions, cross, *, caches=None,
                 cache_pos=None, train=True):
        cfg = self.cfg
        new_caches: Dict[str, Any] = {}
        for i in range(cfg.n_layers):
            name = f"dec_{i:02d}"
            p = params[name]

            def blk(p, x, cache):
                h = C.apply_norm(p["ln1"], x, cfg.norm_kind, cfg.norm_eps)
                a, kv = C.attention_block(
                    p["self_attn"], h, cfg, positions=positions,
                    cache=cache, cache_pos=cache_pos)
                x = x + a
                h = C.apply_norm(p["ln_x"], x, cfg.norm_kind, cfg.norm_eps)
                x = x + C.cross_attention_block(p["cross_attn"], h,
                                                cross[name], cfg)
                h = C.apply_norm(p["ln2"], x, cfg.norm_kind, cfg.norm_eps)
                return x + C.mlp_block(p["mlp"], h, cfg), kv

            f = jax.checkpoint(blk) if (train and cfg.remat == "full") \
                else blk
            x, kv = f(p, x, None if caches is None else caches[name])
            new_caches[name] = kv
        return x, new_caches

    def apply(self, params, batch, *, train: bool = True):
        """Training forward: batch = {frames, tokens}.  Returns
        (decoder logits, aux)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        enc_out = self.encode(params, batch["frames"])
        cross = self.cross_kv(params, enc_out)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = C.embed_tokens(params["embed"], tokens, cfg, dtype)
        x = x + C.sinusoid_pos(S, cfg.d_model).astype(dtype)[None]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _ = self._decoder(params, x, pos, cross, train=train)
        x = C.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        return C.unembed(params["embed"], x, cfg), {"moe_aux": 0.0}

    # -- serving ----------------------------------------------------------
    def cache_specs(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        kv = lambda s: {  # noqa: E731
            "k": ParamSpec((batch, s, cfg.n_kv_heads, cfg.head_dim),
                           ("batch", "kv_seq", "act_heads", None),
                           jnp.bfloat16),
            "v": ParamSpec((batch, s, cfg.n_kv_heads, cfg.head_dim),
                           ("batch", "kv_seq", "act_heads", None),
                           jnp.bfloat16)}
        specs: Dict[str, Any] = {
            "self": {f"dec_{i:02d}": kv(seq_len)
                     for i in range(cfg.n_layers)},
            "cross": {f"dec_{i:02d}": (kv(cfg.n_frames)["k"],
                                       kv(cfg.n_frames)["v"])
                      for i in range(cfg.n_layers)},
            "pos": ParamSpec((), (), jnp.int32),
        }
        return specs

    def init_cache(self, batch: int, seq_len: int):
        return jax.tree.map(
            lambda ps: jnp.zeros(ps.shape, ps.dtype),
            self.cache_specs(batch, seq_len),
            is_leaf=lambda x: isinstance(x, ParamSpec))

    def prefill(self, params, batch, *, max_len=None):
        """Encode + decoder prefill.  batch = {frames, tokens}."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        enc_out = self.encode(params, batch["frames"])
        cross = self.cross_kv(params, enc_out)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = C.embed_tokens(params["embed"], tokens, cfg, dtype)
        x = x + C.sinusoid_pos(S, cfg.d_model).astype(dtype)[None]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, kvs = self._decoder(params, x, pos, cross, train=False)
        x = C.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        logits = C.unembed(params["embed"], x, cfg)
        if max_len is not None and max_len > S:
            extra = max_len - S
            kvs = {name: {n: jnp.pad(kv[n], ((0, 0), (0, extra),
                                             (0, 0), (0, 0)))
                          for n in ("k", "v")}
                   for name, kv in kvs.items()}
        cache = {"self": kvs, "cross": cross,
                 "pos": jnp.asarray(S, jnp.int32)}
        return logits[:, -1], cache

    def decode_step(self, params, cache, tokens):
        """One decoder token against self- and cross-attention caches."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.compute_dtype)
        pos = cache["pos"]                                  # scalar
        B = tokens.shape[0]
        x = C.embed_tokens(params["embed"], tokens, cfg, dtype)
        pe = C.sinusoid_pos_at(pos[None].astype(jnp.int32), cfg.d_model)
        x = x + pe.astype(dtype)[:, None]
        positions = jnp.full((B, 1), pos, jnp.int32)
        x, new_kvs = self._decoder(params, x, positions, cache["cross"],
                                   caches=cache["self"], cache_pos=pos,
                                   train=False)
        x = C.apply_norm(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
        logits = C.unembed(params["embed"], x, cfg)
        new_cache = {"self": new_kvs, "cross": cache["cross"],
                     "pos": pos + 1}
        return logits[:, 0], new_cache
