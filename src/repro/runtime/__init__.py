from .driver import TrainDriver, DriverConfig, StepEvent  # noqa: F401
from .straggler import StragglerMonitor  # noqa: F401
from .elastic import plan_elastic_mesh  # noqa: F401
