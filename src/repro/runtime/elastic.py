"""Elastic-scaling policy: pick a new mesh after membership changes.

Given the surviving device count and the parallelism constraints of the
job (model-axis width must divide the layer shardings it was compiled
for; data axis absorbs the rest), returns the largest legal mesh.  The
checkpoint layer restores onto whatever mesh this returns (full-array
manifests are topology-free).
"""
from __future__ import annotations

from typing import Optional, Tuple

__all__ = ["plan_elastic_mesh"]


def plan_elastic_mesh(n_devices: int, *, model_parallel: int,
                      min_data: int = 1,
                      pods: int = 1) -> Optional[Tuple[Tuple[int, ...],
                                                       Tuple[str, ...]]]:
    """Largest (shape, axes) using <= n_devices.

    Keeps ``model_parallel`` fixed (param shardings stay valid) and
    shrinks the data axis; drops to fewer pods before shrinking data
    below ``min_data``.  Returns None when no legal mesh exists.
    """
    if model_parallel <= 0 or n_devices < model_parallel * min_data:
        return None
    for p in range(pods, 0, -1):
        per_pod = n_devices // p
        data = per_pod // model_parallel
        if data >= min_data:
            if p > 1:
                return ((p, data, model_parallel),
                        ("pod", "data", "model"))
            return ((data, model_parallel), ("data", "model"))
    return None
