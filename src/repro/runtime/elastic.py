"""Deprecated location: the elasticity policy moved to
``repro.serve.elastic`` when the serving fleet became elastic (the
mesh planner is the training-side half of the same story).  This shim
re-exports it so old imports keep working."""
from __future__ import annotations

from ..serve.elastic import plan_elastic_mesh

__all__ = ["plan_elastic_mesh"]
