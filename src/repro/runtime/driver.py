"""Fault-tolerant training driver.

The loop is checkpoint/restart-structured: every step is a pure
function of (params, opt_state, step_number) and the data pipeline is
stateless-resumable, so recovery = restore latest checkpoint + continue
from its step.  Failures (device loss, preemption, injected faults in
tests) surface as exceptions from the step; the driver restores and
retries, re-planning the mesh via the elastic policy when the device
count changed.  Straggler detection runs on step wall times.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..serve.elastic import StragglerMonitor

__all__ = ["TrainDriver", "DriverConfig", "StepEvent"]


@dataclasses.dataclass(frozen=True)
class DriverConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler_threshold: float = 2.5
    log_every: int = 10


@dataclasses.dataclass
class StepEvent:
    step: int
    kind: str                   # "step" | "checkpoint" | "restart" | "straggler"
    wall_s: float = 0.0
    info: Optional[Dict[str, Any]] = None


class TrainDriver:
    """Drives train_step with checkpoint/restart + straggler accounting.

    ``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``
    must be jitted by the caller; ``batch_fn(step) -> batch`` must be
    stateless-resumable (``data.SyntheticPipeline`` is).
    """

    def __init__(self, cfg: DriverConfig, step_fn: Callable,
                 batch_fn: Callable, *,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.fault_hook = fault_hook     # tests inject failures here
        self.monitor = StragglerMonitor(cfg.straggler_threshold)
        self.events: List[StepEvent] = []
        self.metrics_log: List[Dict[str, float]] = []

    # -- recovery ---------------------------------------------------------
    def _restore(self, params, opt_state):
        step = latest_step(self.cfg.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        tree, step = restore_checkpoint(
            self.cfg.ckpt_dir, {"params": params, "opt": opt_state})
        return tree["params"], tree["opt"], step

    def run(self, params, opt_state, *, start_step: int = 0):
        cfg = self.cfg
        step = start_step
        restarts = 0
        while step < cfg.total_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                t0 = time.time()
                batch = self.batch_fn(step)
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                ev = self.monitor.observe(step, dt)
                if ev is not None:
                    self.events.append(StepEvent(
                        step, "straggler", dt,
                        {"ratio": ev.ratio, "ema": ev.ema}))
                if step % cfg.log_every == 0:
                    self.metrics_log.append(
                        {"step": step,
                         "loss": float(metrics["loss"]), "wall_s": dt})
                step += 1
                if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                    save_checkpoint(cfg.ckpt_dir, step,
                                    {"params": params, "opt": opt_state})
                    self.events.append(StepEvent(step, "checkpoint"))
            except KeyboardInterrupt:
                raise
            except Exception as e:                     # noqa: BLE001
                restarts += 1
                self.events.append(StepEvent(
                    step, "restart", info={"error": repr(e),
                                           "restart": restarts}))
                if restarts > cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={cfg.max_restarts}") from e
                params, opt_state, step = self._restore(params, opt_state)
        return params, opt_state
