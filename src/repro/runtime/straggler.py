"""Deprecated location: the straggler monitor moved to
``repro.serve.elastic`` with the rest of the fleet-elasticity
machinery.  This shim re-exports it so old imports keep working."""
from __future__ import annotations

from ..serve.elastic import StragglerEvent, StragglerMonitor

__all__ = ["StragglerMonitor", "StragglerEvent"]
