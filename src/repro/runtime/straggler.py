"""Straggler detection: per-step wall-time EMA with an outlier policy.

On a real pod the mitigation is re-issuing the slow host's shard /
evicting the host; here the monitor emits the decision so the driver
(and tests) can act on it.  Detection is the same either way: a step
that exceeds ``threshold x EMA`` marks its slowest participant.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ema: float
    ratio: float


class StragglerMonitor:
    def __init__(self, threshold: float = 2.5, alpha: float = 0.1,
                 warmup: int = 5):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        self.n += 1
        if self.ema is None:
            self.ema = step_time
            return None
        event = None
        if self.n > self.warmup and step_time > self.threshold * self.ema:
            event = StragglerEvent(step, step_time, self.ema,
                                   step_time / self.ema)
            self.events.append(event)
            # do not poison the EMA with the outlier
            return event
        self.ema = (1 - self.alpha) * self.ema + self.alpha * step_time
        return event
