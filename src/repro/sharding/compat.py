"""Version compatibility for the sharding APIs this repo uses.

jax >= 0.5 exposes ``jax.shard_map(..., check_vma=...)``; the pinned
0.4.37 has ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
``shard_map_compat`` takes the new-style signature and translates.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map_compat"]

if hasattr(jax, "shard_map"):
    def shard_map_compat(f, *, mesh, in_specs, out_specs,
                         check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map_compat(f, *, mesh, in_specs, out_specs,
                         check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
