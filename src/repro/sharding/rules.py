"""Logical-axis sharding rules -> PartitionSpec / NamedSharding.

Every parameter and activation in the model zoo is annotated with
*logical* dimension names ("batch", "embed", "q_heads", ...).  A
:class:`ShardingRules` table maps each logical name to an ordered tuple
of mesh axes.  :func:`logical_spec` resolves annotations against a
concrete mesh with two hard safety rules:

* **divisibility** — a mesh axis (or axis product) is used only if it
  divides the dimension size; otherwise the dim falls back to fewer
  axes and ultimately to replication.  (pjit rejects non-divisible
  ``in_shardings``; we never emit them.)
* **exclusivity** — a mesh axis may appear at most once in one
  PartitionSpec; first dim that claims it wins (annotation order).

This is the mesh-level analogue of the paper's translator (§3.12):
logical ExeBlock addresses -> physical PE/bank assignment happens in
``core/translator.py``; logical tensor dims -> physical mesh axes
happens here.  Both balance the physical resource and both refuse
illegal placements instead of silently emitting them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "ShardingRules", "DEFAULT_RULES", "logical_spec", "named_sharding",
    "tree_shardings", "constrain", "SERVE_TP_AXIS", "serve_tp_spec",
]

# --------------------------------------------------------------------------
# serving tensor parallelism (serve/parallel.py)
# --------------------------------------------------------------------------

#: Mesh axis name the tensor-parallel serve engine shards over.  It is
#: deliberately *not* a ShardingRules axis: the serving TP layout must
#: stay bit-identical to single-device decode, so it only ever shards
#: dims whose ops need no cross-shard reduction (see serve_tp_spec);
#: the training rules above are free to trade exactness for layout.
SERVE_TP_AXIS = "tp"

#: Param leaves the serving TP layout shards, always on their LAST dim
#: (the projection *output*): wq/wk/wv + biases by heads, wg/wu (and
#: gelu w1/b1) by the FFN hidden dim.  Everything contracted *over* a
#: sharded dim (wo, wd/w2, embed/unembed, norms) stays replicated and
#: consumes an all-gathered activation instead — a concatenation, not
#: a reduction, which is what preserves bitwise token parity.
SERVE_TP_SHARDED_LEAVES = frozenset(
    {"wq", "wk", "wv", "bq", "bk", "bv", "wg", "wu", "w1", "b1"})


def serve_tp_spec(leaf_name: str, ndim: int) -> "PartitionSpec":
    """PartitionSpec of one param leaf under the serving TP layout."""
    if leaf_name in SERVE_TP_SHARDED_LEAVES:
        return PartitionSpec(*([None] * (ndim - 1) + [SERVE_TP_AXIS]))
    return PartitionSpec()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical dim name -> ordered mesh-axis candidates.

    A value of ``()`` means "never shard this dim".  A tuple like
    ``("pod", "data")`` means "shard over the product of both if
    divisible, else over a prefix, else replicate".
    """
    # -- activations ------------------------------------------------------
    batch: tuple = ("pod", "data")       # DP over pods x data
    seq: tuple = ()                      # set to ("model",) for SP
    act_embed: tuple = ()                # residual-stream feature dim
    act_heads: tuple = ("model",)        # attention-internal head dim
    act_ff: tuple = ("model",)           # MLP-internal hidden dim
    act_vocab: tuple = ("model",)        # logits vocab dim
    act_expert: tuple = ("model",)       # MoE expert-parallel dim
    kv_seq: tuple = ("model",)           # decode KV-cache sequence dim
    #                                      (flash-decoding: partial softmax
    #                                       per shard + tiny all-reduces)
    # -- parameters -------------------------------------------------------
    embed: tuple = ("data",)             # FSDP: shard feature dim over data
    vocab: tuple = ("model",)
    q_heads: tuple = ("model",)
    kv_heads: tuple = ("model",)
    head_dim: tuple = ()
    ff: tuple = ("model",)
    expert: tuple = ("model",)           # expert-parallelism
    expert_ff: tuple = ()
    layers: tuple = ()                   # stacked scan dim: never sharded
    conv: tuple = ()
    stats: tuple = ()                    # norms / small vectors

    def get(self, name: Optional[str]) -> tuple:
        if name is None:
            return ()
        try:
            return getattr(self, name)
        except AttributeError:
            raise KeyError(f"unknown logical dim {name!r}") from None


DEFAULT_RULES = ShardingRules()

#: Megatron-style sequence parallelism: the residual stream (and the
#: saved scan carries remat keeps for the backward pass) are sharded
#: over `model` along seq; attention/MLP internals re-gather.  This is
#: a *rules* variant, not a model change — select with
#: ``dryrun --rules sp`` or :func:`set_active_rules`.
SP_RULES = dataclasses.replace(DEFAULT_RULES, seq=("model",))

RULE_VARIANTS = {"default": DEFAULT_RULES, "sp": SP_RULES}

_ACTIVE_RULES = DEFAULT_RULES


def set_active_rules(rules: "ShardingRules") -> None:
    global _ACTIVE_RULES
    _ACTIVE_RULES = rules


def active_rules() -> "ShardingRules":
    return _ACTIVE_RULES


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_spec(axes: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh, rules: ShardingRules = DEFAULT_RULES,
                 soft: bool = False) -> PartitionSpec:
    """Resolve logical dim names to a legal PartitionSpec for ``mesh``.

    ``soft=True`` (activation constraints only): dims whose candidates do
    not divide become ``UNCONSTRAINED`` instead of replicated, leaving
    GSPMD propagation free to pick a layout.  Hard mode (params / jit IO,
    which must be concrete) falls back to replication.
    """
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} rank != shape {shape} rank")
    sizes = _axis_sizes(mesh)
    used: set = set()
    entries: list = []
    for name, dim in zip(axes, shape):
        cands = [a for a in rules.get(name) if a in sizes and a not in used]
        # longest prefix whose size-product divides the dim
        picked: tuple = ()
        for k in range(len(cands), 0, -1):
            prod = math.prod(sizes[a] for a in cands[:k])
            if prod > 1 and dim % prod == 0:
                picked = tuple(cands[:k])
                break
        used.update(picked)
        if not picked:
            fell_back = any(sizes[a] > 1 for a in rules.get(name)
                            if a in sizes)
            entries.append(PartitionSpec.UNCONSTRAINED
                           if (soft and fell_back) else None)
        elif len(picked) == 1:
            entries.append(picked[0])
        else:
            entries.append(picked)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def named_sharding(axes: Sequence[Optional[str]], shape: Sequence[int],
                   mesh: Mesh, rules: ShardingRules = DEFAULT_RULES,
                   ) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(axes, shape, mesh, rules))


def tree_shardings(spec_tree: Any, mesh: Mesh,
                   rules: ShardingRules = DEFAULT_RULES) -> Any:
    """Map a pytree of ``ParamSpec``-likes (``.shape`` + ``.axes``) to
    NamedShardings."""
    def one(ps):
        return named_sharding(ps.axes, ps.shape, mesh, rules)
    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: hasattr(x, "axes"))


def constrain(x: jax.Array, axes: Sequence[Optional[str]],
              rules: Optional[ShardingRules] = None) -> jax.Array:
    """`with_sharding_constraint` by logical names; no-op outside a mesh
    context or under a mesh lacking every candidate axis.  Uses the
    process-active rules (see :func:`set_active_rules`) by default."""
    try:
        mesh = _current_mesh()
    except RuntimeError:
        return x
    if mesh is None or mesh.empty:
        return x
    spec = logical_spec(axes, x.shape, mesh, rules or _ACTIVE_RULES,
                        soft=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    env = jax._src.mesh.thread_resources.env  # physical mesh context
    return env.physical_mesh
