from .rules import (  # noqa: F401
    ShardingRules, DEFAULT_RULES, SP_RULES, RULE_VARIANTS, logical_spec,
    named_sharding, tree_shardings, constrain, set_active_rules,
    active_rules,
)
