"""qwen1.5-110b [dense] — 80L d=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  The scale stressor: needs FSDP+TP(+grad
accumulation) to fit the dry-run HBM budget.
[hf:Qwen/Qwen1.5-110B; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=49152, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=192, vocab_size=256,
    qkv_bias=True, rope_theta=1e6, attn_kv_chunk=16,
)
