"""recurrentgemma-2b [hybrid] — 26L d=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; Griffin pattern: (RG-LRU, RG-LRU, local-attn) with a
2048 window.  Sub-quadratic -> eligible for long_500k.
[arXiv:2402.19427; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048, lru_width=2560,
    tie_embeddings=True, scale_embeddings=True, logit_softcap=30.0,
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=16, lru_width=64,
    tie_embeddings=True, scale_embeddings=True, logit_softcap=30.0,
    sub_quadratic=True, attn_kv_chunk=16,
)
