"""qwen3-0.6b [dense] — 28L d=1024 16H (GQA kv=8) head_dim=128
d_ff=3072 vocab=151936, qk-norm, tied embeddings.
[hf:Qwen/Qwen3-0.6B; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True, attn_kv_chunk=16,
)
