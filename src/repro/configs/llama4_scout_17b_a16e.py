"""llama4-scout-17b-16e [moe] — 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 routed experts top-1 + 1 shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=1, d_expert_ff=8192, n_shared=1),
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rope_theta=5e5,
    moe=MoEConfig(n_experts=4, top_k=1, d_expert_ff=128, n_shared=1),
    attn_kv_chunk=32,
)
