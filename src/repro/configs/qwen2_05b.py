"""qwen2-0.5b [dense] — 24L d=896 14H (GQA kv=2) d_ff=4864
vocab=151936, QKV bias, tied embeddings.  [arXiv:2407.10671; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense",
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
    d_ff=128, vocab_size=256,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True, attn_kv_chunk=16,
)
