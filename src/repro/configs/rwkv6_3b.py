"""rwkv6-3b [ssm] — Finch, 32L d=2560 (attention-free, 40 heads of 64)
d_ff=8960 vocab=65536; data-dependent decay WKV.  O(1) state ->
eligible for long_500k.  [arXiv:2404.05892; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    block_pattern=("rwkv",), rope_kind="none",
    norm_kind="layernorm", norm_eps=1e-5,
    sub_quadratic=True,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    block_pattern=("rwkv",), rope_kind="none",
    norm_kind="layernorm", norm_eps=1e-5,
    sub_quadratic=True,
)
