"""qwen2-vl-7b [vlm] — 28L d=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; M-RoPE (t/h/w sections 16/24/24); vision frontend is a
stub (precomputed patch embeddings spliced over the leading tokens).
[arXiv:2409.12191; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_kind="mrope", rope_theta=1e6,
    mrope_sections=(16, 24, 24), n_patches=256,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    qkv_bias=True, rope_kind="mrope", rope_theta=1e6,
    mrope_sections=(2, 3, 3), n_patches=8, attn_kv_chunk=16,
)
