"""Config schema for the model zoo + the assigned input shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoEConfig", "ModelConfig", "ShapeSpec", "SHAPES", "shape_by_name"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0                # shared experts (always-on), units of d_expert_ff
    capacity_factor: float = 1.25
    normalize_router: bool = True
    first_dense: int = 0             # leading layers with a dense FFN instead
    dense_d_ff: int = 0              # d_ff of those dense layers
    aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_kind: str = "rope"           # rope | mrope | none
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    mrope_sections: Tuple[int, ...] = ()
    attn_kv_chunk: int = 1024
    local_window: int = 2048
    # block structure
    block_pattern: Tuple[str, ...] = ("attn",)   # attn | local_attn | rglru | rwkv
    mlp_kind: str = "swiglu"          # swiglu | gelu
    norm_kind: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    lru_width: int = 0
    # embeddings
    tie_embeddings: bool = False
    scale_embeddings: bool = False
    logit_softcap: float = 0.0
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_frames: int = 1500              # stub frontend: precomputed embeddings
    # vlm stub frontend
    n_patches: int = 0
    # training knobs
    train_microbatch: int = 0         # 0 -> auto (see train/step.py)
    remat: str = "full"               # full | none
    loss_chunk: int = 0               # >0: seq-chunked fused CE — never
    #                                   materializes the (B,S,V) logits
    param_dtype: str = "float32"      # "bfloat16": store/gather params in
    #                                   bf16, keep f32 master in opt state
    grad_accum_dtype: str = "float32"  # bf16 halves the grad-accum buffer
    sub_quadratic: bool = False       # eligible for long_500k
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.block_pattern[i % len(self.block_pattern)]
                     for i in range(self.n_layers))

    def n_params(self) -> int:
        """Total parameter count (exact, mirrors param_specs)."""
        import jax
        from ..models import build_model
        import math
        specs = build_model(self).param_specs()
        return sum(math.prod(ps.shape) for ps in jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "axes")))

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        total = self.n_params()
        if self.moe is None:
            return total
        import math
        m = self.moe
        per_expert = 3 * self.d_model * m.d_expert_ff
        n_moe_layers = self.n_layers - m.first_dense
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
