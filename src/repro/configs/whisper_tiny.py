"""whisper-tiny [audio] — enc-dec, 4+4L d=384 6H (MHA) d_ff=1536
vocab=51865; conv/mel frontend is a stub (precomputed frame
embeddings, 1500 frames).  [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51865,
    is_encoder_decoder=True, n_encoder_layers=4, n_frames=1500,
    rope_kind="none", mlp_kind="gelu", norm_kind="layernorm",
    norm_eps=1e-5, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    is_encoder_decoder=True, n_encoder_layers=2, n_frames=32,
    rope_kind="none", mlp_kind="gelu", norm_kind="layernorm",
    norm_eps=1e-5, tie_embeddings=True, attn_kv_chunk=16,
)
