"""deepseek-moe-16b [moe] — 28L d=2048 16H (MHA kv=16) per-expert
d_ff=1408, vocab=102400; 2 shared + 64 routed top-6, fine-grained
experts; first layer is a dense FFN.  [arXiv:2401.06066; hf]"""
from .base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert_ff=1408, n_shared=2,
                  first_dense=1, dense_d_ff=10944),
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=48, vocab_size=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=48, n_shared=2,
                  first_dense=1, dense_d_ff=96),
    attn_kv_chunk=32,
)
