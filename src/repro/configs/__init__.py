"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from .base import (  # noqa: F401
    ModelConfig, MoEConfig, ShapeSpec, SHAPES, shape_by_name,
)

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-tiny": "whisper_tiny",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "qwen1.5-110b": "qwen15_110b",
    "qwen2-0.5b": "qwen2_05b",
    "qwen3-0.6b": "qwen3_06b",
    "stablelm-1.6b": "stablelm_16b",
    "rwkv6-3b": "rwkv6_3b",
    # the paper's own benchmark suite is CNN/MLP/LSTM layers handled by
    # core/ + benchmarks/; LM archs above are the framework's zoo.
}

ARCH_NAMES = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f".{_MODULES[name]}", __package__)


def get(name: str) -> ModelConfig:
    return _module(name).FULL


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


#: per-arch optimized settings for the final §Perf runs
#: (config overrides, rules-variant name).  Derived from the hillclimb
#: log in EXPERIMENTS.md §Perf; everything else inherits the global
#: code-level optimizations (shard_map MoE, bf16 attention operands,
#: shard-local cache writes, GQA expansion).  Per-shape exceptions in
#: OPT_SHAPE_SETTINGS override these (measured regressions: SP hurts
#: rwkv's shift-heavy train step; chunked CE inflates the small-model
#: train memory; whisper's enc-dec loss path keeps plain CE).
OPT_SETTINGS = {
    "qwen1.5-110b": ({"loss_chunk": "512", "param_dtype": "bfloat16",
                      "train_microbatch": "8"}, "sp"),
    "llama4-scout-17b-a16e": ({"loss_chunk": "512",
                               "param_dtype": "bfloat16",
                               "grad_accum_dtype": "bfloat16",
                               "train_microbatch": "8"}, "sp"),
    "deepseek-moe-16b": ({"loss_chunk": "512", "param_dtype": "bfloat16",
                          "grad_accum_dtype": "bfloat16"}, "sp"),
    "qwen2-vl-7b": ({"loss_chunk": "512", "param_dtype": "bfloat16"},
                    "sp"),
    "recurrentgemma-2b": ({"loss_chunk": "512",
                           "param_dtype": "bfloat16"}, "sp"),
    "rwkv6-3b": ({"loss_chunk": "512", "param_dtype": "bfloat16"}, "sp"),
    "qwen2-0.5b": ({"loss_chunk": "512", "param_dtype": "bfloat16"},
                   "default"),
    "qwen3-0.6b": ({"loss_chunk": "512", "param_dtype": "bfloat16"},
                   "default"),
    "stablelm-1.6b": ({"loss_chunk": "512", "param_dtype": "bfloat16"},
                      "default"),
    "whisper-tiny": ({"param_dtype": "bfloat16"}, "default"),
}

OPT_SHAPE_SETTINGS = {
    ("rwkv6-3b", "train_4k"): ({"loss_chunk": "512",
                                "param_dtype": "bfloat16"}, "default"),
    ("qwen2-0.5b", "train_4k"): ({"param_dtype": "bfloat16"}, "default"),
    ("whisper-tiny", "train_4k"): ({}, "default"),
}


def opt_settings_for(arch: str, shape: str):
    if (arch, shape) in OPT_SHAPE_SETTINGS:
        return OPT_SHAPE_SETTINGS[(arch, shape)]
    return OPT_SETTINGS.get(arch, ({}, "default"))


def cells():
    """Every (arch, shape) cell, with skip reasons where applicable.

    Yields (arch_name, shape, skip_reason | None)."""
    for name in ARCH_NAMES:
        cfg = get(name)
        for shape in SHAPES:
            skip = None
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                skip = ("full quadratic attention at 524k context: "
                        "KV/score cost infeasible; brief directs skip "
                        "for pure full-attention archs")
            yield name, shape, skip
