"""stablelm-1.6b [dense] — 24L d=2048 32H (MHA kv=32) d_ff=5632
vocab=100352; LayerNorm + partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352,
    norm_kind="layernorm", norm_eps=1e-5, rope_fraction=0.25,
)

SMOKE = ModelConfig(
    name="stablelm-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    norm_kind="layernorm", norm_eps=1e-5, rope_fraction=0.25,
    attn_kv_chunk=16,
)
