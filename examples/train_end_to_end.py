"""End-to-end training driver (deliverable (b)): a ~100M-param decoder
LM trained for a few hundred steps through the fault-tolerant runtime —
checkpointing, restart and straggler accounting all active.

The default invocation is sized for this CPU container (a ~10M model,
60 steps, a couple of minutes).  The documented full run is the same
command on real hardware:

    PYTHONPATH=src python examples/train_end_to_end.py \
        --scale 100m --steps 300 --batch 32 --seq 512

Both scales exercise identical code paths.
"""
import argparse
import tempfile

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.runtime import DriverConfig, TrainDriver
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import make_train_step

SCALES = {
    # ~10M: CPU-friendly demo
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                d_ff=1024, vocab_size=8192),
    # ~100M: the deliverable configuration
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="10m", choices=list(SCALES))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-fault-at", type=int, default=-1,
                    help="crash once at this step to demo recovery")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"demo-{args.scale}", family="dense",
                      qk_norm=True, tie_embeddings=True,
                      **SCALES[args.scale])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(int(jax.numpy.size(p)) for p in jax.tree.leaves(params))
    print(f"training {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    pipe = SyntheticPipeline(cfg, batch=args.batch, seq=args.seq)
    step_fn = jax.jit(make_train_step(model, cfg,
                                      opt=OptConfig(lr=6e-4,
                                                    warmup_steps=20)))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="e2e_ckpt_")

    faults = {args.inject_fault_at} if args.inject_fault_at >= 0 else set()

    def fault_hook(step):
        if step in faults:
            faults.discard(step)
            raise RuntimeError(f"injected fault at step {step}")

    driver = TrainDriver(
        DriverConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                     ckpt_every=20, log_every=5),
        step_fn, lambda s: pipe.device_batch(s), fault_hook=fault_hook)
    params, opt = driver.run(params, init_opt_state(params))

    first = driver.metrics_log[0]["loss"]
    last = driver.metrics_log[-1]["loss"]
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")
    for e in driver.events:
        print(f"  event: {e.kind} @ step {e.step} {e.info or ''}")
    assert last < first, "training must reduce loss"
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
