"""RISC-NN core API example: compile a CNN layer into ExeBlock programs
under all five reuse schemes, run them on the functional interpreter +
performance model, then prune and re-run sparse (paper §5.2/§5.4).

    PYTHONPATH=src python examples/riscnn_sparse_conv.py
"""
import numpy as np

from repro.core.dataflows import ConvSpec, Reuse, build_conv_program, \
    conv_reference, panel_items, read_psums, seed_dram
from repro.core.interpreter import MachineState, run_graph
from repro.core.machine import MachineConfig, simulate
from repro.core.sparse import apply_pruning, conv_sparse_vectors, \
    prune_weights

SPEC = ConvSpec("demo_conv", in_ch=4, out_ch=16, kh=3, kw=3, ih=10, iw=10)


def main():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(SPEC.out_ch, SPEC.in_ch, 3, 3)).astype(np.float32)
    x = rng.normal(size=(SPEC.in_ch, SPEC.ih, SPEC.iw,
                         SPEC.batch)).astype(np.float32)

    print(f"{'scheme':15s} {'cycles':>9s} {'MAC util':>9s} {'DRAM B':>9s} "
          f"{'energy uJ':>10s}")
    for scheme in Reuse:
        g = build_conv_program(SPEC, scheme, n_pes=16, items_per_block=4,
                               n_items=64)
        r = simulate(g, MachineConfig(n_pes=16))
        print(f"{scheme.value:15s} {r.cycles:9.0f} "
              f"{r.mac_utilization:9.3f} {r.dram_bytes:9.0f} "
              f"{r.energy_pj / 1e6:10.2f}")

    # functional check + sparse run on Filter-Reuse
    scheme = Reuse.FILTER_REUSE
    g = build_conv_program(SPEC, scheme, n_pes=16, items_per_block=4,
                           n_items=64)
    state = MachineState(n_pes=16, opm_entries=4096)
    seed_dram(state, SPEC, w, x)
    run_graph(g, state)
    items = panel_items(SPEC, scheme, n_items=64)
    got = read_psums(state, SPEC, items)
    want = conv_reference(SPEC, w, x, channel=0, items=items)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    print("\nfunctional check vs numpy oracle: OK")

    wp = prune_weights(w, keep_frac=0.35, rng=rng)
    pruned = {(o, k) for o in range(SPEC.out_ch) for k in range(SPEC.k)
              if wp[o, 0, k // 3, k % 3] == 0.0}
    vecs = conv_sparse_vectors(g, SPEC, scheme, pruned,
                               items_per_block=4, n_items=64)
    gs = apply_pruning(g, vecs)
    rd = simulate(g, MachineConfig(n_pes=16))
    rs = simulate(gs, MachineConfig(n_pes=16))
    print(f"sparse (keep 35%): cycles {rd.cycles:.0f} -> {rs.cycles:.0f} "
          f"(+{(rd.cycles / rs.cycles - 1) * 100:.1f}% perf), "
          f"energy -{(1 - rs.energy_pj / rd.energy_pj) * 100:.1f}%")


if __name__ == "__main__":
    main()
