"""Quickstart: build a model from the zoo, train a few steps, checkpoint,
restore, and generate — the whole public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import numpy as np

from repro import configs
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.serve.step import greedy_generate
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.step import make_train_step


def main():
    # 1. pick an architecture (reduced config so CPU is instant)
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  ({n / 1e6:.2f}M params)")

    # 2. train a few steps on the synthetic pipeline
    pipe = SyntheticPipeline(cfg, batch=8, seq=64)
    step = jax.jit(make_train_step(model, cfg, opt=OptConfig(lr=1e-3),
                                   n_micro=2))
    opt = init_opt_state(params)
    for i in range(10):
        params, opt, m = step(params, opt, pipe.device_batch(i))
        if i % 3 == 0:
            print(f"  step {i}: loss {float(m['loss']):.4f} "
                  f"grad_norm {float(m['grad_norm']):.3f}")

    # 3. checkpoint + restore (topology-free manifests)
    ckpt = tempfile.mkdtemp()
    save_checkpoint(ckpt, 10, {"params": params, "opt": opt})
    restored, at = restore_checkpoint(ckpt, {"params": params, "opt": opt})
    print(f"checkpoint roundtrip ok at step {at}")

    # 4. batched greedy generation through prefill + decode_step
    prompts = pipe.device_batch(99)
    gen = greedy_generate(model, restored["params"], prompts, n_steps=12,
                          cache_len=64)
    print("generated ids (seq 0):", np.asarray(gen)[0])


if __name__ == "__main__":
    main()
