"""Serving example, five tiers:

1. Continuous-batching engine (paged KV cache, chunked prefill) on the
   dense-GQA arch: staggered request lengths, mid-flight admission,
   per-request TTFT.
2. Prefix sharing: the same engine under a shared system prompt —
   requests after the first reuse its KV pages (copy-on-write guards
   the tail) instead of recomputing them.
3. Multi-replica routing: two engine replicas behind the
   prefix-affinity router — two shared-prompt workloads are
   partitioned so each replica's prefix trie serves one of them
   (token streams identical to any single engine's).
4. Streaming front-end: submit at any time, iterate confirmed tokens
   per request, cancel one stream mid-flight — an interactive-class
   request preempts saturated batch work and still every stream is
   token-exact.
5. Lockstep greedy loop across the other cache families (ring-buffer
   local attention, recurrent state) — fixed-size states don't page.

    PYTHONPATH=src python examples/serve_batched.py

(Tensor-parallel serving needs >1 device; see docs/serving.md and
``python -m repro.launch.serve --tp 2 --replicas 2``.)
"""
import time

import jax
import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.serve import Request, RequestRouter, ServeEngine, ServePrograms
from repro.serve.step import make_decode_step, make_prefill_step

LOCKSTEP_ARCHS = [
    "recurrentgemma-2b",     # hybrid: ring buffer + RG-LRU state
    "rwkv6-3b",              # attention-free: O(1) state
]


def engine_demo():
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(sl,)).astype(np.int32),
                    max_new_tokens=12)
            for i, sl in enumerate([24, 48, 16, 40, 32, 20])]
    eng = ServeEngine(model, params, max_batch=4, n_pages=64,
                      page_size=8, chunk_size=16)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"qwen3-0.6b[engine]     {len(done)} reqs "
          f"(prompts 16..48) -> {toks} tok in {dt * 1e3:6.0f} ms; "
          f"{eng.n_decode_steps} batched decode steps, "
          f"{eng.n_prefill_chunks} prefill chunks")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req{r.rid}: prompt {len(r.prompt):2d} tok, "
              f"ids={r.generated[:6]}")


def prefix_demo():
    """Six requests sharing a 28-token system prompt: the first pays
    its prefill, the other five attach the cached pages.  The prefix
    straddles a page boundary (28 = 3.5 pages of 8) so each sharing
    request also exercises the copy-on-write fork of the partial
    page."""
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    sys_prompt = rng.integers(0, cfg.vocab_size, size=(28,)).astype(np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(0, cfg.vocab_size,
                                      size=(8,)).astype(np.int32)]),
                    max_new_tokens=8)
            for i in range(6)]
    eng = ServeEngine(model, params, max_batch=4, n_pages=64,
                      page_size=8, chunk_size=16)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    c = eng.cache
    print(f"qwen3-0.6b[prefix]     {len(done)} reqs sharing a 28-tok "
          f"system prompt -> {dt * 1e3:6.0f} ms; "
          f"{c.n_shared_tokens} prompt tokens served from cache, "
          f"{c.n_cow} COW copies, "
          f"{eng.n_prefill_chunks} prefill chunks")


def router_demo():
    """Two shared-prompt workloads, two replicas: prefix affinity
    routes each workload to the replica whose trie already holds its
    system prompt, so neither replica ever re-ingests the other's."""
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    sys_prompts = [rng.integers(0, cfg.vocab_size,
                                size=(28,)).astype(np.int32)
                   for _ in range(2)]
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompts[i % 2],
                         rng.integers(0, cfg.vocab_size,
                                      size=(8,)).astype(np.int32)]),
                    max_new_tokens=8)
            for i in range(8)]
    programs = ServePrograms(model)      # one compile cache, N replicas
    replicas = [ServeEngine(model, params, max_batch=4, n_pages=64,
                            page_size=8, chunk_size=16,
                            programs=programs) for _ in range(2)]
    router = RequestRouter(replicas, policy="prefix")
    t0 = time.time()
    done = router.run(reqs)
    dt = time.time() - t0
    shared = [e.cache.n_shared_tokens for e in replicas]
    print(f"qwen3-0.6b[router]     {len(done)} reqs, 2 workloads x 2 "
          f"replicas -> {dt * 1e3:6.0f} ms; dispatched "
          f"{router.n_dispatched}, {router.n_affinity_hits} affinity "
          f"hits, prefix tokens reused per replica {shared}")


def stream_demo():
    """The async front-end over the same engine: per-request token
    streams, a mid-stream cancel, and an interactive request that
    preempts a full batch of batch-class work."""
    from repro.serve import ServeFrontend, ServeOptions
    cfg = configs.get_smoke("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=(24,)).astype(np.int32)
               for _ in range(4)]
    opts = ServeOptions(batch=2, page_size=8, chunk_size=16, n_pages=64)
    fe = opts.build_frontend(model, params)
    t0 = time.time()
    batch_streams = [fe.submit(p, 12) for p in prompts[:3]]
    for _ in range(4):                  # saturate both slots
        fe.pump()
    hangup = batch_streams[2]
    hangup.cancel()
    hi = fe.submit(prompts[3], 6, slo_class="interactive")
    hi_toks = list(hi)                  # iteration pumps the backend
    for s in batch_streams[:2]:
        for _ in s:                     # drain the batch streams
            pass
    dt = time.time() - t0
    st = fe.stats()
    print(f"qwen3-0.6b[stream]     {int(st['n_completed'])} streams + "
          f"1 cancelled -> {dt * 1e3:6.0f} ms; interactive got "
          f"{len(hi_toks)} tok via {int(st['n_slo_preemptions'])} "
          f"preemption(s), ids={hi_toks}")


def lockstep_demo():
    for name in LOCKSTEP_ARCHS:
        cfg = configs.get_smoke(name)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = SyntheticPipeline(cfg, batch=4, seq=48).device_batch(0)
        prefill = jax.jit(make_prefill_step(model))
        step = jax.jit(make_decode_step(model))
        last, cache = prefill(params, batch)
        tok = jax.numpy.argmax(last, -1).astype(jax.numpy.int32)[:, None]
        t0 = time.time()
        toks = [np.asarray(tok)]
        for _ in range(15):
            tok, cache = step(params, cache, tok)
            toks.append(np.asarray(tok))
        dt = time.time() - t0
        state_bytes = sum(
            v.size * v.dtype.itemsize for v in jax.tree.leaves(cache))
        print(f"{name}[lockstep] decoded 16 tok x 4 seqs in "
              f"{dt * 1e3:6.0f} ms; cache/state = "
              f"{state_bytes / 1e3:8.1f} kB; "
              f"ids[0]={np.concatenate(toks, 1)[0][:6]}")


def main():
    engine_demo()
    prefix_demo()
    router_demo()
    stream_demo()
    lockstep_demo()


if __name__ == "__main__":
    main()
