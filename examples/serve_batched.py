"""Batched serving example: prefill a batch of prompts across all cache
families (full KV, ring-buffer local attention, recurrent state), then
decode — mirrors the decode_32k / long_500k dry-run shapes at CPU size.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticPipeline
from repro.models import build_model
from repro.serve.step import make_decode_step, make_prefill_step

ARCHS = ["qwen3-0.6b",            # dense GQA: full KV cache
         "recurrentgemma-2b",     # hybrid: ring buffer + RG-LRU state
         "rwkv6-3b"]              # attention-free: O(1) state


def main():
    for name in ARCHS:
        cfg = configs.get_smoke(name)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = SyntheticPipeline(cfg, batch=4, seq=48).device_batch(0)
        prefill = jax.jit(make_prefill_step(model))
        step = jax.jit(make_decode_step(model))
        last, cache = prefill(params, batch)
        tok = jax.numpy.argmax(last, -1).astype(jax.numpy.int32)[:, None]
        t0 = time.time()
        toks = [np.asarray(tok)]
        for _ in range(15):
            tok, cache = step(params, cache, tok)
            toks.append(np.asarray(tok))
        dt = time.time() - t0
        state_bytes = sum(
            v.size * v.dtype.itemsize for v in jax.tree.leaves(cache))
        print(f"{name:20s} decoded 16 tok x 4 seqs in {dt * 1e3:6.0f} ms; "
              f"cache/state = {state_bytes / 1e3:8.1f} kB; "
              f"ids[0]={np.concatenate(toks, 1)[0][:6]}")


if __name__ == "__main__":
    main()
